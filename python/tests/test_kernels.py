"""L1 kernel correctness: Pallas vs pure-jnp oracle, swept over shapes and
dtypes with hypothesis (the core correctness signal for the kernels)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, vmem_bytes
from compile.kernels.varnorm import varnorm
from compile.kernels import ref


def rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    hq=st.sampled_from([2, 4, 8]),
    group=st.sampled_from([1, 2, 4]),
    s=st.integers(1, 9),
    t=st.sampled_from([8, 40, 56, 80]),
    kv_tile=st.sampled_from([8, 28, 40, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, hq, group, s, t, kv_tile, seed):
    if hq % group != 0:
        group = 1
    hkv = hq // group
    hd = 16
    rng = np.random.default_rng(seed)
    q = rand(rng, (b, hq, s, hd))
    k = rand(rng, (b, hkv, t, hd))
    v = rand(rng, (b, hkv, t, hd))
    got = attention(q, k, v, kv_tile=kv_tile)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    s=st.integers(1, 16),
    d=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_varnorm_matches_ref(b, s, d, seed):
    rng = np.random.default_rng(seed)
    h = rand(rng, (b, s, d))
    p = rand(rng, (b, s, d))
    got = varnorm(h, p)
    want = ref.varnorm_ref(h, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_attention_bf16_inputs_close_to_f32():
    rng = np.random.default_rng(0)
    q = rand(rng, (2, 4, 8, 16))
    k = rand(rng, (2, 4, 80, 16))
    v = rand(rng, (2, 4, 80, 16))
    f32 = attention(q, k, v)
    bf = attention(q.astype(jnp.bfloat16).astype(jnp.float32),
                   k.astype(jnp.bfloat16).astype(jnp.float32),
                   v.astype(jnp.bfloat16).astype(jnp.float32))
    # bf16 round-trip of inputs shifts outputs only slightly
    assert float(jnp.max(jnp.abs(f32 - bf))) < 0.05


def test_attention_softmax_rows_sum_to_one_property():
    # identical V rows ⇒ output equals that row regardless of scores
    rng = np.random.default_rng(1)
    q = rand(rng, (1, 2, 4, 16))
    k = rand(rng, (1, 2, 40, 16))
    row = rng.standard_normal(16).astype(np.float32)
    v = jnp.broadcast_to(jnp.asarray(row), (1, 2, 40, 16))
    out = attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(row, out.shape), rtol=1e-5)


def test_varnorm_zero_when_unchanged():
    rng = np.random.default_rng(2)
    h = rand(rng, (2, 8, 64))
    out = varnorm(h, h)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


def test_vmem_estimate_monotone_in_tile():
    assert vmem_bytes(8, 16, 80) > vmem_bytes(8, 16, 40)
    # nano default comfortably under a TPU core's ~16 MiB VMEM
    assert vmem_bytes(8, 16, 64) < 1 << 20


def test_attention_odd_kv_lengths_tile_cleanly():
    # 56 = pruned sparse length; 80 = dense ctx; both must tile
    rng = np.random.default_rng(3)
    for t in (56, 80):
        q = rand(rng, (1, 4, 8, 16))
        k = rand(rng, (1, 4, t, 16))
        v = rand(rng, (1, 4, t, 16))
        got = attention(q, k, v, kv_tile=64)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
