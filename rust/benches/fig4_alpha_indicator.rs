//! Figure 4: importance-estimation ablations on llada-nano —
//! (a) the α mixing weight in Eq. 1 (α passed as a runtime scalar, no
//!     recompile), and
//! (b) the variation-indicator tensor (hidden vs Q/K/V executable
//!     variants).

use esdllm::bench::{bench_n, Table};
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::runtime::Runtime;
use esdllm::workload::paper_name;

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let n = bench_n(16);
    let arch = "llada-nano";
    let benches: [&'static str; 3] = ["arith", "chain", "logic"];

    // (a) alpha sweep
    let mut ta = Table::new(
        &format!("Fig 4a analog: α ablation on {arch}, {n} samples"),
        &["alpha", "GSM8K~arith", "MATH~chain", "BBH~logic"],
    );
    for alpha in [0.0f32, 0.25, 0.5, 0.75, 1.0] {
        let mut row = vec![format!("{alpha:.2}")];
        for bench in benches {
            let opts = EvalOpts { alpha: Some(alpha), ..Default::default() };
            let r = evaluate(&rt, arch, Method::EsDllm, bench, n, &opts)?;
            row.push(format!("{:.2}", r.score));
        }
        ta.row(&row);
    }
    ta.print();
    ta.write_csv("artifacts/figures/fig4a_alpha.csv")?;

    // (b) indicator sweep
    let mut tb = Table::new(
        &format!("Fig 4b analog: variation-indicator ablation on {arch}, {n} samples"),
        &["indicator", "GSM8K~arith", "MATH~chain", "BBH~logic"],
    );
    for ind in ["h", "q", "k", "v"] {
        let mut row = vec![ind.to_string()];
        for bench in benches {
            // indicator executables exist for blk8 only; the chain
            // benchmark (blk32) reuses the hidden-state variant there
            let opts = if bench == "chain" && ind != "h" {
                EvalOpts {
                    indicator: Some("h".into()),
                    es_exe_override: Some("es_blk32_b8".into()),
                    ..Default::default()
                }
            } else {
                EvalOpts { indicator: Some(ind.to_string()), ..Default::default() }
            };
            let r = evaluate(&rt, arch, Method::EsDllm, bench, n, &opts)?;
            row.push(if bench == "chain" && ind != "h" {
                format!("({:.2})", r.score)
            } else {
                format!("{:.2}", r.score)
            });
        }
        tb.row(&row);
    }
    tb.print();
    println!("(parenthesised chain cells reuse the hidden-state variant: indicator \
              executables are compiled for block 8 only)");
    tb.write_csv("artifacts/figures/fig4b_indicator.csv")?;
    Ok(())
}
