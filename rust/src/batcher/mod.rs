//! Dynamic batcher: groups queued requests into executable-sized batches.
//!
//! The compiled step executables exist for batch sizes {1, 8}; the batcher
//! drains the queue into groups of up to 8, waiting at most `flush_ms`
//! after the first request before dispatching a partial batch (classic
//! deadline-based dynamic batching, vLLM-style).
//!
//! Since the continuous-batching refactor this drain-a-whole-batch path
//! backs only [`crate::router::SchedMode::RunToCompletion`] (the
//! baseline the serving benches compare against); the default continuous
//! mode admits requests into free scheduler slots one at a time.
//! `BatcherCfg::max_batch` doubles as the scheduler's slot count.

use std::time::{Duration, Instant};

use crate::threadpool::Channel;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatcherCfg {
    pub max_batch: usize,
    pub flush_ms: u64,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_batch: 8, flush_ms: 20 }
    }
}

/// Batch classes a continuous worker switches between: the lone-request
/// class (b = 1, the latency-optimal executables) plus the configured
/// full class. The compiled artifacts exist for batch sizes {1, 8}; the
/// sim backend accepts any geometry, so tests can run intermediate
/// classes too.
pub fn batch_classes(max_batch: usize) -> Vec<usize> {
    if max_batch <= 1 {
        vec![1]
    } else {
        vec![1, max_batch]
    }
}

/// Drain the next batch from `queue`. Blocks until at least one item is
/// available (or the channel closes → None), then collects up to
/// `cfg.max_batch` items within the flush window.
pub fn next_batch<T>(queue: &Channel<T>, cfg: &BatcherCfg) -> Option<Vec<T>> {
    let first = queue.recv()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + Duration::from_millis(cfg.flush_ms);
    while batch.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match queue.recv_timeout(deadline - now) {
            Some(item) => batch.push(item),
            None => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drains_up_to_max_batch() {
        let ch = Channel::bounded(32);
        for i in 0..12 {
            ch.try_send(i).unwrap();
        }
        let cfg = BatcherCfg { max_batch: 8, flush_ms: 5 };
        let b1 = next_batch(&ch, &cfg).unwrap();
        assert_eq!(b1, (0..8).collect::<Vec<_>>());
        let b2 = next_batch(&ch, &cfg).unwrap();
        assert_eq!(b2, (8..12).collect::<Vec<_>>());
    }

    #[test]
    fn flush_deadline_dispatches_partial_batch() {
        let ch = Channel::bounded(8);
        ch.try_send(1).unwrap();
        let cfg = BatcherCfg { max_batch: 8, flush_ms: 15 };
        let t0 = Instant::now();
        let b = next_batch(&ch, &cfg).unwrap();
        assert_eq!(b, vec![1]);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(10), "{waited:?}");
        assert!(waited < Duration::from_millis(500));
    }

    #[test]
    fn batch_classes_cover_lone_and_full() {
        assert_eq!(batch_classes(1), vec![1]);
        assert_eq!(batch_classes(8), vec![1, 8]);
        assert_eq!(batch_classes(0), vec![1]);
    }

    #[test]
    fn closed_queue_returns_none() {
        let ch: Channel<u32> = Channel::bounded(2);
        ch.close();
        assert!(next_batch(&ch, &BatcherCfg::default()).is_none());
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let ch = Channel::bounded(8);
        let ch2 = ch.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            ch2.send(2).unwrap();
        });
        ch.try_send(1).unwrap();
        let cfg = BatcherCfg { max_batch: 8, flush_ms: 60 };
        let b = next_batch(&ch, &cfg).unwrap();
        t.join().unwrap();
        assert_eq!(b, vec![1, 2]);
    }
}
