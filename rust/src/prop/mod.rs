//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it reports
//! the seed so the case can be replayed deterministically, and performs a
//! simple halving shrink for `usize` vectors produced via [`Gen::vec_usize`].

use crate::rng::SplitMix;

pub struct Gen {
    pub rng: SplitMix,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: SplitMix::new(seed) }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as i64, hi as i64) as usize
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.f64() as f32
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32_unit()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `prop` over `cases` generated cases. Panics (with the seed) on the
/// first failing case.
pub fn check<F: Fn(&mut Gen) -> Result<(), String>>(name: &str, cases: usize, prop: F) {
    let base = match std::env::var("ESDLLM_PROP_SEED") {
        Ok(v) => v.parse().unwrap_or(0xDEFA),
        Err(_) => 0xDEFA,
    };
    for case in 0..cases {
        let seed = base ^ ((case as u64) << 17) ^ 0x9E37_79B9;
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (replay with \
                 ESDLLM_PROP_SEED={base}, case seed {seed}): {msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("reverse-involution", 64, |g| {
            let len = g.usize_in(0, 30);
            let v = g.vec_usize(len, 0, 100);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w, "reverse twice changed the vector");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_reports_failures() {
        check("always-fails", 4, |_g| Err("nope".to_string()));
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(11);
        for _ in 0..200 {
            let x = g.usize_in(3, 9);
            assert!((3..=9).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
        }
    }
}
