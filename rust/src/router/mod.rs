//! Request router: the leader loop connecting the HTTP front end to
//! engine worker threads.
//!
//! PJRT objects are not `Send`, so each worker thread constructs its own
//! [`Runtime`] + [`Engine`] and pulls request batches from a shared
//! bounded queue (backpressure: `try_submit` fails when the queue is
//! full → HTTP 429/503). Responses travel back through per-request
//! oneshot slots.

use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::batcher::{next_batch, BatcherCfg};
use crate::engine::{Engine, EngineCfg};
use crate::metrics::Metrics;
use crate::runtime::Runtime;
use crate::threadpool::Channel;

pub struct GenRequest {
    pub prompt: String,
    pub submitted: std::time::Instant,
    reply: OneShot<Result<GenReply, String>>,
}

#[derive(Debug, Clone)]
pub struct GenReply {
    pub text: String,
    pub iterations: usize,
    pub wall_s: f64,
}

/// Minimal oneshot built on Mutex + Condvar.
pub struct OneShot<T>(Arc<(Mutex<Option<T>>, Condvar)>);

impl<T> Clone for OneShot<T> {
    fn clone(&self) -> Self {
        OneShot(self.0.clone())
    }
}

impl<T> OneShot<T> {
    pub fn new() -> Self {
        OneShot(Arc::new((Mutex::new(None), Condvar::new())))
    }

    pub fn put(&self, v: T) {
        *self.0 .0.lock().unwrap() = Some(v);
        self.0 .1.notify_all();
    }

    pub fn wait(&self) -> T {
        let mut g = self.0 .0.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return v;
            }
            g = self.0 .1.wait(g).unwrap();
        }
    }
}

impl<T> Default for OneShot<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Clone)]
pub struct Router {
    queue: Channel<GenRequest>,
    pub metrics: Arc<Metrics>,
}

pub struct RouterCfg {
    pub engine: EngineCfg,
    pub batcher: BatcherCfg,
    pub queue_cap: usize,
    pub workers: usize,
    pub artifacts_dir: std::path::PathBuf,
}

impl Router {
    /// Spawn worker threads and return the router handle. Each worker owns
    /// a full Runtime (PJRT client + compiled executables + params).
    pub fn start(cfg: RouterCfg) -> Router {
        let queue: Channel<GenRequest> = Channel::bounded(cfg.queue_cap.max(1));
        let metrics = Arc::new(Metrics::default());
        metrics.start_clock();
        for w in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let engine_cfg = cfg.engine.clone();
            let batcher = cfg.batcher;
            let dir = cfg.artifacts_dir.clone();
            std::thread::Builder::new()
                .name(format!("engine-{w}"))
                .spawn(move || worker_loop(queue, metrics, engine_cfg, batcher, dir))
                .expect("spawn engine worker");
        }
        Router { queue, metrics }
    }

    /// Enqueue a request; returns a oneshot to wait on, or Err when the
    /// queue is full (backpressure).
    pub fn try_submit(&self, prompt: String) -> Result<OneShot<Result<GenReply, String>>, ()> {
        let reply = OneShot::new();
        let req = GenRequest {
            prompt,
            submitted: std::time::Instant::now(),
            reply: reply.clone(),
        };
        match self.queue.try_send(req) {
            Ok(()) => {
                self.metrics.requests_total.inc();
                Ok(reply)
            }
            Err(_) => {
                self.metrics.requests_rejected.inc();
                Err(())
            }
        }
    }

    /// Blocking submit (used by the load generator / tests).
    pub fn submit(&self, prompt: String) -> Result<OneShot<Result<GenReply, String>>, ()> {
        let reply = OneShot::new();
        let req = GenRequest {
            prompt,
            submitted: std::time::Instant::now(),
            reply: reply.clone(),
        };
        self.queue.send(req).map_err(|_| ())?;
        self.metrics.requests_total.inc();
        Ok(reply)
    }

    pub fn shutdown(&self) {
        self.queue.close();
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

fn worker_loop(
    queue: Channel<GenRequest>,
    metrics: Arc<Metrics>,
    engine_cfg: EngineCfg,
    batcher: BatcherCfg,
    artifacts_dir: std::path::PathBuf,
) {
    let rt = match Runtime::load(&artifacts_dir) {
        Ok(rt) => rt,
        Err(e) => {
            log::error!("engine worker failed to load runtime: {e:#}");
            // drain queue with errors so clients aren't stuck
            while let Some(req) = queue.recv() {
                req.reply.put(Err(format!("runtime unavailable: {e}")));
            }
            return;
        }
    };
    let mut engine = Engine::new(&rt, engine_cfg);
    while let Some(batch) = next_batch(&queue, &batcher) {
        metrics.batches_total.inc();
        metrics.batch_occupancy_sum.add(batch.len() as u64);
        for req in &batch {
            metrics
                .queue_latency
                .observe_secs(req.submitted.elapsed().as_secs_f64());
        }
        let prompts: Vec<String> = batch.iter().map(|r| r.prompt.clone()).collect();
        match engine.generate(&prompts) {
            Ok(res) => {
                metrics.tokens_generated.add(res.tokens_generated as u64);
                metrics.iterations_total.add(res.iterations as u64);
                metrics.prefill_steps.add(res.n_prefill as u64);
                metrics.dual_steps.add(res.n_dual as u64);
                metrics.es_steps.add(res.n_es as u64);
                for (req, text) in batch.iter().zip(res.texts.iter()) {
                    let lat = req.submitted.elapsed().as_secs_f64();
                    metrics.request_latency.observe_secs(lat);
                    req.reply.put(Ok(GenReply {
                        text: text.clone(),
                        iterations: res.iterations,
                        wall_s: res.wall_s,
                    }));
                }
            }
            Err(e) => {
                log::error!("generate failed: {e:#}");
                for req in &batch {
                    req.reply.put(Err(format!("{e}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oneshot_roundtrip() {
        let s: OneShot<u32> = OneShot::new();
        let s2 = s.clone();
        std::thread::spawn(move || s2.put(7));
        assert_eq!(s.wait(), 7);
    }
}
