//! SLO-serving acceptance tests: block-boundary preemption is
//! trajectory-exact (a preempted-then-resumed sequence decodes the
//! byte-identical output of an undisturbed run), the preemption ledger
//! the sim backend feeds through `StepBackend::note_preempt` is
//! byte-exact with the `ResidencyPool::note_victim` calls the PJRT
//! backend makes for the same park / resume / drop schedule, and the
//! router's SLO-aware policy actually reorders service under load:
//! latency-sensitive arrivals jump the queue (and preempt a
//! block-boundary victim), while overload and blown deadlines are
//! answered with structured `overloaded:` / `timeout:` errors — never
//! a silent hang. Everything runs over the sim backend; no PJRT
//! artifacts required.

use std::time::{Duration, Instant};

use esdllm::batcher::BatcherCfg;
use esdllm::cache::RefreshPolicy;
use esdllm::engine::{EngineCfg, Method};
use esdllm::router::{Router, RouterCfg, SchedMode, SloPolicy, WorkerBackend};
use esdllm::runtime::resident::{PreemptEvent, ResidencyPool};
use esdllm::sampler::SamplerCfg;
use esdllm::scheduler::sim::{SimBackend, SimCfg};
use esdllm::scheduler::{
    FinishedSeq, GroupScheduler, ResumeOutcome, SchedCfg, SeqInput, SeqParams, SloClass,
};

const BLOCK: usize = 4;

fn sched(n_slots: usize) -> GroupScheduler<'static> {
    let backend = SimBackend::new(SimCfg::default());
    let cfg = SchedCfg {
        method: Method::EsDllm,
        block: BLOCK,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
        sampler: SamplerCfg::llada(),
        seed: 0,
        k: 1,
        hysteresis: None,
    };
    GroupScheduler::new(Box::new(backend), n_slots, cfg).unwrap()
}

fn input(id: u64, prompt: &str, params: SeqParams) -> SeqInput {
    SeqInput { id, prompt: prompt.to_string(), params, submitted: Instant::now() }
}

fn drain(s: &mut GroupScheduler<'_>) -> Vec<FinishedSeq> {
    let mut out = Vec::new();
    let mut guard = 0;
    while s.active() > 0 {
        out.extend(s.tick().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
    }
    out
}

/// Drive a 1-slot scheduler to its victim's first block boundary, park
/// the victim for a latency-sensitive arrival, serve that arrival,
/// resume the victim, and return (victim finish, pool stats snapshot).
fn preempt_resume_run() -> (FinishedSeq, esdllm::runtime::resident::PoolStats) {
    let mut s = sched(1);
    s.admit(input(1, "abcdefgh", SeqParams::default())).unwrap();
    // 4 ticks = block 0 of a 2-block sequence: the next plan is the
    // block-1 grounding prefill, i.e. a preemption-safe boundary
    for _ in 0..BLOCK {
        assert!(s.tick().unwrap().is_empty(), "victim must still be mid-flight");
    }
    assert!(s.at_block_boundary());
    assert_eq!(s.preempt_victim(SloClass::LatencySensitive), Some(1));
    assert_eq!(s.parked(), 1);
    assert_eq!(s.best_parked_class(), Some(SloClass::Throughput));

    // the latency-sensitive arrival takes the freed slot end-to-end
    let ls = SeqParams { slo: SloClass::LatencySensitive, ..Default::default() };
    s.admit(input(2, "xy", ls)).unwrap();
    let served = drain(&mut s);
    assert_eq!(served.len(), 1);
    assert_eq!(served[0].id, 2);

    // resume must re-ground the parked slot, not reseed the chain
    let before = s.transfer_stats();
    assert!(matches!(s.resume_victim(), ResumeOutcome::Seated(1)));
    let finishes = drain(&mut s);
    let delta = s.transfer_stats().since(&before);
    assert_eq!(delta.full_kv_uploads, 0, "resume must not pay a full-KV reseed");
    assert_eq!(finishes.len(), 1);
    let pool = s.pool_stats();
    (finishes.into_iter().next().unwrap(), pool)
}

#[test]
fn preempted_then_resumed_decode_is_trajectory_exact() {
    // baseline: the same prompt decoded solo, never disturbed
    let mut s = sched(1);
    s.admit(input(1, "abcdefgh", SeqParams::default())).unwrap();
    let baseline = drain(&mut s).remove(0);

    let (victim, pool) = preempt_resume_run();
    assert_eq!(victim.id, 1);
    assert_eq!(victim.text, baseline.text, "park/resume must not perturb a token");
    assert_eq!(victim.tokens, baseline.tokens);
    assert_eq!(victim.iterations, baseline.iterations);
    assert!(victim.error.is_none());

    // the ledger saw exactly one park and one resume, nobody left parked
    assert_eq!(pool.preemptions, 1);
    assert_eq!(pool.victim_resumes, 1);
    assert_eq!(pool.victims_parked, 0);
}

/// `PjrtBackend::note_preempt` forwards every preemption event to
/// `ResidencyPool::note_victim` — exactly the calls the sim backend
/// makes. Replaying the schedule's event sequence against a bare pool
/// (the PJRT planner side) must reproduce the sim run's ledger
/// byte-exact, for both the resumed and the dropped lifecycle.
#[test]
fn preemption_ledger_parity_sim_vs_pjrt_pool_calls() {
    // sim side: park → resume through the scheduler
    let (_, sim_pool) = preempt_resume_run();
    let pool = ResidencyPool::new();
    pool.note_victim(PreemptEvent::Parked);
    pool.note_victim(PreemptEvent::Resumed);
    let ps = pool.stats();
    assert_eq!(ps.preemptions, sim_pool.preemptions);
    assert_eq!(ps.victim_resumes, sim_pool.victim_resumes);
    assert_eq!(ps.victims_parked, sim_pool.victims_parked);

    // sim side: park → drop (eviction while parked)
    let mut s = sched(1);
    s.admit(input(1, "abcdefgh", SeqParams::default())).unwrap();
    for _ in 0..BLOCK {
        s.tick().unwrap();
    }
    assert_eq!(s.preempt_victim(SloClass::LatencySensitive), Some(1));
    s.evict_all();
    assert_eq!(s.parked(), 0, "eviction covers the parked victim");
    let sim_drop = s.pool_stats();
    let pool = ResidencyPool::new();
    pool.note_victim(PreemptEvent::Parked);
    pool.note_victim(PreemptEvent::Dropped);
    let ps = pool.stats();
    assert_eq!(ps.preemptions, sim_drop.preemptions);
    assert_eq!(ps.victim_resumes, sim_drop.victim_resumes);
    assert_eq!(ps.victims_parked, sim_drop.victims_parked);
}

// ---------------------------------------------------------------------------
// router-level: the SLO-aware policy reorders service under load
// ---------------------------------------------------------------------------

fn slow_router(slots: usize, queue_cap: usize) -> Router {
    let mut engine = EngineCfg::new("llada-nano", Method::EsDllm);
    engine.block = BLOCK;
    engine.refresh = RefreshPolicy { prompt_period: 16, block_period: 2 };
    let mut cfg = RouterCfg::new(engine, std::path::PathBuf::from("/nonexistent"));
    // slow per-plan costs keep the lone slot busy for tens of ms, so
    // queue ordering (not raw timing) decides who is served next
    cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(4000, 2000, 2000));
    cfg.batcher = BatcherCfg { max_batch: slots, flush_ms: 2 };
    cfg.queue_cap = queue_cap;
    cfg.mode = SchedMode::Continuous;
    cfg.policy = SloPolicy::SloAware;
    Router::start(cfg)
}

#[test]
fn latency_sensitive_jumps_the_queue_under_load() {
    let router = slow_router(1, 16);
    // an 8-char request occupies the only slot for ~10 ticks (~20 ms)
    let long = router.submit("abcdefgh".into(), SeqParams::default()).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    let batch_params = SeqParams { slo: SloClass::Batch, ..Default::default() };
    let batch: Vec<_> = (0..3)
        .map(|_| router.submit("cdef".into(), batch_params).unwrap())
        .collect();
    let ls_params = SeqParams { slo: SloClass::LatencySensitive, ..Default::default() };
    let ls = router.submit("wxyz".into(), ls_params).unwrap();

    let ls_reply = ls.wait_timeout(Duration::from_secs(60)).expect("no hang").unwrap();
    let long_reply = long.wait_timeout(Duration::from_secs(60)).expect("no hang").unwrap();
    assert_eq!(long_reply.text, "abcdefgh", "preempted victim still echoes exactly");
    for h in batch {
        let b = h.wait_timeout(Duration::from_secs(60)).expect("no hang").unwrap();
        assert!(
            ls_reply.queue_s < b.queue_s,
            "latency-sensitive ({:.4}s queued) must be served before batch \
             ({:.4}s queued)",
            ls_reply.queue_s,
            b.queue_s
        );
    }
    assert_eq!(router.metrics.requests_failed.get(), 0);
    router.shutdown();
}

#[test]
fn overload_and_blown_deadlines_shed_with_structured_errors() {
    let router = slow_router(1, 1);
    // occupy the slot, then fill the 1-deep queue with a batch request
    let long = router.submit("abcdefgh".into(), SeqParams::default()).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    let batch_params = SeqParams { slo: SloClass::Batch, ..Default::default() };
    let victim = router.submit("cdef".into(), batch_params).unwrap();
    // a latency-sensitive arrival sheds the queued batch request
    let ls_params = SeqParams { slo: SloClass::LatencySensitive, ..Default::default() };
    let ls = router.try_submit("wxyz".into(), ls_params).unwrap();
    let shed = victim.wait_timeout(Duration::from_secs(60)).expect("no hang");
    let err = shed.expect_err("the shed victim gets an error, not a completion");
    assert!(err.starts_with("overloaded:"), "got: {err}");

    // a request whose deadline burned while queued sheds as timeout:
    // before any prefill
    let doomed_params = SeqParams { timeout_ms: Some(1), ..Default::default() };
    let doomed = router.submit("cdef".into(), doomed_params).unwrap();
    let err = doomed
        .wait_timeout(Duration::from_secs(60))
        .expect("no hang")
        .expect_err("an already-expired request must not be served");
    assert!(err.starts_with("timeout:"), "got: {err}");

    // the survivors complete normally
    assert!(long.wait_timeout(Duration::from_secs(60)).expect("no hang").is_ok());
    assert!(ls.wait_timeout(Duration::from_secs(60)).expect("no hang").is_ok());
    assert!(router.metrics.shed_total.get() >= 2);
    router.shutdown();
}
