//! Stub of the `xla` PJRT bindings used by `crate::runtime`.
//!
//! This container image ships no PJRT shared library, so the real
//! bindings cannot link. The stub exposes the exact API surface the
//! runtime uses and fails fast at [`PjRtClient::cpu`] with a clear
//! message; everything downstream (router, scheduler, HTTP front end)
//! degrades gracefully, and the simulation backend plus all host-side
//! tests run without it. Point the `xla` path dependency in the root
//! `Cargo.toml` at the real bindings to enable PJRT execution — no
//! source changes are needed.
//!
//! One piece of behavior IS modeled rather than stubbed: device-buffer
//! lifetime under input-output aliasing (donation). The runtime declares
//! alias pairs at compile time
//! ([`PjRtClient::compile_with_io_aliases`], from the manifest's
//! retained-chaining signatures) so the device-apply cache update writes
//! its input buffer in place. [`StubDevice`] reproduces exactly the
//! allocation consequences of that contract — an aliased output reuses
//! its donated input's allocation, an unaliased one materializes a fresh
//! buffer while the input is still live — behind a live/peak allocation
//! ledger, so tests can pin the invariant donation buys ("at most one
//! live copy per chained tensor, even transiently during execution")
//! without any PJRT library present.

use std::cell::{Cell, RefCell};
use std::fmt;
use std::path::Path;
use std::rc::Rc;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (stub xla crate; link the real \
         xla bindings to enable execution)"
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Bf16,
    F32,
    S32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    Bf16,
    S32,
}

pub struct PjRtDevice;

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile"))
    }

    /// Compile with an input-output alias (donation) config: each
    /// `(output_index, parameter_number)` pair tells the runtime that the
    /// output may reuse — and therefore invalidates — the argument
    /// buffer passed at that parameter position. The real bindings lower
    /// this to `HloInputOutputAliasConfig` before `client.compile`; the
    /// stub fails like every other compile entry point.
    pub fn compile_with_io_aliases(
        &self,
        _comp: &XlaComputation,
        _aliases: &[(usize, usize)],
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile_with_io_aliases"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("buffer_from_host_literal"))
    }
}

/// A device buffer. Real-path constructors all fail in the stub, so a
/// live `PjRtBuffer` only ever exists with a [`StubDevice`] allocation
/// behind it (the donation-model tests).
pub struct PjRtBuffer {
    alloc: Option<Rc<Allocation>>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("to_literal_sync"))
    }

    /// Size of the backing stub allocation in bytes (0 when the buffer
    /// has no stub allocation).
    pub fn stub_bytes(&self) -> usize {
        self.alloc.as_ref().map(|a| a.bytes).unwrap_or(0)
    }

    /// Whether this buffer shares its device allocation with `other` —
    /// true exactly when one was produced by donating the other (or a
    /// chain of donations) under an input-output alias config.
    pub fn shares_allocation(&self, other: &PjRtBuffer) -> bool {
        match (&self.alloc, &other.alloc) {
            (Some(a), Some(b)) => Rc::ptr_eq(a, b),
            _ => false,
        }
    }
}

// --------------------------------------------------------------------------
// Stubbed device-memory model: allocation ledger + donation semantics
// --------------------------------------------------------------------------

struct LedgerCells {
    live: Cell<usize>,
    peak: Cell<usize>,
}

/// A deterministic device-fault schedule: 1-based event ordinals at
/// which an executable run ([`StubExecutable::execute`]) or an
/// allocation ([`StubDevice::try_alloc`]) fails. Self-contained here —
/// the stub cannot depend on the serving crate — and populated from the
/// serving layer's fault plan so the same ordinal faults at the same
/// modeled event on both sides.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    pub exec_at: Vec<u64>,
    pub alloc_at: Vec<u64>,
}

/// Shared fault state: the schedule plus per-kind event counters, shared
/// by a [`StubDevice`] and every executable it builds.
struct FaultState {
    schedule: RefCell<FaultSchedule>,
    exec_seen: Cell<u64>,
    alloc_seen: Cell<u64>,
}

impl FaultState {
    fn new() -> Rc<FaultState> {
        Rc::new(FaultState {
            schedule: RefCell::new(FaultSchedule::default()),
            exec_seen: Cell::new(0),
            alloc_seen: Cell::new(0),
        })
    }
}

/// One device allocation; dropping the last buffer that references it
/// releases it from the ledger.
struct Allocation {
    ledger: Rc<LedgerCells>,
    bytes: usize,
}

impl Allocation {
    fn fresh(ledger: &Rc<LedgerCells>, bytes: usize) -> Rc<Allocation> {
        let live = ledger.live.get() + 1;
        ledger.live.set(live);
        if live > ledger.peak.get() {
            ledger.peak.set(live);
        }
        Rc::new(Allocation { ledger: ledger.clone(), bytes })
    }
}

impl Drop for Allocation {
    fn drop(&mut self) {
        self.ledger.live.set(self.ledger.live.get() - 1);
    }
}

/// Allocation-accurate model of a PJRT device for donation tests: counts
/// live allocations (and the peak), hands out buffers, and builds
/// executables whose outputs either materialize fresh allocations or —
/// for pairs named in an input-output alias config — reuse the donated
/// input's allocation in place, exactly as a donation-capable PJRT build
/// does. Single-threaded by construction (`Rc`/`Cell`), matching the
/// non-`Send` threading model of the real wrapper types.
pub struct StubDevice {
    ledger: Rc<LedgerCells>,
    faults: Rc<FaultState>,
}

impl StubDevice {
    pub fn new() -> StubDevice {
        StubDevice {
            ledger: Rc::new(LedgerCells { live: Cell::new(0), peak: Cell::new(0) }),
            faults: FaultState::new(),
        }
    }

    /// Install (or replace) the deterministic fault schedule. Event
    /// counters keep running — the schedule addresses ordinals from
    /// device construction, not from installation.
    pub fn set_fault_schedule(&self, s: FaultSchedule) {
        *self.faults.schedule.borrow_mut() = s;
    }

    /// Executable-run events seen so far (faulted runs included).
    pub fn exec_events(&self) -> u64 {
        self.faults.exec_seen.get()
    }

    /// Fallible-allocation events seen so far (faulted attempts
    /// included). The legacy infallible [`StubDevice::alloc`] does not
    /// count here — only [`StubDevice::try_alloc`] participates in the
    /// fault model.
    pub fn alloc_events(&self) -> u64 {
        self.faults.alloc_seen.get()
    }

    /// Currently live device allocations.
    pub fn live_buffers(&self) -> usize {
        self.ledger.live.get()
    }

    /// High-water mark of live allocations since construction (or the
    /// last [`StubDevice::reset_peak`]).
    pub fn peak_live_buffers(&self) -> usize {
        self.ledger.peak.get()
    }

    /// Restart peak tracking from the current live count.
    pub fn reset_peak(&self) {
        self.ledger.peak.set(self.ledger.live.get());
    }

    /// Allocate a device buffer of `bytes` (a seed upload).
    pub fn alloc(&self, bytes: usize) -> PjRtBuffer {
        PjRtBuffer { alloc: Some(Allocation::fresh(&self.ledger, bytes)) }
    }

    /// Fault-aware allocation: counts one allocation event and fails it
    /// when the installed [`FaultSchedule`] names its ordinal (modeling
    /// device OOM on a chain seed/checkout). Clean events allocate
    /// exactly like [`StubDevice::alloc`].
    pub fn try_alloc(&self, bytes: usize) -> Result<PjRtBuffer, Error> {
        let n = self.faults.alloc_seen.get() + 1;
        self.faults.alloc_seen.set(n);
        if self.faults.schedule.borrow().alloc_at.contains(&n) {
            return Err(Error(format!("injected alloc fault at device event {n}")));
        }
        Ok(self.alloc(bytes))
    }

    /// Build a stub executable producing one output per `out_bytes`
    /// entry. `aliases` holds `(output_index, parameter_number)` pairs in
    /// the same format the runtime derives from the manifest
    /// ([`PjRtClient::compile_with_io_aliases`]): at execution, an
    /// aliased output donates the named argument's allocation instead of
    /// materializing a second copy.
    pub fn executable(&self, out_bytes: &[usize], aliases: &[(usize, usize)]) -> StubExecutable {
        StubExecutable {
            ledger: self.ledger.clone(),
            faults: self.faults.clone(),
            out_bytes: out_bytes.to_vec(),
            aliases: aliases.to_vec(),
        }
    }
}

impl Default for StubDevice {
    fn default() -> Self {
        StubDevice::new()
    }
}

/// A compiled executable under the stub device model: execution
/// allocates fresh output buffers, except for aliased outputs, which
/// reuse (donate) their input's allocation — the device-side effect of
/// `HloInputOutputAliasConfig`.
pub struct StubExecutable {
    ledger: Rc<LedgerCells>,
    faults: Rc<FaultState>,
    out_bytes: Vec<usize>,
    aliases: Vec<(usize, usize)>,
}

impl StubExecutable {
    /// Run once over `args`. Aliased outputs share their donated input's
    /// allocation (the caller must treat that input as invalidated, as
    /// under real donation); every other output is a fresh allocation
    /// held live alongside the inputs for the duration of the call.
    /// Each call counts one exec event against the device's
    /// [`FaultSchedule`]; a scheduled event fails before allocating any
    /// output (no partial result, inputs untouched — the caller must
    /// treat the chain as invalid, exactly as after a real device error).
    pub fn execute(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>, Error> {
        let n = self.faults.exec_seen.get() + 1;
        self.faults.exec_seen.set(n);
        if self.faults.schedule.borrow().exec_at.contains(&n) {
            return Err(Error(format!("injected exec fault at device event {n}")));
        }
        for &(out, param) in &self.aliases {
            if out >= self.out_bytes.len() {
                return Err(Error(format!(
                    "alias names output {out}, executable has {}",
                    self.out_bytes.len()
                )));
            }
            if param >= args.len() {
                return Err(Error(format!(
                    "alias names parameter {param}, called with {} args",
                    args.len()
                )));
            }
            if self.aliases.iter().filter(|(_, p)| *p == param).count() > 1 {
                return Err(Error(format!(
                    "parameter {param} donated to more than one output"
                )));
            }
        }
        let mut out = Vec::with_capacity(self.out_bytes.len());
        for (i, &bytes) in self.out_bytes.iter().enumerate() {
            let donated = self.aliases.iter().find(|(o, _)| *o == i).map(|&(_, p)| p);
            let alloc = match donated {
                Some(p) => match &args[p].alloc {
                    Some(a) => a.clone(),
                    None => return Err(Error(format!(
                        "parameter {p} has no stub allocation to donate"
                    ))),
                },
                None => Allocation::fresh(&self.ledger, bytes),
            };
            out.push(PjRtBuffer { alloc: Some(alloc) });
        }
        Ok(out)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_b"))
    }

    /// Untupled execution: the real bindings run with
    /// `ExecuteOptions.untuple_result = true`, so the inner vector holds
    /// one `PjRtBuffer` per root-tuple element. This is what lets the
    /// runtime retain individual outputs on device (device-apply cache
    /// chaining) instead of downloading one fused result tuple.
    pub fn execute_untupled<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_untupled"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Err(unavailable("array_shape"))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("to_vec"))
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, Error> {
        Err(unavailable("convert"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("to_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("PJRT backend unavailable"));
    }

    #[test]
    fn donated_output_reuses_the_allocation() {
        let dev = StubDevice::new();
        let seed = dev.alloc(1024);
        let exe = dev.executable(&[1024], &[(0, 0)]);
        let out = exe.execute(&[&seed]).unwrap();
        assert_eq!(dev.live_buffers(), 1, "no second copy, even transiently");
        assert_eq!(dev.peak_live_buffers(), 1);
        assert!(out[0].shares_allocation(&seed));
        drop(seed);
        assert_eq!(dev.live_buffers(), 1, "chained handle keeps it alive");
    }

    #[test]
    fn unaliased_output_holds_two_copies_transiently() {
        let dev = StubDevice::new();
        let seed = dev.alloc(1024);
        let exe = dev.executable(&[1024], &[]);
        let out = exe.execute(&[&seed]).unwrap();
        assert_eq!(dev.live_buffers(), 2, "replace-and-drop's transient");
        assert!(!out[0].shares_allocation(&seed));
        drop(seed);
        assert_eq!(dev.live_buffers(), 1);
    }

    #[test]
    fn fault_schedule_fails_the_named_exec_event() {
        let dev = StubDevice::new();
        dev.set_fault_schedule(FaultSchedule { exec_at: vec![2], alloc_at: vec![] });
        let seed = dev.alloc(64);
        let exe = dev.executable(&[64], &[(0, 0)]);
        assert!(exe.execute(&[&seed]).is_ok(), "event 1 clean");
        let err = exe.execute(&[&seed]).expect_err("event 2 scheduled");
        assert!(format!("{err}").contains("injected exec fault"), "{err}");
        assert_eq!(dev.live_buffers(), 1, "faulted run allocated nothing");
        assert!(exe.execute(&[&seed]).is_ok(), "event 3 clean again");
        assert_eq!(dev.exec_events(), 3);
    }

    #[test]
    fn fault_schedule_fails_the_named_alloc_event() {
        let dev = StubDevice::new();
        dev.set_fault_schedule(FaultSchedule { exec_at: vec![], alloc_at: vec![1, 3] });
        assert!(dev.try_alloc(8).is_err(), "event 1 scheduled");
        assert_eq!(dev.live_buffers(), 0);
        assert!(dev.try_alloc(8).is_ok(), "event 2 clean");
        assert!(dev.try_alloc(8).is_err(), "event 3 scheduled");
        assert_eq!(dev.alloc_events(), 3);
    }

    #[test]
    fn invalid_alias_configs_error() {
        let dev = StubDevice::new();
        let a = dev.alloc(8);
        assert!(dev.executable(&[8], &[(1, 0)]).execute(&[&a]).is_err());
        assert!(dev.executable(&[8], &[(0, 3)]).execute(&[&a]).is_err());
        assert!(dev
            .executable(&[8, 8], &[(0, 0), (1, 0)])
            .execute(&[&a])
            .is_err());
    }
}
