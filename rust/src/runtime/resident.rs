//! Device-resident group caches: the planning/accounting layer that
//! keeps KV, indicator, and confidence state on the device between
//! scheduler ticks instead of re-shipping it every executable run.
//!
//! The pre-resident step path cloned the entire group KV on the host,
//! uploaded all of it, ran the step, downloaded the block outputs, and
//! scattered them back into host vectors — every tick, for every
//! co-resident slot. Early-skipping reduces FLOPs but none of that byte
//! traffic, which is exactly the measured-speedup gap `perf_hotpath`
//! documents. This module closes it:
//!
//!   * [`DeviceGroupCaches`] owns a **buffer pool** (persistent staging
//!     tensors for step/prefill tokens, the gathered indicator input and
//!     the occupancy-masked confidence input — allocations live for the
//!     backend's lifetime) plus the **retained device handles** for the
//!     big cache inputs, and a [`TransferStats`] ledger;
//!   * every `sync_*` call consults the dirty bitmaps maintained by
//!     [`crate::cache::GroupCaches`] and ships only the rows the host
//!     actually mutated since the resident copy was last refreshed
//!     (delta transfer), clearing the bits it ships;
//!   * [`ApplyMode::Device`] models a transport that applies executable
//!     outputs (the KV/indicator block scatters, the prefill row merges)
//!     to the resident copy on-device — the outputs never left the
//!     device, so `note_*_applied` clears their dirty bits and the
//!     steady-state step uploads **zero** KV/indicator bytes. The
//!     deterministic sim backend runs in this mode, which is how the
//!     transfer win is measured and asserted without PJRT artifacts;
//!   * [`ApplyMode::Host`] is today's PJRT reality: outputs land in the
//!     host mirror only, so their rows stay dirty and re-ship as a
//!     *delta* (block rows, not the full tensor) on the next sync. A
//!     future device-side scatter executable upgrades the PJRT transport
//!     to `Device` mode with no scheduler changes.
//!
//! Confidence is host-computed (softmax over downloaded logits) and the
//! rebuild of the pruned sparse KV is host-side top-k, so those rows are
//! honestly host-originated in both modes and re-ship as deltas. The
//! occupancy mask applied to the confidence input is modelled as a
//! device-side op (a real transport ships a `batch`-bit mask, not the
//! tensor).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::cache::{DirtyBitmap, GroupCaches};
use crate::manifest::Dims;
use crate::runtime::tensor::HostTensor;

/// The one copy of the sync-planning invariant: an unseeded kind ships
/// its whole resident payload and clears everything; a seeded kind ships
/// (and clears) exactly the dirty rows of the reading slots. Clearing a
/// bit is a promise that the device copy now matches the host — callers
/// that fail to deliver the shipped bytes must
/// [`DeviceGroupCaches::invalidate`] to take the promise back.
fn plan_sync(
    bm: &mut DirtyBitmap,
    seeded: &mut bool,
    slots: &[usize],
    row_bytes: u64,
    seed_bytes: u64,
) -> u64 {
    if !*seeded {
        *seeded = true;
        bm.clear_all();
        seed_bytes
    } else {
        let mut rows = 0usize;
        for &b in slots {
            rows += bm.count_slot(b);
            bm.clear_slot(b);
        }
        rows as u64 * row_bytes
    }
}

/// Which logical input a transfer belongs to (per-kind accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TransferKind {
    Kv,
    KvSparse,
    Ind,
    Conf,
    Tokens,
}

/// Logical host→device transfer ledger. "Logical" bytes are what a
/// delta-capable transport ships; `upload_bytes_saved` is the difference
/// against the clone-and-reupload baseline (the full tensor every call).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TransferStats {
    pub upload_bytes: u64,
    pub upload_bytes_saved: u64,
    pub kv_upload_bytes: u64,
    pub kv_sparse_upload_bytes: u64,
    pub ind_upload_bytes: u64,
    pub conf_upload_bytes: u64,
    pub token_upload_bytes: u64,
    /// syncs that shipped an entire KV tensor (dense or sparse)
    pub full_kv_uploads: u64,
    /// syncs served entirely from the resident copy (zero bytes shipped)
    pub resident_reuses: u64,
}

impl TransferStats {
    pub fn record(&mut self, kind: TransferKind, shipped: u64, full: u64) {
        self.upload_bytes += shipped;
        self.upload_bytes_saved += full.saturating_sub(shipped);
        if shipped == 0 && full > 0 {
            self.resident_reuses += 1;
        }
        match kind {
            TransferKind::Kv => {
                self.kv_upload_bytes += shipped;
                if full > 0 && shipped >= full {
                    self.full_kv_uploads += 1;
                }
            }
            TransferKind::KvSparse => {
                self.kv_sparse_upload_bytes += shipped;
                if full > 0 && shipped >= full {
                    self.full_kv_uploads += 1;
                }
            }
            TransferKind::Ind => self.ind_upload_bytes += shipped,
            TransferKind::Conf => self.conf_upload_bytes += shipped,
            TransferKind::Tokens => self.token_upload_bytes += shipped,
        }
    }

    /// Field-wise accumulate of another ledger (or a ledger delta).
    pub fn merge(&mut self, d: &TransferStats) {
        self.upload_bytes += d.upload_bytes;
        self.upload_bytes_saved += d.upload_bytes_saved;
        self.kv_upload_bytes += d.kv_upload_bytes;
        self.kv_sparse_upload_bytes += d.kv_sparse_upload_bytes;
        self.ind_upload_bytes += d.ind_upload_bytes;
        self.conf_upload_bytes += d.conf_upload_bytes;
        self.token_upload_bytes += d.token_upload_bytes;
        self.full_kv_uploads += d.full_kv_uploads;
        self.resident_reuses += d.resident_reuses;
    }

    /// Field-wise delta against an earlier snapshot of the same ledger.
    pub fn since(&self, earlier: &TransferStats) -> TransferStats {
        TransferStats {
            upload_bytes: self.upload_bytes.saturating_sub(earlier.upload_bytes),
            upload_bytes_saved: self
                .upload_bytes_saved
                .saturating_sub(earlier.upload_bytes_saved),
            kv_upload_bytes: self.kv_upload_bytes.saturating_sub(earlier.kv_upload_bytes),
            kv_sparse_upload_bytes: self
                .kv_sparse_upload_bytes
                .saturating_sub(earlier.kv_sparse_upload_bytes),
            ind_upload_bytes: self.ind_upload_bytes.saturating_sub(earlier.ind_upload_bytes),
            conf_upload_bytes: self
                .conf_upload_bytes
                .saturating_sub(earlier.conf_upload_bytes),
            token_upload_bytes: self
                .token_upload_bytes
                .saturating_sub(earlier.token_upload_bytes),
            full_kv_uploads: self.full_kv_uploads.saturating_sub(earlier.full_kv_uploads),
            resident_reuses: self.resident_reuses.saturating_sub(earlier.resident_reuses),
        }
    }
}

/// Outcome of one input sync: bytes shipped vs the full-tensor baseline.
#[derive(Debug, Clone, Copy)]
pub struct SyncOutcome {
    pub shipped: u64,
    pub full: u64,
}

/// How executable outputs reach the resident device copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApplyMode {
    /// Outputs are applied to the resident copy on-device (they were
    /// produced there); the mirrored host scatter leaves nothing to
    /// re-upload. Used by the sim/virtual transport; the PJRT transport
    /// graduates to this once device-side scatter executables exist.
    Device,
    /// Outputs land only in the host mirror; the scattered rows stay
    /// dirty and re-ship as a delta on the next sync (the stateless-
    /// executable PJRT transport today).
    Host,
}

/// A retained device-side upload: the PJRT buffer plus the backing
/// literal that must outlive it (async H2D copy — see
/// [`crate::runtime::Runtime::upload_tensor`]).
pub struct UploadHandle {
    pub buf: xla::PjRtBuffer,
    pub lit: Option<xla::Literal>,
}

/// Per-kind retained device buffers. An entry is reusable only while the
/// sync planner reports zero dirty rows for the reading slots *and* the
/// derived-input key (gathered layer set, occupancy-mask slot set) still
/// matches what the buffer was built for.
#[derive(Default)]
pub struct ResidentHandles {
    pub kv: Option<UploadHandle>,
    pub kv_sparse: Option<UploadHandle>,
    /// keyed by (indicator name, gathered layers)
    pub ind: Option<(String, Vec<usize>, UploadHandle)>,
    /// keyed by the slot set the occupancy mask was built for
    pub conf: Option<(Vec<usize>, UploadHandle)>,
}

/// The resident-cache layer for one batch group: buffer pool + dirty-
/// delta sync planner + retained device handles + transfer ledger.
pub struct DeviceGroupCaches {
    dims: Dims,
    batch: usize,
    apply: ApplyMode,
    kv_seeded: bool,
    kv_sparse_seeded: bool,
    ind_seeded: BTreeMap<String, bool>,
    conf_seeded: bool,
    /// pooled step-token staging [B, block] (i32); rows outside the
    /// stepped slots keep stale contents — garbage-tolerant by the
    /// row-filtered-merge contract
    pub step_tokens: HostTensor,
    /// pooled prefill-token staging [B, ctx] (i32); only the refreshed
    /// slots' rows are copied per call
    pub prefill_tokens: HostTensor,
    /// pooled gathered-indicator input [n_ind, B, gen, d] (bf16)
    pub ind_gather: HostTensor,
    /// pooled occupancy-masked confidence input [B, gen] (f32)
    pub conf_masked: HostTensor,
    pub handles: ResidentHandles,
    pub stats: TransferStats,
}

impl DeviceGroupCaches {
    pub fn new(dims: &Dims, batch: usize, apply: ApplyMode) -> DeviceGroupCaches {
        DeviceGroupCaches {
            dims: *dims,
            batch,
            apply,
            kv_seeded: false,
            kv_sparse_seeded: false,
            ind_seeded: BTreeMap::new(),
            conf_seeded: false,
            step_tokens: HostTensor::I32 { shape: vec![batch, 0], data: Vec::new() },
            prefill_tokens: HostTensor::I32 {
                shape: vec![batch, dims.ctx],
                data: vec![0i32; batch * dims.ctx],
            },
            ind_gather: HostTensor::Bf16 { shape: Vec::new(), data: Vec::new() },
            conf_masked: HostTensor::F32 {
                shape: vec![batch, dims.gen_len],
                data: vec![-1.0f32; batch * dims.gen_len],
            },
            handles: ResidentHandles::default(),
            stats: TransferStats::default(),
        }
    }

    pub fn apply_mode(&self) -> ApplyMode {
        self.apply
    }

    /// Stage the prefill token upload: copy only the refreshed slots'
    /// context rows into the persistent [B, ctx] buffer (the other rows
    /// are garbage-tolerant — their prefill outputs are discarded by the
    /// row-filtered merges).
    pub fn stage_prefill_tokens(&mut self, tokens: &[i32], slots: &[usize]) -> SyncOutcome {
        let ctx = self.dims.ctx;
        if let HostTensor::I32 { data, .. } = &mut self.prefill_tokens {
            for &b in slots {
                data[b * ctx..(b + 1) * ctx]
                    .copy_from_slice(&tokens[b * ctx..(b + 1) * ctx]);
            }
        }
        let out = SyncOutcome {
            shipped: (slots.len() * ctx * 4) as u64,
            full: (self.batch * ctx * 4) as u64,
        };
        self.stats.record(TransferKind::Tokens, out.shipped, out.full);
        out
    }

    /// Stage the step's block-token input [B, block] for the stepped
    /// slots (reusing the pooled allocation).
    pub fn stage_step_tokens(
        &mut self,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
    ) -> SyncOutcome {
        let ctx = self.dims.ctx;
        let batch = self.batch;
        if let HostTensor::I32 { shape, data } = &mut self.step_tokens {
            shape.clear();
            shape.extend_from_slice(&[batch, block]);
            data.resize(batch * block, 0);
            for &b in slots {
                let src = b * ctx + block_start;
                data[b * block..(b + 1) * block]
                    .copy_from_slice(&tokens[src..src + block]);
            }
        }
        let out = SyncOutcome {
            shipped: (slots.len() * block * 4) as u64,
            full: (batch * block * 4) as u64,
        };
        self.stats.record(TransferKind::Tokens, out.shipped, out.full);
        out
    }

    /// Sync the dense KV input for a step reading `slots`' rows. First
    /// touch seeds the whole tensor; afterwards only rows the host
    /// mutated since the resident copy was refreshed are shipped (and
    /// their dirty bits cleared). In steady state under
    /// [`ApplyMode::Device`] nothing ships.
    pub fn sync_kv(&mut self, caches: &mut GroupCaches, slots: &[usize]) -> SyncOutcome {
        let full = caches.kv_bytes() as u64;
        let row = caches.kv_row_bytes() as u64;
        let shipped = plan_sync(&mut caches.dirty.kv, &mut self.kv_seeded, slots, row, full);
        let out = SyncOutcome { shipped, full };
        self.stats.record(TransferKind::Kv, shipped, full);
        out
    }

    /// Same for the pruned sparse KV input.
    pub fn sync_kv_sparse(
        &mut self,
        caches: &mut GroupCaches,
        slots: &[usize],
    ) -> Result<SyncOutcome> {
        if caches.kv_sparse.is_none() {
            return Err(anyhow!("no sparse cache"));
        }
        let full = caches.kv_sparse_bytes() as u64;
        let row = caches.kv_sparse_row_bytes() as u64;
        let bm = caches
            .dirty
            .kv_sparse
            .as_mut()
            .ok_or_else(|| anyhow!("sparse cache has no dirty bitmap"))?;
        let shipped = plan_sync(bm, &mut self.kv_sparse_seeded, slots, row, full);
        let out = SyncOutcome { shipped, full };
        self.stats.record(TransferKind::KvSparse, shipped, full);
        Ok(out)
    }

    /// Sync accounting for the indicator input of `indicator` over
    /// `layers` (the pooled gather tensor is NOT rebuilt here — callers
    /// stage it via [`GroupCaches::gather_ind_into`] only when they
    /// actually upload, so a reused resident buffer costs zero host
    /// work). The resident model keeps the full per-name cache (all
    /// layers) on device with the layer gather as a device-side op, so:
    /// the seed ships the whole per-name cache, a dirty row re-ships
    /// across **all** layers (the bitmap is layer-collapsed), and the
    /// savings baseline is the gathered tensor the clone-per-step path
    /// used to upload.
    pub fn sync_ind(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        layers: &[usize],
        slots: &[usize],
    ) -> Result<SyncOutcome> {
        let n_ind = layers.len().max(1);
        let per_layer = self.batch * self.dims.gen_len * self.dims.d_model * 2;
        // what the pre-resident path shipped every step (the gather)
        let baseline = (n_ind * per_layer) as u64;
        // what the resident copy holds (every layer of the cache)
        let cache_full = (self.dims.n_layers * per_layer) as u64;
        let row = caches.ind_row_bytes(self.dims.n_layers) as u64;
        if !self.ind_seeded.contains_key(indicator) {
            self.ind_seeded.insert(indicator.to_string(), false);
        }
        let seeded = self.ind_seeded.get_mut(indicator).expect("just inserted");
        let bm = caches
            .dirty
            .ind
            .get_mut(indicator)
            .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?;
        let shipped = plan_sync(bm, seeded, slots, row, cache_full);
        let out = SyncOutcome { shipped, full: baseline };
        self.stats.record(TransferKind::Ind, shipped, baseline);
        Ok(out)
    }

    /// Sync accounting for the confidence input (callers rebuild the
    /// pooled occupancy-masked tensor via
    /// [`GroupCaches::conf_masked_into`] only when they upload).
    /// Confidence rows are host-computed, so the stepped slots' freshly
    /// merged rows ship every tick — but that is `B × gen × 4` bytes,
    /// noise next to the KV tensor this layer keeps resident.
    pub fn sync_conf_masked(
        &mut self,
        caches: &mut GroupCaches,
        slots: &[usize],
    ) -> SyncOutcome {
        let full = (self.batch * self.dims.gen_len * 4) as u64;
        let shipped = plan_sync(&mut caches.dirty.conf, &mut self.conf_seeded, slots, 4, full);
        let out = SyncOutcome { shipped, full };
        self.stats.record(TransferKind::Conf, shipped, full);
        out
    }

    /// Forget everything the device supposedly holds: drop every
    /// retained handle, reset the seeded flags, and mark the entire host
    /// state dirty. Called after a failed upload/execute — the sync
    /// planner cleared bits (a promise that the device copy matches the
    /// host) for a transfer that never completed, so the promise must be
    /// taken back wholesale. The next syncs re-seed, so the ledger stays
    /// conservative (it may double-count the failed step's bytes, never
    /// undercount the re-sync).
    pub fn invalidate(&mut self, caches: &mut GroupCaches) {
        self.kv_seeded = false;
        self.kv_sparse_seeded = false;
        self.ind_seeded.clear();
        self.conf_seeded = false;
        self.handles = ResidentHandles::default();
        for b in 0..self.batch {
            caches.dirty.kv.mark_slot(b);
            for bm in caches.dirty.ind.values_mut() {
                bm.mark_slot(b);
            }
            caches.dirty.conf.mark_slot(b);
            if let Some(bm) = caches.dirty.kv_sparse.as_mut() {
                bm.mark_slot(b);
            }
        }
    }

    /// A step's outputs (KV block + indicator block) were scattered into
    /// the host mirror for `slots`. Under [`ApplyMode::Device`] the same
    /// row-filtered scatter ran on the resident copy (the outputs were
    /// already on device), so those rows are back in sync.
    pub fn note_step_applied(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        sparse: bool,
        block_start: usize,
        block: usize,
        slots: &[usize],
    ) {
        if self.apply != ApplyMode::Device {
            return;
        }
        let g0 = block_start - self.dims.prompt_len;
        for &b in slots {
            if sparse {
                if let (Some(bm), Some(sp)) =
                    (caches.dirty.kv_sparse.as_mut(), caches.kv_sparse.as_ref())
                {
                    let row0 = sp.keep_prompt + g0;
                    bm.clear_range(b, row0, row0 + block);
                }
            } else {
                caches.dirty.kv.clear_range(b, block_start, block_start + block);
            }
            if let Some(bm) = caches.dirty.ind.get_mut(indicator) {
                bm.clear_range(b, g0, g0 + block);
            }
        }
    }

    /// A prefill's outputs (full KV + all indicator caches) were merged
    /// into the host mirror for `slots`; under [`ApplyMode::Device`] the
    /// resident copy received the same row-filtered merge. Confidence
    /// stays dirty (host-computed from the downloaded logits), as does a
    /// sparse rebuild (host-side top-k).
    pub fn note_prefill_applied(&mut self, caches: &mut GroupCaches, slots: &[usize]) {
        if self.apply != ApplyMode::Device {
            return;
        }
        for &b in slots {
            caches.dirty.kv.clear_slot(b);
            for bm in caches.dirty.ind.values_mut() {
                bm.clear_slot(b);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::HostTensor;

    fn dims() -> Dims {
        Dims {
            vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, n_kv_heads: 1,
            d_ff: 8, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
        }
    }

    fn kv_block_tensor(d: &Dims, batch: usize, block: usize) -> HostTensor {
        let n = d.n_layers * 2 * batch * d.n_kv_heads * block * d.head_dim;
        HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, batch, d.n_kv_heads, block, d.head_dim],
            data: vec![1u16; n],
        }
    }

    #[test]
    fn first_sync_seeds_then_device_apply_keeps_kv_clean() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let slots = [0usize, 1];

        let seed = r.sync_kv(&mut c, &slots);
        assert_eq!(seed.shipped, c.kv_bytes() as u64, "first touch ships all");
        assert_eq!(r.stats.full_kv_uploads, 1);

        // a step: scatter outputs (marks), then device-apply (clears)
        let block = 2;
        let t = kv_block_tensor(&d, 2, block);
        c.scatter_kv_block_slots(4, block, &t, &slots).unwrap();
        r.note_step_applied(&mut c, "h", false, 4, block, &slots);
        let steady = r.sync_kv(&mut c, &slots);
        assert_eq!(steady.shipped, 0, "steady state uploads no KV bytes");
        assert_eq!(r.stats.full_kv_uploads, 1, "no further full uploads");
        assert!(r.stats.upload_bytes_saved >= c.kv_bytes() as u64);
        assert_eq!(r.stats.resident_reuses, 1);
    }

    // The Host-apply delta behavior (a step's own scatter re-ships
    // exactly the dirty rows) is asserted end-to-end in
    // tests/transfer_accounting.rs.

    #[test]
    fn admission_reset_dirties_one_slot_and_prefill_apply_clears_it() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        r.sync_kv(&mut c, &[0, 1]);
        let _ = r.sync_ind(&mut c, "h", &[0, 1], &[0, 1]).unwrap();

        c.reset_slot(1); // mid-flight admission
        assert_eq!(c.dirty.kv.count_slot(1), d.ctx);
        assert_eq!(c.dirty.kv.count_slot(0), 0, "exactly one slot dirtied");

        // the admitted slot's grounding prefill regenerates its rows on
        // device — no upload needed
        r.note_prefill_applied(&mut c, &[1]);
        assert_eq!(c.dirty.kv.count_slot(1), 0);
        let after = r.sync_kv(&mut c, &[0, 1]);
        assert_eq!(after.shipped, 0);
    }

    #[test]
    fn pooled_staging_copies_only_requested_rows() {
        let d = dims();
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let mut tokens = vec![0i32; 2 * d.ctx];
        for (i, t) in tokens.iter_mut().enumerate() {
            *t = i as i32;
        }
        let out = r.stage_prefill_tokens(&tokens, &[1]);
        assert_eq!(out.shipped, (d.ctx * 4) as u64);
        assert_eq!(out.full, (2 * d.ctx * 4) as u64);
        let data = r.prefill_tokens.as_i32().unwrap();
        assert_eq!(data[d.ctx], d.ctx as i32, "slot 1 row copied");
        assert_eq!(data[0], 0, "slot 0 row untouched");

        let s = r.stage_step_tokens(&tokens, d.prompt_len, 2, &[0]);
        assert_eq!(s.shipped, 8);
        assert_eq!(r.step_tokens.shape(), &[2, 2]);
        assert_eq!(
            r.step_tokens.as_i32().unwrap()[0],
            d.prompt_len as i32,
            "block tokens staged from block_start"
        );
    }

    #[test]
    fn invalidate_takes_back_the_cleared_bit_promise() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Host);
        r.sync_kv(&mut c, &[0, 1]);
        let _ = r.sync_ind(&mut c, "h", &[0, 1], &[0, 1]).unwrap();
        assert_eq!(c.dirty.kv.count(), 0);

        // a failed upload/execute: the planner's clears must be undone
        r.invalidate(&mut c);
        assert_eq!(c.dirty.kv.count(), 2 * d.ctx, "everything dirty again");
        assert!(r.handles.kv.is_none() && r.handles.ind.is_none());
        let reseed = r.sync_kv(&mut c, &[0, 1]);
        assert_eq!(reseed.shipped, c.kv_bytes() as u64, "next sync re-seeds");
        assert_eq!(r.stats.full_kv_uploads, 2);
    }

    #[test]
    fn transfer_stats_since_is_fieldwise() {
        let mut a = TransferStats::default();
        a.record(TransferKind::Kv, 100, 100);
        let snap = a;
        a.record(TransferKind::Conf, 4, 16);
        a.record(TransferKind::Kv, 0, 100);
        let delta = a.since(&snap);
        assert_eq!(delta.conf_upload_bytes, 4);
        assert_eq!(delta.upload_bytes, 4);
        assert_eq!(delta.upload_bytes_saved, 112);
        assert_eq!(delta.full_kv_uploads, 0);
        assert_eq!(delta.resident_reuses, 1);
    }
}
