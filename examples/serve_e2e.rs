//! End-to-end serving driver (the required full-system validation run):
//! starts the router + HTTP server in-process, replays a Poisson request
//! trace over real HTTP connections, and reports latency percentiles and
//! throughput. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example serve_e2e -- \
//!        [--n 64] [--rate 4] [--clients 8] [--method es]`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use esdllm::batcher::BatcherCfg;
use esdllm::cli::Args;
use esdllm::engine::{EngineCfg, Method};
use esdllm::httpd::Client;
use esdllm::json::{self, Json};
use esdllm::router::{Router, RouterCfg};
use esdllm::runtime::default_artifacts_dir;
use esdllm::server::{serve, ServeCfg};
use esdllm::workload;

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let n = args.usize("n", 64);
    let rate = args.f64("rate", 4.0);
    let n_clients = args.usize("clients", 8);
    let arch = args.str("arch", "llada-nano");
    let method = match args.str("method", "es").as_str() {
        "vanilla" => Method::Vanilla,
        "dual" => Method::DualCache,
        _ => Method::EsDllm,
    };

    println!("== serve_e2e: {arch} / {} / {} requests @ {rate}/s over {n_clients} clients ==",
             method.label(), n);

    let mut router_cfg = RouterCfg::new(EngineCfg::new(&arch, method), default_artifacts_dir());
    router_cfg.batcher = BatcherCfg { max_batch: 8, flush_ms: 30 };
    router_cfg.queue_cap = 512;
    let router = Router::start(router_cfg);
    let server = serve(&ServeCfg::default(), router.clone())?;
    let addr = server.addr;
    println!("server on http://{addr}");

    // build the trace, partitioned round-robin over client threads; each
    // thread replays its share via workload::replay_trace, with a barrier
    // aligning every thread's replay baseline to one instant. Each client
    // blocks on its in-flight request (HTTP is synchronous here), so the
    // offered load is open-loop only up to per-thread head-of-line
    // blocking — raise --clients to approach the generated trace.
    let trace = workload::poisson_trace(rate, n, 0xC11E);
    let t0 = std::time::Instant::now();
    let latencies: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(vec![]));
    let correct = Arc::new(AtomicUsize::new(0));
    let errors = Arc::new(AtomicUsize::new(0));
    let tokens = Arc::new(AtomicUsize::new(0));
    let start = Arc::new(std::sync::Barrier::new(n_clients));

    let threads: Vec<_> = (0..n_clients)
        .map(|c| {
            let share: Vec<workload::TraceRequest> = trace
                .iter()
                .skip(c)
                .step_by(n_clients)
                .cloned()
                .collect();
            let latencies = latencies.clone();
            let correct = correct.clone();
            let errors = errors.clone();
            let tokens = tokens.clone();
            let start = start.clone();
            std::thread::spawn(move || {
                let mut client = Client::new(addr);
                start.wait();
                workload::replay_trace(&share, |req| {
                    let sent = std::time::Instant::now();
                    let body = json::obj(vec![(
                        "prompt",
                        json::s(req.item.prompt.clone()),
                    )])
                    .to_string();
                    match client.post("/generate", body.as_bytes()) {
                        Ok((200, resp)) => {
                            let lat = sent.elapsed().as_secs_f64();
                            latencies.lock().unwrap().push(lat);
                            let j = Json::parse(
                                std::str::from_utf8(&resp).unwrap_or("{}"),
                            )
                            .unwrap_or(Json::Null);
                            if let Some(text) = j.get("text").as_str() {
                                if workload::score(&req.item.answer, text) {
                                    correct.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            // per-request emitted tokens: the EOS guard
                            // retires early, so crediting gen_len per
                            // request would inflate tok/s
                            tokens
                                .fetch_add(j.get("tokens").as_usize().unwrap_or(0), Ordering::Relaxed);
                        }
                        _ => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut lats = latencies.lock().unwrap().clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lats[((lats.len() as f64 - 1.0) * p).round() as usize];
    let ok = lats.len();
    println!("\n== results ==");
    println!("completed      {ok}/{n} (errors {})", errors.load(Ordering::Relaxed));
    println!("wall clock     {wall:.2}s");
    println!("throughput     {:.2} req/s, {:.1} tok/s", ok as f64 / wall,
             tokens.load(Ordering::Relaxed) as f64 / wall);
    if ok > 0 {
        println!("latency p50    {:.3}s", pct(0.5));
        println!("latency p90    {:.3}s", pct(0.9));
        println!("latency p99    {:.3}s", pct(0.99));
    }
    println!("exact match    {}/{ok}", correct.load(Ordering::Relaxed));
    println!("\n== /metrics ==");
    let mut c = Client::new(addr);
    let (_, m) = c.get("/metrics")?;
    println!("{}", String::from_utf8_lossy(&m));
    router.shutdown();
    Ok(())
}
