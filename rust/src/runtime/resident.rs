//! Device-resident group caches: the planning/accounting layer that
//! keeps KV, indicator, and confidence state on the device between
//! scheduler ticks instead of re-shipping it every executable run —
//! and, since the pooled-residency refactor, across batch-class
//! switches and multiple serving workers.
//!
//! The pre-resident step path cloned the entire group KV on the host,
//! uploaded all of it, ran the step, downloaded the block outputs, and
//! scattered them back into host vectors — every tick, for every
//! co-resident slot. Early-skipping reduces FLOPs but none of that byte
//! traffic, which is exactly the measured-speedup gap `perf_hotpath`
//! documents. This module closes it:
//!
//!   * [`DeviceGroupCaches`] owns a **buffer pool** (persistent staging
//!     tensors for step/prefill tokens, the gathered indicator input and
//!     the occupancy-masked confidence input — allocations live for the
//!     backend's lifetime) plus the **retained device handles** for the
//!     big cache inputs, and a [`TransferStats`] ledger;
//!   * every `sync_*` call consults the dirty bitmaps maintained by
//!     [`crate::cache::GroupCaches`] and ships only the rows the host
//!     actually mutated since the resident copy was last refreshed
//!     (delta transfer), clearing the bits it ships;
//!   * [`ApplyMode::Device`] is the device-apply decode path: the
//!     `prefill_apply`/`step_apply` executables scatter their own KV and
//!     indicator updates into the resident cache tensors in-graph
//!     (dynamic-update-slice), compute confidence in-graph from their
//!     logits, and take the occupancy mask as a `batch`-bit input. The
//!     runtime retains those outputs on device
//!     ([`crate::runtime::Runtime::run_retained`]) and the backend
//!     chains them into the next call, so after the one-time seed upload
//!     a steady-state step ships **zero** KV, indicator, and confidence
//!     bytes in either direction — only block tokens (plus the batch-bit
//!     masks) go up. The **downlink is gen-region-sliced**: a grounding
//!     prefill downloads `logits_gen` `[B, gen, V]` (the prompt-region
//!     rows never cross the bus — 60% of the old `[B, ctx, V]` download
//!     at nano geometry), and a step downloads only its selected rows
//!     `[B, k, V]` plus positions. The ledger accounts both directions:
//!     `d2h_bytes_shipped` is what actually came down,
//!     `d2h_bytes_saved` is the reduction vs a full-context
//!     `[B, ctx, V]`-every-run design. The chained inputs are
//!     additionally **donated**: the manifest's alias signatures make
//!     the runtime declare a PJRT input-output alias config at compile
//!     time, so each cache update writes its input buffer in place —
//!     at most one live device copy per chained tensor, with no
//!     transient second allocation during execution (`donated_execs`
//!     counts those runs; the vendored `xla` stub models the allocation
//!     semantics so tests can pin the invariant). Both the PJRT backend
//!     (when the apply executables are compiled) and the deterministic
//!     sim backend run this mode through the same
//!     [`DeviceGroupCaches::sync_prefill_device`] /
//!     [`DeviceGroupCaches::sync_step_device`] /
//!     [`DeviceGroupCaches::sync_step_device_k`] planner, which is how
//!     the two ledgers are kept byte-exact and asserted without
//!     artifacts;
//!   * [`ApplyMode::Host`] is the stateless-executable fallback (sparse
//!     attention, indicator ablations, adaptive skip ratios — variants
//!     without compiled apply executables): outputs land in the host
//!     mirror only, so their rows stay dirty and re-ship as a *delta*
//!     (block rows, not the full tensor) on the next sync. Host-mode
//!     downloads are not planner-mediated, so the D2H ledger counters
//!     stay zero there (the physical `RuntimeStats::download_bytes`
//!     still counts them).
//!
//! In `Host` mode confidence is host-computed (softmax over downloaded
//! logits) and re-ships as a delta; in `Device` mode the host keeps a
//! confidence *mirror* recomputed from the downloaded logit rows (the
//! sampler reads it) but never uploads it — the device copy is advanced
//! in-graph by the same update. The sparse-KV rebuild is host-side top-k
//! in both modes, which is one reason the sparse path stays on `Host`.
//!
//! The host KV/indicator mirrors go stale in `Device` mode (nothing
//! downloads the cache blocks back). That is safe because nothing reads
//! them there — admission resets are regenerated on device by the
//! grounding `prefill_apply` (refresh mask), and
//! [`DeviceGroupCaches::invalidate`] plus the scheduler's eviction path
//! guarantee a failed transfer or an evicted group can never seed a new
//! chain from the stale mirror without a full re-ground.
//!
//! # Fused k-step dispatches
//!
//! A tick is no longer necessarily one execution. When the scheduler's
//! `k` knob is set and the refresh plan gives a run of consecutive
//! ES steps, the backends dispatch one `step_apply_k` executable that
//! unrolls k diffusion iterations in-graph: greedy/threshold unmasking
//! runs *between* inner iterations on device (occupancy-masked argmax
//! commit, confidence recomputed in-graph each iteration), the retained
//! kv/ind/conf chain threads straight through the unrolled body, and
//! only the **final** iteration's selected logit rows plus a per-slot
//! committed-count vector come down the bus. The uplink is the same as
//! a single step — in steady state just the occupancy mask, because
//! `x_tok` rides a **fourth retained chain**: the grounding prefill's
//! token staging doubles as its seed, the unrolled body advances the
//! device copy in-graph, and the `tok` dirty bitmap re-dirties exactly
//! the rows admissions and host-applied commits touch — so a fused
//! dispatch amortizes k − 1 host round-trips away entirely (dInfer's
//! loop-unrolling observation: at small batch the dispatch bubble, not
//! FLOPs, floors TPS).
//! [`DeviceGroupCaches::sync_step_device_k`] is the one copy of the
//! fused accounting (`fused_execs`, `inner_iters_fused`,
//! `dispatches_avoided`, k× `ingraph_conf_steps` and avoided block
//! downloads), shared by the sim and PJRT backends so the fused ledgers
//! stay byte-exact. Chain semantics are unchanged: one retained output
//! set per dispatch, donated in place exactly like single-step.
//!
//! # Pooled residency
//!
//! Chain *ownership* is split out of [`DeviceGroupCaches`] into a
//! [`ResidentChain`]: the host-side **plan** ([`ChainPlan`] — which
//! chains are seeded, per kind) plus the per-worker **device handles**
//! ([`ResidentHandles`] — PJRT buffers, which are not `Send` and
//! therefore never leave the worker thread that uploaded them). Parked
//! plans live in a process-wide [`ResidencyPool`] keyed by
//! `(arch, batch)`:
//!
//!   * a **batch-class switch** (b1 ↔ b8 from queue depth, decided by
//!     the scheduler at block boundaries) parks the outgoing class's
//!     plan and checks the incoming class's plan back out — a checkout
//!     hit means the retained device state is still valid, so the switch
//!     costs **zero full-KV reseed**: only the slots dirtied since the
//!     chain was parked (admission resets, Host-apply scatters) re-ship,
//!     via the existing dirty bitmaps, and under [`ApplyMode::Device`]
//!     even those regenerate on device through the grounding prefill;
//!   * **multi-worker serving** shares one pool behind the non-`Send`
//!     PJRT constraint: a PJRT worker parks under its own owner id (its
//!     buffers are useless to any other thread, so a foreign checkout
//!     misses and seeds its own chain), while the sim backend parks
//!     under the shared owner `None` and so models true cross-worker
//!     device sharing — a second worker checking out a seeded plan
//!     uploads nothing;
//!   * eviction ([`DeviceGroupCaches::invalidate`] via the scheduler's
//!     `evict_all`/`invalidate_resident`) removes the **pooled** entry
//!     too, not just the live chain — a post-eviction checkout must
//!     re-seed, never step against evicted device state.
//!
//! The pool's [`PoolStats`] ledger (`resident_chains`,
//! `chain_switches`, `chain_rebuilds_avoided`, `reseed_bytes_saved`)
//! flows into `/metrics` per scheduler tick, and — like the transfer
//! ledger — is byte-exact between the sim and PJRT planners because
//! both drive the same pool API with the same [`chain_seed_bytes`]
//! accounting.
//!
//! # Cross-request prefix reuse
//!
//! The pool reuses chains across batch-class switches; the
//! [`PrefixCache`] — its process-wide sibling — reuses **prompt-region
//! KV rows across requests**. Admission probes it with the prompt's
//! content tokens before planning the grounding prefill: a hit on the
//! longest block-aligned cached prefix seeds the slot's rows via
//! [`crate::cache::GroupCaches::merge_prefix_rows`] (clone-on-hit, the
//! entry stays cached), leaving only the unshared suffix for the
//! prefill to pay for; retirement offers the slot's own prefix back
//! (insert-on-retire). Keys are `(arch, owner, prefix-token hash)` with
//! the same sim/PJRT owner split as the pool, eviction is LRU against a
//! byte budget, and the [`PrefixStats`] ledger (`prefix_hits`,
//! `prefix_misses`, `prefill_bytes_saved`, `prefix_cache_bytes`,
//! `prefix_evictions`) flows into `/metrics` next to the pool's.
//! Because the payloads are *host* memory — a pure function of the
//! prompt tokens under the deterministic prefill — `evict_all` and
//! fault recovery drop device state without touching this cache, and a
//! prefix-seeded admission decodes token-identically to a full-prefill
//! one.
//!
//! # Faults and the eviction ladder
//!
//! Residency is also where device faults land, and the recovery
//! contract (see [`crate::fault`] and [`crate::router`]) leans on two
//! properties of this module:
//!
//!   * **A faulted run invalidates, never limps.** When an execution or
//!     transfer fails mid-tick, the scheduler calls
//!     `invalidate_resident`, which drops the live chain *and* its
//!     pooled entry. The retained device state may be arbitrarily
//!     corrupt after a failed dispatch; because the host trajectory is
//!     only mutated after a successful downlink, the chain can always
//!     be rebuilt from host truth by a grounding prefill — that
//!     re-ground is what makes transient-fault recovery
//!     token-identical.
//!   * **Allocation pressure degrades before it fails.** An allocation
//!     fault during chain seed/checkout first walks the ladder's
//!     cheapest rung: [`ResidencyPool::evict_lru`] frees the
//!     least-recently-used *parked* plans (live chains are never
//!     victims) and the activation retries. Only an empty pool lets the
//!     error surface to the router, whose ladder continues with fused-k
//!     demotion and, ultimately, `ApplyMode::Host` quarantine. An
//!     evicted chain's next checkout misses and re-seeds exactly the
//!     evicted keys — untouched parked chains still resume for free.
//!
//! # Live-context planning
//!
//! With the scheduler's live-context decoding on (tiered executables
//! compiled at the manifest's `generation.ctx_tiers` key lengths), this
//! layer is also where the **tiered transfer plan** lives.
//! [`DeviceGroupCaches::set_live_ctx`] pins the current tier; every
//! device-apply planner call then prices its uplink against the live
//! row count, not the compiled maximum — `stage_prefill_tokens` ships
//! `live_ctx` token columns per slot, cold chain seeds allocate the
//! tier-shaped kv/ind/conf tensors, and the per-exec ledger charges
//! `batch × live_ctx` live row·ticks against a `batch × ctx` full-row
//! denominator plus an abstract `batch × rows × live-keys` FLOPs
//! estimate ([`TransferStats`]: `live_row_ticks`, `full_row_ticks`,
//! `flops_units`). A step dispatched below the compiled maximum also
//! credits `suffix_blocks_pruned` with the converged suffix blocks it
//! did not attend over, and the scheduler's EOS-guard early exit
//! credits `early_retired_blocks` for trailing blocks that were never
//! dispatched at all. The **block-sliced prefill downlink**
//! ([`DeviceGroupCaches::sync_prefill_device`] with a block window,
//! driven by the backends' `run_prefill_blk`) uplinks one per-slot
//! `blk_start` index vector and downloads `logits_blk` `[B, block, V]`
//! — the active block's rows only, instead of the whole gen region —
//! with the saving credited to `d2h_bytes_saved`. Because the sim and
//! PJRT backends route through these same planner calls, the tiered
//! counters stay byte-exact between them and the ledger-parity tests
//! extend to pruned ticks.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::cache::{DirtyBitmap, GroupCaches};
use crate::manifest::Dims;
use crate::runtime::tensor::HostTensor;

/// The one copy of the sync-planning invariant: an unseeded kind ships
/// its whole resident payload and clears everything; a seeded kind ships
/// (and clears) exactly the dirty rows of the reading slots. Clearing a
/// bit is a promise that the device copy now matches the host — callers
/// that fail to deliver the shipped bytes must
/// [`DeviceGroupCaches::invalidate`] to take the promise back.
fn plan_sync(
    bm: &mut DirtyBitmap,
    seeded: &mut bool,
    slots: &[usize],
    row_bytes: u64,
    seed_bytes: u64,
) -> u64 {
    if !*seeded {
        *seeded = true;
        bm.clear_all();
        seed_bytes
    } else {
        let mut rows = 0usize;
        for &b in slots {
            rows += bm.count_slot(b);
            bm.clear_slot(b);
        }
        rows as u64 * row_bytes
    }
}

/// Which logical input a transfer belongs to (per-kind accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TransferKind {
    Kv,
    KvSparse,
    Ind,
    Conf,
    Tokens,
}

/// Logical host→device transfer ledger. "Logical" bytes are what a
/// delta-capable transport ships; `upload_bytes_saved` is the difference
/// against the clone-and-reupload baseline (the full tensor every call).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TransferStats {
    pub upload_bytes: u64,
    pub upload_bytes_saved: u64,
    pub kv_upload_bytes: u64,
    pub kv_sparse_upload_bytes: u64,
    pub ind_upload_bytes: u64,
    pub conf_upload_bytes: u64,
    pub token_upload_bytes: u64,
    /// syncs that shipped an entire KV tensor (dense or sparse)
    pub full_kv_uploads: u64,
    /// syncs served entirely from the resident copy (zero bytes shipped)
    pub resident_reuses: u64,
    /// executable inputs served by chaining a retained device *output*
    /// (device-apply mode: the tensor never crossed the bus in either
    /// direction — counted per chained input per run)
    pub retained_out_reuses: u64,
    /// D2H bytes avoided by retaining outputs on device instead of
    /// downloading them, vs the Host-apply path's downloads for the same
    /// plan (step: the KV/indicator block slices; prefill: the full KV +
    /// indicator caches)
    pub d2h_bytes_avoided: u64,
    /// runs whose per-token confidence was computed in-graph (no host
    /// conf round-trip in either direction)
    pub ingraph_conf_steps: u64,
    /// sampler-bound D2H bytes a device-apply run actually downloads:
    /// the gen-region logit slice `[B, gen, V]` for a grounding prefill,
    /// the selected rows `[B, k, V]` plus positions for a step
    pub d2h_bytes_shipped: u64,
    /// logit downlink reduction vs the full-context baseline (a design
    /// that downloads `[B, ctx, V]` every run, as the pre-slice
    /// `prefill_apply` and the vanilla forward do):
    /// `B × (ctx − rows_shipped) × V` floats per run
    pub d2h_bytes_saved: u64,
    /// device-apply executions whose chained kv/ind/conf inputs are
    /// donated in place by the compile-time input-output alias config
    /// (one live device copy per chained tensor, no transient second
    /// allocation)
    pub donated_execs: u64,
    /// fused k-step executions (`step_apply_k`): dispatches that ran
    /// k > 1 diffusion iterations in one device execution, unmasking
    /// in-graph between inner iterations
    pub fused_execs: u64,
    /// inner diffusion iterations performed inside those fused
    /// executions (Σ k over fused dispatches)
    pub inner_iters_fused: u64,
    /// device dispatches the fused executions amortized away vs the
    /// one-execution-per-iteration path (k − 1 per fused run)
    pub dispatches_avoided: u64,
    /// live context rows actually computed by device-apply executions:
    /// Σ batch × live_ctx per exec (live_ctx = the context tier the call
    /// ran at; == ctx when untiered)
    pub live_row_ticks: u64,
    /// the full-context baseline for the same executions: Σ batch × ctx
    /// per exec — `live_row_ticks / full_row_ticks` is the steady-state
    /// row (≈ attention-FLOPs) fraction the live-context tiering left
    /// running
    pub full_row_ticks: u64,
    /// attention-FLOPs estimate in abstract units: Σ batch × live_ctx²
    /// per prefill exec, Σ k × batch × block × live_ctx per step exec —
    /// the quadratic/bilinear row products that actually scale with the
    /// live context (weight FLOPs scale with the same row counts)
    pub flops_units: u64,
    /// converged suffix blocks a tiered device-apply step did NOT attend
    /// over: (ctx − live_ctx) / block per step exec
    pub suffix_blocks_pruned: u64,
    /// trailing blocks never decoded because the EOS guard completed the
    /// sequence early (per-request gen_len headroom retired at once)
    pub early_retired_blocks: u64,
}

impl TransferStats {
    pub fn record(&mut self, kind: TransferKind, shipped: u64, full: u64) {
        self.upload_bytes += shipped;
        self.upload_bytes_saved += full.saturating_sub(shipped);
        if shipped == 0 && full > 0 {
            self.resident_reuses += 1;
        }
        match kind {
            TransferKind::Kv => {
                self.kv_upload_bytes += shipped;
                if full > 0 && shipped >= full {
                    self.full_kv_uploads += 1;
                }
            }
            TransferKind::KvSparse => {
                self.kv_sparse_upload_bytes += shipped;
                if full > 0 && shipped >= full {
                    self.full_kv_uploads += 1;
                }
            }
            TransferKind::Ind => self.ind_upload_bytes += shipped,
            TransferKind::Conf => self.conf_upload_bytes += shipped,
            TransferKind::Tokens => self.token_upload_bytes += shipped,
        }
    }

    /// Field-wise accumulate of another ledger (or a ledger delta).
    pub fn merge(&mut self, d: &TransferStats) {
        self.upload_bytes += d.upload_bytes;
        self.upload_bytes_saved += d.upload_bytes_saved;
        self.kv_upload_bytes += d.kv_upload_bytes;
        self.kv_sparse_upload_bytes += d.kv_sparse_upload_bytes;
        self.ind_upload_bytes += d.ind_upload_bytes;
        self.conf_upload_bytes += d.conf_upload_bytes;
        self.token_upload_bytes += d.token_upload_bytes;
        self.full_kv_uploads += d.full_kv_uploads;
        self.resident_reuses += d.resident_reuses;
        self.retained_out_reuses += d.retained_out_reuses;
        self.d2h_bytes_avoided += d.d2h_bytes_avoided;
        self.ingraph_conf_steps += d.ingraph_conf_steps;
        self.d2h_bytes_shipped += d.d2h_bytes_shipped;
        self.d2h_bytes_saved += d.d2h_bytes_saved;
        self.donated_execs += d.donated_execs;
        self.fused_execs += d.fused_execs;
        self.inner_iters_fused += d.inner_iters_fused;
        self.dispatches_avoided += d.dispatches_avoided;
        self.live_row_ticks += d.live_row_ticks;
        self.full_row_ticks += d.full_row_ticks;
        self.flops_units += d.flops_units;
        self.suffix_blocks_pruned += d.suffix_blocks_pruned;
        self.early_retired_blocks += d.early_retired_blocks;
    }

    /// Field-wise delta against an earlier snapshot of the same ledger.
    pub fn since(&self, earlier: &TransferStats) -> TransferStats {
        TransferStats {
            upload_bytes: self.upload_bytes.saturating_sub(earlier.upload_bytes),
            upload_bytes_saved: self
                .upload_bytes_saved
                .saturating_sub(earlier.upload_bytes_saved),
            kv_upload_bytes: self.kv_upload_bytes.saturating_sub(earlier.kv_upload_bytes),
            kv_sparse_upload_bytes: self
                .kv_sparse_upload_bytes
                .saturating_sub(earlier.kv_sparse_upload_bytes),
            ind_upload_bytes: self.ind_upload_bytes.saturating_sub(earlier.ind_upload_bytes),
            conf_upload_bytes: self
                .conf_upload_bytes
                .saturating_sub(earlier.conf_upload_bytes),
            token_upload_bytes: self
                .token_upload_bytes
                .saturating_sub(earlier.token_upload_bytes),
            full_kv_uploads: self.full_kv_uploads.saturating_sub(earlier.full_kv_uploads),
            resident_reuses: self.resident_reuses.saturating_sub(earlier.resident_reuses),
            retained_out_reuses: self
                .retained_out_reuses
                .saturating_sub(earlier.retained_out_reuses),
            d2h_bytes_avoided: self
                .d2h_bytes_avoided
                .saturating_sub(earlier.d2h_bytes_avoided),
            ingraph_conf_steps: self
                .ingraph_conf_steps
                .saturating_sub(earlier.ingraph_conf_steps),
            d2h_bytes_shipped: self
                .d2h_bytes_shipped
                .saturating_sub(earlier.d2h_bytes_shipped),
            d2h_bytes_saved: self
                .d2h_bytes_saved
                .saturating_sub(earlier.d2h_bytes_saved),
            donated_execs: self.donated_execs.saturating_sub(earlier.donated_execs),
            fused_execs: self.fused_execs.saturating_sub(earlier.fused_execs),
            inner_iters_fused: self
                .inner_iters_fused
                .saturating_sub(earlier.inner_iters_fused),
            dispatches_avoided: self
                .dispatches_avoided
                .saturating_sub(earlier.dispatches_avoided),
            live_row_ticks: self.live_row_ticks.saturating_sub(earlier.live_row_ticks),
            full_row_ticks: self.full_row_ticks.saturating_sub(earlier.full_row_ticks),
            flops_units: self.flops_units.saturating_sub(earlier.flops_units),
            suffix_blocks_pruned: self
                .suffix_blocks_pruned
                .saturating_sub(earlier.suffix_blocks_pruned),
            early_retired_blocks: self
                .early_retired_blocks
                .saturating_sub(earlier.early_retired_blocks),
        }
    }
}

/// Outcome of one input sync: bytes shipped vs the full-tensor baseline.
#[derive(Debug, Clone, Copy)]
pub struct SyncOutcome {
    pub shipped: u64,
    pub full: u64,
}

/// How executable outputs reach the resident device copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApplyMode {
    /// The device-apply path: `prefill_apply`/`step_apply` executables
    /// scatter their own updates into the resident cache tensors
    /// in-graph and the runtime retains those outputs for chaining, so
    /// nothing is downloaded and re-shipped. Used by the PJRT backend
    /// whenever the apply executables are compiled, and by the sim
    /// backend by default.
    Device,
    /// Outputs land only in the host mirror; the scattered rows stay
    /// dirty and re-ship as a delta on the next sync (the stateless-
    /// executable fallback: sparse attention, indicator ablations,
    /// adaptive skip ratios).
    Host,
}

/// A retained device-side upload: the PJRT buffer plus the backing
/// literal that must outlive it (async H2D copy — see
/// [`crate::runtime::Runtime::upload_tensor`]).
pub struct UploadHandle {
    pub buf: xla::PjRtBuffer,
    pub lit: Option<xla::Literal>,
}

/// Per-kind retained device buffers. An upload entry is reusable only
/// while the sync planner reports zero dirty rows for the reading slots
/// *and* the derived-input key (gathered layer set, occupancy-mask slot
/// set) still matches what the buffer was built for. The `*_chain`
/// entries are the device-apply output chains: the executable's own
/// retained outputs (or the one-time seed upload), fed straight back as
/// the next call's inputs — replacing a chain entry drops the previous
/// buffer, so device memory stays bounded at one live copy per tensor.
#[derive(Default)]
pub struct ResidentHandles {
    pub kv: Option<UploadHandle>,
    pub kv_sparse: Option<UploadHandle>,
    /// keyed by (indicator name, gathered layers)
    pub ind: Option<(String, Vec<usize>, UploadHandle)>,
    /// keyed by the slot set the occupancy mask was built for
    pub conf: Option<(Vec<usize>, UploadHandle)>,
    /// device-apply chains (ApplyMode::Device): full KV cache, the full
    /// per-name indicator cache, and the confidence state
    pub kv_chain: Option<UploadHandle>,
    pub ind_chain: Option<UploadHandle>,
    pub conf_chain: Option<UploadHandle>,
    /// the fused path's fourth chain: the context-token tensor `x_tok`
    /// (the device advances its own tokens between fused dispatches)
    pub tok_chain: Option<UploadHandle>,
}

/// The host-side half of a retained chain: which per-kind chains are
/// seeded on the device. This is everything a worker needs to resume a
/// parked chain without a full reseed — it is `Send`, so it can cross
/// threads through the [`ResidencyPool`] even though the device buffers
/// themselves cannot.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChainPlan {
    pub kv_seeded: bool,
    pub kv_sparse_seeded: bool,
    pub ind_seeded: BTreeMap<String, bool>,
    pub conf_seeded: bool,
    /// the fused token chain: seeded by the grounding prefill's token
    /// staging (its full context rows ship there anyway), re-dirtied per
    /// row by admissions and host-applied commits
    pub tok_seeded: bool,
}

/// One retained device chain: the parkable [`ChainPlan`] plus the
/// per-worker [`ResidentHandles`] (PJRT buffers — not `Send`, so the
/// handles stay with the worker thread while the plan travels through
/// the pool).
#[derive(Default)]
pub struct ResidentChain {
    pub plan: ChainPlan,
    pub handles: ResidentHandles,
}

/// Bytes a cold chain seed ships for `(dims, batch)`: the full dense KV
/// tensor plus one per-name indicator cache plus the confidence state —
/// what [`ResidencyPool::checkout`] credits to `reseed_bytes_saved`
/// when a parked, seeded chain is reused instead of rebuilt. One copy of
/// the formula, shared by the sim and PJRT backends, keeps the two pool
/// ledgers byte-exact.
pub fn chain_seed_bytes(dims: &Dims, batch: usize) -> u64 {
    let kv = (dims.n_layers * 2 * batch * dims.n_kv_heads * dims.ctx * dims.head_dim * 2) as u64;
    let ind = (dims.n_layers * batch * dims.gen_len * dims.d_model * 2) as u64;
    let conf = (batch * dims.gen_len * 4) as u64;
    kv + ind + conf
}

/// A preemption-ledger event: what happened to a victim sequence's
/// parked slot state. The scheduler reports these through
/// `StepBackend::note_preempt`; the pool keeps the shared ledger so
/// every worker's preemptions land in one place, beside the pooled
/// chains whose park/checkout mechanics make the preemption
/// trajectory-exact in the first place.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptEvent {
    /// a seated sequence was preempted at a block boundary and its
    /// decode state parked
    Parked,
    /// a parked victim was reseated into a free slot
    Resumed,
    /// a parked victim left without resuming (deadline expired while
    /// parked, or an eviction drained it)
    Dropped,
}

/// Cumulative pool ledger, mirrored into `/metrics` each tick.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// chains currently holding device state: checked-out + parked
    pub resident_chains: u64,
    /// batch-class switches the schedulers performed
    pub chain_switches: u64,
    /// checkouts that found a seeded parked chain (a cold rebuild that
    /// did not happen)
    pub chain_rebuilds_avoided: u64,
    /// seed bytes those avoided rebuilds would have shipped
    pub reseed_bytes_saved: u64,
    /// sequences preempted off their slots at block boundaries (total)
    pub preemptions: u64,
    /// preempted sequences reseated after pressure dropped (total)
    pub victim_resumes: u64,
    /// victims currently parked (a gauge: parked − resumed − dropped)
    pub victims_parked: u64,
}

#[derive(Default)]
struct PoolInner {
    /// parked plans keyed by (arch, batch, owner), each stamped with the
    /// monotonic use counter below for LRU eviction. PJRT workers park
    /// under `Some(worker)` — their device buffers are thread-local, so
    /// only they can resume the chain; the sim backend parks under
    /// `None`, modelling true cross-worker device sharing.
    parked: BTreeMap<(String, usize, Option<u64>), (ChainPlan, u64)>,
    /// monotonic use counter: bumped on every park and checkout hit, so
    /// the smallest stamp in `parked` is the least-recently-used entry
    use_clock: u64,
    /// chains currently checked out (live in some worker)
    active: u64,
    switches: u64,
    rebuilds_avoided: u64,
    reseed_bytes_saved: u64,
    /// preemption ledger (see [`PreemptEvent`])
    preemptions: u64,
    victim_resumes: u64,
    victims_parked: u64,
}

/// Process-wide registry of retained device chains, keyed by
/// `(arch, batch)` (+ the owner discriminant above). Workers check
/// chains out when a batch class activates and park them when the
/// scheduler switches away, so batch-shape churn and multi-worker
/// serving reuse device state instead of re-seeding full KV over the
/// bus. Plans are `Send`; the pool never touches a device buffer.
#[derive(Default)]
pub struct ResidencyPool {
    inner: Mutex<PoolInner>,
}

impl ResidencyPool {
    pub fn new() -> Arc<ResidencyPool> {
        Arc::new(ResidencyPool::default())
    }

    /// Resume the parked plan for `(arch, batch, owner)`, if present. A
    /// hit on a *seeded* plan is an avoided cold rebuild: `seed_bytes`
    /// (from [`chain_seed_bytes`]) is credited to the ledger.
    ///
    /// Per-owner entries (`Some(worker)` — PJRT chains, resumable only
    /// by the thread holding their buffers) are checked out exclusively:
    /// the entry moves out of the pool until the next
    /// [`ResidencyPool::park`]. Shared entries (`None` — the sim's
    /// true-sharing device model) record ONE device-resident chain any
    /// worker may use concurrently (per-slot grounding keeps every user
    /// sound), so a shared checkout clones the plan and leaves the entry
    /// parked — once a chain has been parked, any worker resuming that
    /// class hits and never forces a spurious reseed. (Before the first
    /// park there is nothing to share: workers racing to cold-activate
    /// the same class each miss and seed their own chain.)
    pub fn checkout(
        &self,
        arch: &str,
        batch: usize,
        owner: Option<u64>,
        seed_bytes: u64,
    ) -> Option<ChainPlan> {
        let mut g = self.inner.lock().unwrap();
        let key = (arch.to_string(), batch, owner);
        g.use_clock += 1;
        let now = g.use_clock;
        let plan = if owner.is_none() {
            let (plan, stamp) = g.parked.get_mut(&key)?;
            *stamp = now;
            plan.clone()
        } else {
            let (plan, _) = g.parked.remove(&key)?;
            g.active += 1;
            plan
        };
        if plan.kv_seeded {
            g.rebuilds_avoided += 1;
            g.reseed_bytes_saved += seed_bytes;
        }
        Some(plan)
    }

    /// Register a chain built from scratch (a checkout miss) so the
    /// `resident_chains` gauge counts it.
    pub fn register_fresh(&self) {
        self.inner.lock().unwrap().active += 1;
    }

    /// Park a live chain's plan: it stays resident (the worker keeps the
    /// device handles) but is no longer checked out. `was_active` says
    /// whether the caller's activation contributed to the live count —
    /// true after [`ResidencyPool::register_fresh`] or a per-owner
    /// checkout, false after a shared clone-checkout (the entry it
    /// cloned is still counted in the parked registry) — so the gauge
    /// stays balanced whatever order workers park and resume in.
    pub fn park(
        &self,
        arch: &str,
        batch: usize,
        owner: Option<u64>,
        plan: ChainPlan,
        was_active: bool,
    ) {
        let mut g = self.inner.lock().unwrap();
        if was_active {
            g.active = g.active.saturating_sub(1);
        }
        g.use_clock += 1;
        let now = g.use_clock;
        g.parked.insert((arch.to_string(), batch, owner), (plan, now));
    }

    /// Count one scheduler batch-class switch.
    pub fn record_switch(&self) {
        self.inner.lock().unwrap().switches += 1;
    }

    /// Record a preemption-ledger event (the scheduler parked, resumed,
    /// or dropped a victim's slot state).
    pub fn note_victim(&self, ev: PreemptEvent) {
        let mut g = self.inner.lock().unwrap();
        match ev {
            PreemptEvent::Parked => {
                g.preemptions += 1;
                g.victims_parked += 1;
            }
            PreemptEvent::Resumed => {
                g.victim_resumes += 1;
                g.victims_parked = g.victims_parked.saturating_sub(1);
            }
            PreemptEvent::Dropped => {
                g.victims_parked = g.victims_parked.saturating_sub(1);
            }
        }
    }

    /// Drop a chain from the registry entirely — the parked entry if one
    /// exists, and the live count when the caller held the chain checked
    /// out (`was_active`). Called on backend invalidation/eviction so a
    /// later checkout can never resume evicted device state.
    ///
    /// Known shared-model limitation: a sim worker concurrently live on
    /// a clone-checkout of the same shared key does not observe the
    /// eviction — it keeps its seeded plan (and may park it back,
    /// re-recording the chain). That is reachable only through a
    /// backend-error eviction racing another worker in the no-real-
    /// buffers sim model, where "seeded" is ledger accounting rather
    /// than device state; per-owner (PJRT) entries cannot race this way.
    pub fn evict(&self, arch: &str, batch: usize, owner: Option<u64>, was_active: bool) {
        let mut g = self.inner.lock().unwrap();
        g.parked.remove(&(arch.to_string(), batch, owner));
        if was_active {
            g.active = g.active.saturating_sub(1);
        }
    }

    /// Evict up to `n` least-recently-used parked entries (live chains
    /// are never touched — a worker is executing against them) and
    /// return the evicted keys. The degradation ladder's response to an
    /// allocation failure on chain seed/checkout: free parked device
    /// state first, fall back to surfacing the error only when there is
    /// nothing left to free. An evicted chain's next checkout misses and
    /// re-seeds — exactly the evicted keys, nothing else.
    pub fn evict_lru(&self, n: usize) -> Vec<(String, usize, Option<u64>)> {
        let mut g = self.inner.lock().unwrap();
        let mut evicted = Vec::new();
        for _ in 0..n {
            let key = match g
                .parked
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                Some(k) => k,
                None => break,
            };
            g.parked.remove(&key);
            evicted.push(key);
        }
        evicted
    }

    /// Return `n` live-chain counts without touching any parked entry —
    /// the backends' drop path: a worker that exits (or unwinds) frees
    /// its device buffers, so its live chains leave the gauge instead of
    /// inflating `resident_chains` forever (the same leak class the
    /// router's `ActiveSlotsGuard` closes for occupied slots).
    pub fn release(&self, n: u64) {
        let mut g = self.inner.lock().unwrap();
        g.active = g.active.saturating_sub(n);
    }

    pub fn stats(&self) -> PoolStats {
        let g = self.inner.lock().unwrap();
        PoolStats {
            resident_chains: g.active + g.parked.len() as u64,
            chain_switches: g.switches,
            chain_rebuilds_avoided: g.rebuilds_avoided,
            reseed_bytes_saved: g.reseed_bytes_saved,
            preemptions: g.preemptions,
            victim_resumes: g.victim_resumes,
            victims_parked: g.victims_parked,
        }
    }
}

/// Cumulative cross-request prefix-cache ledger, mirrored into
/// `/metrics` each tick and shared (like the [`PoolStats`] ledger) by
/// every worker driving the same [`PrefixCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// admission probes that found a cached block-aligned prefix
    pub prefix_hits: u64,
    /// admission probes that found nothing reusable
    pub prefix_misses: u64,
    /// grounding-prefill KV bytes the hits did not regenerate (prefix
    /// rows × per-row KV bytes, credited at probe time — the one copy of
    /// the formula, so the sim and PJRT ledgers agree byte-exactly)
    pub prefill_bytes_saved: u64,
    /// bytes of prefix payloads currently held (gauge, not a counter)
    pub prefix_cache_bytes: u64,
    /// entries evicted to keep the cache under its byte budget
    pub prefix_evictions: u64,
}

/// FNV-1a over the little-endian bytes of the prefix tokens — the
/// token-hash half of the cache key. Deterministic across runs, workers
/// and processes (no seeded `RandomState`), which the sim-vs-PJRT
/// ledger-parity tests lean on.
fn hash_tokens(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// One cached prefix payload: the prompt-region KV rows
/// ([`crate::cache::GroupCaches::extract_prefix_rows`] layout) plus its
/// byte size and LRU stamp.
struct PrefixEntry {
    rows: Vec<u16>,
    bytes: u64,
    stamp: u64,
}

#[derive(Default)]
struct PrefixInner {
    /// payloads keyed by (arch, owner, prefix length, token hash). The
    /// owner discriminant mirrors the pool's sim/PJRT split: the sim
    /// backend inserts under the shared owner `None` (host payloads are
    /// `Send`, so true cross-worker sharing), a PJRT worker under its
    /// own id — its merged rows must re-sync through that worker's
    /// chain, so foreign hits would mis-credit the ledger (cross-worker
    /// PJRT prefix sharing is a follow-up for real bindings).
    entries: BTreeMap<(String, Option<u64>, usize, u64), PrefixEntry>,
    /// monotonic probe/insert counter; the smallest stamp is the LRU
    use_clock: u64,
    stats: PrefixStats,
}

/// Process-wide cross-request prefix KV cache, the [`ResidencyPool`]'s
/// sibling: where the pool reuses *chains* across batch-class switches,
/// this cache reuses *prompt-region KV rows* across requests. A
/// retiring slot offers its longest block-aligned prompt prefix
/// (insert-on-retire); an admission probes for the longest cached
/// prefix of its own prompt and seeds the slot's rows from the payload
/// (clone-on-hit — the entry stays cached for the next admission)
/// instead of regenerating them in the grounding prefill, which then
/// only has the unshared suffix left to pay for. Trajectory-exactness
/// holds because prefix KV is a pure function of the prompt tokens
/// under the deterministic prefill — seeding equals regenerating.
///
/// Eviction is LRU-by-bytes against a fixed byte budget
/// (`prefix_evictions` counts the victims). Unlike pooled chains, the
/// payloads are host memory: `evict_all`/fault recovery drop device
/// state and re-ground, but never invalidate this cache — the cached
/// rows were never wrong, only the device copies were.
pub struct PrefixCache {
    inner: Mutex<PrefixInner>,
    /// byte budget for cached payloads; inserts past it evict LRU
    budget: u64,
}

impl PrefixCache {
    pub fn new(budget: u64) -> Arc<PrefixCache> {
        Arc::new(PrefixCache { inner: Mutex::new(PrefixInner::default()), budget })
    }

    /// Probe for the longest block-aligned cached prefix of `content`
    /// (the admitted prompt's tokens, padding stripped). A hit stamps
    /// the entry most-recently-used, credits `p × row_bytes` to
    /// `prefill_bytes_saved` (the prompt-region KV regeneration the
    /// suffix-only prefill skips) and returns the prefix length plus a
    /// clone of the payload; a miss — including a sub-block prompt —
    /// counts `prefix_misses`.
    pub fn probe(
        &self,
        arch: &str,
        owner: Option<u64>,
        content: &[i32],
        block: usize,
        row_bytes: u64,
    ) -> Option<(usize, Vec<u16>)> {
        let mut g = self.inner.lock().unwrap();
        g.use_clock += 1;
        let now = g.use_clock;
        if block > 0 {
            let mut p = (content.len() / block) * block;
            while p >= block {
                let key = (arch.to_string(), owner, p, hash_tokens(&content[..p]));
                if let Some(e) = g.entries.get_mut(&key) {
                    e.stamp = now;
                    let rows = e.rows.clone();
                    g.stats.prefix_hits += 1;
                    g.stats.prefill_bytes_saved += p as u64 * row_bytes;
                    return Some((p, rows));
                }
                p -= block;
            }
        }
        g.stats.prefix_misses += 1;
        None
    }

    /// Insert a retiring slot's prefix payload under
    /// `(arch, owner, prefix)`. Re-inserting an existing key replaces
    /// the payload (same prompt prefix ⇒ same rows under the
    /// deterministic prefill, so this is a refresh, not a conflict).
    /// The byte budget is enforced here: least-recently-used entries
    /// are evicted until the cache fits, and a payload no budget could
    /// hold is dropped on the floor rather than evicting everything.
    pub fn insert(&self, arch: &str, owner: Option<u64>, prefix: &[i32], rows: Vec<u16>) {
        let bytes = (rows.len() * 2) as u64;
        if prefix.is_empty() || bytes == 0 || bytes > self.budget {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.use_clock += 1;
        let now = g.use_clock;
        let key = (arch.to_string(), owner, prefix.len(), hash_tokens(prefix));
        if let Some(old) = g.entries.insert(key, PrefixEntry { rows, bytes, stamp: now }) {
            g.stats.prefix_cache_bytes =
                g.stats.prefix_cache_bytes.saturating_sub(old.bytes);
        }
        g.stats.prefix_cache_bytes += bytes;
        // LRU-by-bytes: the just-inserted entry is most-recently-used,
        // so it is never its own victim (oversize payloads are rejected
        // above)
        while g.stats.prefix_cache_bytes > self.budget {
            let victim = match g
                .entries
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            {
                Some(k) => k,
                None => break,
            };
            if let Some(e) = g.entries.remove(&victim) {
                g.stats.prefix_cache_bytes =
                    g.stats.prefix_cache_bytes.saturating_sub(e.bytes);
                g.stats.prefix_evictions += 1;
            }
        }
    }

    pub fn stats(&self) -> PrefixStats {
        self.inner.lock().unwrap().stats
    }
}

/// The resident-cache layer for one batch group: buffer pool + dirty-
/// delta sync planner + the retained [`ResidentChain`] + transfer
/// ledger. The chain's plan half is what parks in the
/// [`ResidencyPool`] across batch-class switches.
pub struct DeviceGroupCaches {
    dims: Dims,
    batch: usize,
    apply: ApplyMode,
    /// whether the chained inputs are donated in place by a compile-time
    /// input-output alias config. Defaults to true under
    /// [`ApplyMode::Device`] (the compile pipeline emits the alias
    /// signatures); the PJRT backend overrides it from the loaded
    /// manifest so `donated_execs` never reports donation an alias-less
    /// artifact set cannot perform.
    donate: bool,
    /// the live context tier this group currently runs at: the absolute
    /// kv length (prompt + live gen rows) the device-apply executables
    /// cover. `dims.ctx` when untiered — every byte formula below
    /// reduces to the pre-tier value then, which is what keeps the
    /// default-off ledger identical. The scheduler steps this down (and
    /// back up) through [`DeviceGroupCaches::set_live_ctx`] as the
    /// group's live frontier moves, re-grounding the group in the same
    /// tick so the chained state is regenerated at the new shape.
    live_ctx: usize,
    /// the retained chain: parkable plan + per-worker device handles
    pub chain: ResidentChain,
    /// pooled step-token staging [B, block] (i32); rows outside the
    /// stepped slots keep stale contents — garbage-tolerant by the
    /// row-filtered-merge contract
    pub step_tokens: HostTensor,
    /// pooled prefill-token staging [B, ctx] (i32); only the refreshed
    /// slots' rows are copied per call
    pub prefill_tokens: HostTensor,
    /// pooled gathered-indicator input [n_ind, B, gen, d] (bf16)
    pub ind_gather: HostTensor,
    /// pooled occupancy-masked confidence input [B, gen] (f32)
    pub conf_masked: HostTensor,
    /// pooled batch-bit occupancy / refresh mask [B] (i32 0/1) — the
    /// device-apply executables take this instead of a host-masked
    /// confidence tensor
    pub occ_mask: HostTensor,
    /// pooled fused-step argmax-cache seed [2, B, block] (i32): row 0
    /// the host logits mirror's argmax with the mask id banned, row 1
    /// with mask + EOS banned. The fused executable chains these caches
    /// in-graph so block positions the skip chain drops in an inner
    /// iteration still commit the token the host sampler would have
    /// picked from its mirror
    pub tok_seed: HostTensor,
    pub stats: TransferStats,
}

impl DeviceGroupCaches {
    pub fn new(dims: &Dims, batch: usize, apply: ApplyMode) -> DeviceGroupCaches {
        Self::with_plan(dims, batch, apply, ChainPlan::default())
    }

    /// Build the resident layer around a plan checked out of the
    /// [`ResidencyPool`]: a seeded plan means the device (shared, for
    /// the sim's true-sharing model) already holds the chain, so the
    /// first sync ships nothing instead of re-seeding.
    pub fn with_plan(
        dims: &Dims,
        batch: usize,
        apply: ApplyMode,
        plan: ChainPlan,
    ) -> DeviceGroupCaches {
        DeviceGroupCaches {
            dims: *dims,
            batch,
            apply,
            donate: apply == ApplyMode::Device,
            live_ctx: dims.ctx,
            chain: ResidentChain { plan, handles: ResidentHandles::default() },
            step_tokens: HostTensor::I32 { shape: vec![batch, 0], data: Vec::new() },
            prefill_tokens: HostTensor::I32 {
                shape: vec![batch, dims.ctx],
                data: vec![0i32; batch * dims.ctx],
            },
            ind_gather: HostTensor::Bf16 { shape: Vec::new(), data: Vec::new() },
            conf_masked: HostTensor::F32 {
                shape: vec![batch, dims.gen_len],
                data: vec![-1.0f32; batch * dims.gen_len],
            },
            occ_mask: HostTensor::I32 { shape: vec![batch], data: vec![0i32; batch] },
            tok_seed: HostTensor::I32 { shape: vec![2, batch, 0], data: Vec::new() },
            stats: TransferStats::default(),
        }
    }

    pub fn apply_mode(&self) -> ApplyMode {
        self.apply
    }

    /// Snapshot the chain's host-side plan for parking in the
    /// [`ResidencyPool`] (the device handles stay with this worker).
    pub fn park_plan(&self) -> ChainPlan {
        self.chain.plan.clone()
    }

    /// Resume a plan checked back out of the pool. The handles this
    /// worker kept across the park line up with the plan by
    /// construction; a worker resuming a plan it never owned (the sim's
    /// shared-device model) simply has no handles to reuse, which the
    /// sim never reads anyway.
    pub fn restore_plan(&mut self, plan: ChainPlan) {
        self.chain.plan = plan;
    }

    /// Override whether the ledger may count executions as donated —
    /// the PJRT backend sets this from the loaded manifest (false when
    /// the apply executables carry no `alias` signatures, so they were
    /// compiled without an input-output alias config and chain by
    /// replace-and-drop instead).
    pub fn set_donation(&mut self, on: bool) {
        self.donate = on;
    }

    pub fn donation(&self) -> bool {
        self.donate
    }

    /// Switch the group to a live-context tier (absolute kv length,
    /// clamped to `[prompt_len + 1, ctx]`). Pure planner state: the
    /// caller owns the re-ground that rebuilds the chained device state
    /// at the new shape (the scheduler forces a full-group grounding
    /// prefill on the tier-change tick, so no stale-shape buffer is ever
    /// executed against).
    pub fn set_live_ctx(&mut self, live_ctx: usize) {
        self.live_ctx = live_ctx.clamp(self.dims.prompt_len + 1, self.dims.ctx);
    }

    pub fn live_ctx(&self) -> usize {
        self.live_ctx
    }

    /// live gen rows at the current tier
    fn gen_live(&self) -> usize {
        self.live_ctx - self.dims.prompt_len
    }

    /// Trailing blocks of a retiring sequence that were never decoded
    /// (EOS-guard completion before its `gen_len`): pure ledger.
    pub fn note_early_retired(&mut self, blocks: u64) {
        self.stats.early_retired_blocks += blocks;
    }

    /// Per-exec live/full row bookkeeping shared by the prefill and step
    /// planners, plus the abstract attention-FLOPs estimate:
    /// `rows_active` is how many query rows the exec computes per batch
    /// row (live context for a prefill, k × block for a step), each
    /// attending over `live_ctx` keys.
    fn account_live_rows(&mut self, rows_active: usize) {
        self.stats.live_row_ticks += (self.batch * self.live_ctx) as u64;
        self.stats.full_row_ticks += (self.batch * self.dims.ctx) as u64;
        self.stats.flops_units += (self.batch * rows_active * self.live_ctx) as u64;
    }

    /// Stage the prefill token upload: copy only the refreshed slots'
    /// context rows into the persistent [B, ctx] buffer (the other rows
    /// are garbage-tolerant — their prefill outputs are discarded by the
    /// row-filtered merges).
    pub fn stage_prefill_tokens(&mut self, tokens: &[i32], slots: &[usize]) -> SyncOutcome {
        let ctx = self.dims.ctx;
        if let HostTensor::I32 { data, .. } = &mut self.prefill_tokens {
            for &b in slots {
                data[b * ctx..(b + 1) * ctx]
                    .copy_from_slice(&tokens[b * ctx..(b + 1) * ctx]);
            }
        }
        // a tiered executable's token input covers live rows only (the
        // pooled staging keeps full rows; the upload slices)
        let out = SyncOutcome {
            shipped: (slots.len() * self.live_ctx * 4) as u64,
            full: (self.batch * self.live_ctx * 4) as u64,
        };
        self.stats.record(TransferKind::Tokens, out.shipped, out.full);
        out
    }

    /// Copy the stepped slots' block-token rows into the pooled
    /// [B, block] staging buffer without touching the ledger — the fused
    /// planner accounts its token traffic through the chained-tok bitmap
    /// instead of a per-dispatch upload.
    fn copy_step_tokens(
        &mut self,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
    ) {
        let ctx = self.dims.ctx;
        let batch = self.batch;
        if let HostTensor::I32 { shape, data } = &mut self.step_tokens {
            shape.clear();
            shape.extend_from_slice(&[batch, block]);
            data.resize(batch * block, 0);
            for &b in slots {
                let src = b * ctx + block_start;
                data[b * block..(b + 1) * block]
                    .copy_from_slice(&tokens[src..src + block]);
            }
        }
    }

    /// Stage the step's block-token input [B, block] for the stepped
    /// slots (reusing the pooled allocation).
    pub fn stage_step_tokens(
        &mut self,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
    ) -> SyncOutcome {
        self.copy_step_tokens(tokens, block_start, block, slots);
        let out = SyncOutcome {
            shipped: (slots.len() * block * 4) as u64,
            full: (self.batch * block * 4) as u64,
        };
        self.stats.record(TransferKind::Tokens, out.shipped, out.full);
        out
    }

    /// Stage the fused step's argmax-cache seed [2, B, block] (i32) from
    /// the host logits mirror: for each block position of the stepped
    /// slots, the argmax with the mask id banned (row 0) and with mask +
    /// EOS banned (row 1) — first max on ties, the same convention as
    /// the host sampler's `argmax` and the executable's in-graph argmax.
    /// No ledger entry here: under the chained-token transport the
    /// planner (`sync_step_device_k`) models the argmax caches as
    /// device-derived from resident state, so the seed costs no logical
    /// bytes — this staging only feeds the current executable
    /// generation's `tok_seed` input, and the sim never materializes it.
    #[allow(clippy::too_many_arguments)]
    pub fn stage_tok_seed(
        &mut self,
        caches: &GroupCaches,
        block_start: usize,
        block: usize,
        slots: &[usize],
        mask_id: i32,
        eos_id: i32,
    ) {
        let batch = self.batch;
        let gen = self.dims.gen_len;
        let vocab = self.dims.vocab;
        let g0 = block_start - self.dims.prompt_len;
        if let HostTensor::I32 { shape, data } = &mut self.tok_seed {
            shape.clear();
            shape.extend_from_slice(&[2, batch, block]);
            data.resize(2 * batch * block, 0);
            for &b in slots {
                for j in 0..block {
                    let row = (b * gen + g0 + j) * vocab;
                    let lg = &caches.logits[row..row + vocab];
                    let (mut hat, mut hat_v) = (0usize, f32::NEG_INFINITY);
                    let (mut noe, mut noe_v) = (0usize, f32::NEG_INFINITY);
                    for (t, &v) in lg.iter().enumerate() {
                        if t as i32 == mask_id {
                            continue;
                        }
                        if v > hat_v {
                            hat = t;
                            hat_v = v;
                        }
                        if t as i32 != eos_id && v > noe_v {
                            noe = t;
                            noe_v = v;
                        }
                    }
                    data[b * block + j] = hat as i32;
                    data[(batch + b) * block + j] = noe as i32;
                }
            }
        }
    }

    /// Sync the dense KV input for a step reading `slots`' rows. First
    /// touch seeds the whole tensor; afterwards only rows the host
    /// mutated since the resident copy was refreshed are shipped (and
    /// their dirty bits cleared). In steady state under
    /// [`ApplyMode::Device`] nothing ships.
    pub fn sync_kv(&mut self, caches: &mut GroupCaches, slots: &[usize]) -> SyncOutcome {
        let full = caches.kv_bytes() as u64;
        let row = caches.kv_row_bytes() as u64;
        let shipped = plan_sync(&mut caches.dirty.kv, &mut self.chain.plan.kv_seeded, slots, row, full);
        let out = SyncOutcome { shipped, full };
        self.stats.record(TransferKind::Kv, shipped, full);
        out
    }

    /// Same for the pruned sparse KV input.
    pub fn sync_kv_sparse(
        &mut self,
        caches: &mut GroupCaches,
        slots: &[usize],
    ) -> Result<SyncOutcome> {
        if caches.kv_sparse.is_none() {
            return Err(anyhow!("no sparse cache"));
        }
        let full = caches.kv_sparse_bytes() as u64;
        let row = caches.kv_sparse_row_bytes() as u64;
        let bm = caches
            .dirty
            .kv_sparse
            .as_mut()
            .ok_or_else(|| anyhow!("sparse cache has no dirty bitmap"))?;
        let shipped = plan_sync(bm, &mut self.chain.plan.kv_sparse_seeded, slots, row, full);
        let out = SyncOutcome { shipped, full };
        self.stats.record(TransferKind::KvSparse, shipped, full);
        Ok(out)
    }

    /// Sync accounting for the indicator input of `indicator` over
    /// `layers` (the pooled gather tensor is NOT rebuilt here — callers
    /// stage it via [`GroupCaches::gather_ind_into`] only when they
    /// actually upload, so a reused resident buffer costs zero host
    /// work). The resident model keeps the full per-name cache (all
    /// layers) on device with the layer gather as a device-side op, so:
    /// the seed ships the whole per-name cache, a dirty row re-ships
    /// across **all** layers (the bitmap is layer-collapsed), and the
    /// savings baseline is the gathered tensor the clone-per-step path
    /// used to upload.
    pub fn sync_ind(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        layers: &[usize],
        slots: &[usize],
    ) -> Result<SyncOutcome> {
        let n_ind = layers.len().max(1);
        let per_layer = self.batch * self.dims.gen_len * self.dims.d_model * 2;
        // what the pre-resident path shipped every step (the gather)
        let baseline = (n_ind * per_layer) as u64;
        // what the resident copy holds (every layer of the cache)
        let cache_full = (self.dims.n_layers * per_layer) as u64;
        let row = caches.ind_row_bytes(self.dims.n_layers) as u64;
        if !self.chain.plan.ind_seeded.contains_key(indicator) {
            self.chain.plan.ind_seeded.insert(indicator.to_string(), false);
        }
        let seeded = self.chain.plan.ind_seeded.get_mut(indicator).expect("just inserted");
        let bm = caches
            .dirty
            .ind
            .get_mut(indicator)
            .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?;
        let shipped = plan_sync(bm, seeded, slots, row, cache_full);
        let out = SyncOutcome { shipped, full: baseline };
        self.stats.record(TransferKind::Ind, shipped, baseline);
        Ok(out)
    }

    /// Sync accounting for the confidence input (callers rebuild the
    /// pooled occupancy-masked tensor via
    /// [`GroupCaches::conf_masked_into`] only when they upload).
    /// Confidence rows are host-computed, so the stepped slots' freshly
    /// merged rows ship every tick — but that is `B × gen × 4` bytes,
    /// noise next to the KV tensor this layer keeps resident.
    pub fn sync_conf_masked(
        &mut self,
        caches: &mut GroupCaches,
        slots: &[usize],
    ) -> SyncOutcome {
        let full = (self.batch * self.dims.gen_len * 4) as u64;
        let shipped = plan_sync(&mut caches.dirty.conf, &mut self.chain.plan.conf_seeded, slots, 4, full);
        let out = SyncOutcome { shipped, full };
        self.stats.record(TransferKind::Conf, shipped, full);
        out
    }

    // -- device-apply planner (ApplyMode::Device) ---------------------------
    //
    // Both backends route their Device-mode ticks through the two
    // composite syncs below, so the PJRT planner and the sim planner
    // produce identical `TransferStats` by construction (asserted in
    // tests/transfer_accounting.rs).

    /// Bytes of the full per-name indicator cache (the device-apply
    /// chain keeps every layer resident; the gather is in-graph).
    fn ind_cache_bytes(&self) -> u64 {
        (self.dims.n_layers * self.batch * self.dims.gen_len * self.dims.d_model * 2) as u64
    }

    /// Bytes of the confidence state tensor.
    fn conf_bytes(&self) -> u64 {
        (self.batch * self.dims.gen_len * 4) as u64
    }

    // Live-tier byte sizes of the chained tensors: what a cold seed (or
    // an avoided download) physically measures at the current context
    // tier. Equal to the full sizes when untiered.
    fn kv_live_bytes(&self) -> u64 {
        (self.dims.n_layers * 2 * self.batch * self.dims.n_kv_heads
            * self.live_ctx * self.dims.head_dim * 2) as u64
    }

    fn ind_live_bytes(&self) -> u64 {
        (self.dims.n_layers * self.batch * self.gen_live() * self.dims.d_model * 2) as u64
    }

    fn conf_live_bytes(&self) -> u64 {
        (self.batch * self.gen_live() * 4) as u64
    }

    /// The one copy of the gen-region downlink accounting: a device-apply
    /// run downloads `rows` logit rows (f32) plus, when `with_pos`, their
    /// i32 positions; the savings baseline is the full-context
    /// `[B, ctx, V]` logit download the pre-slice executables shipped.
    /// Counts the run as donated only when the executables were compiled
    /// with the input-output alias config ([`DeviceGroupCaches::set_donation`]
    /// — an alias-less artifact set chains by replace-and-drop and must
    /// not report in-place updates it cannot perform).
    fn account_d2h_logits(&mut self, rows: usize, with_pos: bool) {
        let row_bytes = (self.batch * self.dims.vocab * 4) as u64;
        let shipped =
            rows as u64 * row_bytes + if with_pos { (self.batch * rows * 4) as u64 } else { 0 };
        let full_ctx = self.dims.ctx as u64 * row_bytes;
        self.stats.d2h_bytes_shipped += shipped;
        self.stats.d2h_bytes_saved += full_ctx.saturating_sub(rows as u64 * row_bytes);
        if self.donate {
            self.stats.donated_execs += 1;
        }
    }

    /// Stage the batch-bit occupancy / refresh mask for `slots` into the
    /// pooled [B] i32 buffer. The mask rides up with the tokens (B × 4
    /// bytes — this is what replaces the host-masked confidence upload).
    pub fn stage_occ_mask(&mut self, slots: &[usize]) -> SyncOutcome {
        if let HostTensor::I32 { data, .. } = &mut self.occ_mask {
            data.iter_mut().for_each(|v| *v = 0);
            for &b in slots {
                data[b] = 1;
            }
        }
        let bytes = (self.batch * 4) as u64;
        let out = SyncOutcome { shipped: bytes, full: bytes };
        self.stats.record(TransferKind::Tokens, bytes, bytes);
        out
    }

    /// Input sync for one device-apply prefill refreshing `slots`:
    /// stages the token rows and the refresh mask, then seeds or chains
    /// the kv/ind/conf resident tensors. The first touch ships the whole
    /// host tensors (the physical upload that opens the chain — the
    /// residency seed); every later call feeds back the executable's own
    /// retained outputs for zero bytes. Downlink: the run downloads only
    /// the gen-region logit slice (`logits_gen`, `B × gen × V` floats,
    /// counted in `d2h_bytes_shipped` with the `B × prompt × V` slice
    /// saving in `d2h_bytes_saved`), and the D2H bytes the retained
    /// cache outputs avoid vs the Host-apply prefill's cache downloads
    /// land in `d2h_bytes_avoided`.
    pub fn sync_prefill_device(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        tokens: &[i32],
        slots: &[usize],
    ) -> Result<()> {
        self.sync_prefill_device_inner(caches, indicator, tokens, slots, None)
    }

    /// Input sync for one **block-sliced** device-apply prefill
    /// (`prefill_apply_blk*`): identical chaining to
    /// [`DeviceGroupCaches::sync_prefill_device`], but the executable
    /// takes a per-slot block-index input (`blk_start`, `B × 4` bytes of
    /// extra uplink) and downloads only each slot's current `[B, block,
    /// V]` logit window instead of the whole gen region — `block /
    /// gen_live` of the grounding-prefill downlink.
    pub fn sync_prefill_device_blk(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        tokens: &[i32],
        slots: &[usize],
        block: usize,
    ) -> Result<()> {
        self.sync_prefill_device_inner(caches, indicator, tokens, slots, Some(block))
    }

    fn sync_prefill_device_inner(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        tokens: &[i32],
        slots: &[usize],
        blk: Option<usize>,
    ) -> Result<()> {
        if self.apply != ApplyMode::Device {
            return Err(anyhow!("sync_prefill_device requires ApplyMode::Device"));
        }
        self.stage_prefill_tokens(tokens, slots);
        self.stage_occ_mask(slots);
        if blk.is_some() {
            // the per-slot block-start vector rides up with the mask
            let bytes = (self.batch * 4) as u64;
            self.stats.record(TransferKind::Tokens, bytes, bytes);
        }
        // the prefill's token rows double as the x_tok chain seed: the
        // refreshed slots' full context rows just shipped (accounted by
        // the staging above), so their chained device tokens match the
        // host again and a following fused run chains them for free
        self.chain.plan.tok_seeded = true;
        for &b in slots {
            caches.dirty.tok.clear_slot(b);
        }
        let kv_full = caches.kv_bytes() as u64;
        if !self.chain.plan.kv_seeded {
            self.chain.plan.kv_seeded = true;
            caches.dirty.kv.clear_all();
            // a cold seed ships the chained tensor at its LIVE shape —
            // a tiered group's device KV simply has no pruned rows
            self.stats.record(TransferKind::Kv, self.kv_live_bytes(), kv_full);
        } else {
            self.stats.record(TransferKind::Kv, 0, kv_full);
            self.stats.retained_out_reuses += 1;
        }
        let ind_full = self.ind_cache_bytes();
        if !self.chain.plan.ind_seeded.contains_key(indicator) {
            self.chain.plan.ind_seeded.insert(indicator.to_string(), false);
        }
        let seeded = self.chain.plan.ind_seeded.get_mut(indicator).expect("just inserted");
        if !*seeded {
            *seeded = true;
            caches
                .dirty
                .ind
                .get_mut(indicator)
                .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?
                .clear_all();
            self.stats.record(TransferKind::Ind, self.ind_live_bytes(), ind_full);
        } else {
            self.stats.record(TransferKind::Ind, 0, ind_full);
            self.stats.retained_out_reuses += 1;
        }
        let conf_full = self.conf_bytes();
        if !self.chain.plan.conf_seeded {
            self.chain.plan.conf_seeded = true;
            self.stats.record(TransferKind::Conf, self.conf_live_bytes(), conf_full);
        } else {
            self.stats.record(TransferKind::Conf, 0, conf_full);
            self.stats.retained_out_reuses += 1;
        }
        // the Host-apply prefill downloads the full KV plus every
        // indicator cache to refresh the host mirrors; this plan retains
        // them on device instead (confidence is NOT counted: the Host
        // path computes it from logits, which both paths download) —
        // measured at the live tier, since that is the shape the Host
        // path would have downloaded for the same executables
        self.stats.d2h_bytes_avoided += self.kv_live_bytes()
            + crate::cache::INDICATORS.len() as u64 * self.ind_live_bytes();
        // the downlink is the live gen-region logit slice (no positions:
        // a prefill refreshes every live gen row) — or, block-sliced,
        // each slot's current block window only
        self.account_d2h_logits(blk.unwrap_or_else(|| self.gen_live()), false);
        // a prefill computes every live context row once
        self.account_live_rows(self.live_ctx);
        Ok(())
    }

    /// Input sync for one device-apply step over `block` positions at
    /// `block_start` for `slots`: token rows and the occupancy mask ship;
    /// the kv/ind/conf inputs chain the previous call's retained outputs
    /// (zero bytes, donated in place by the alias config); confidence is
    /// computed in-graph. `n_ind` is the number of indicator layers the
    /// equivalent Host-apply step would have downloaded in its
    /// `ind_block` output (the exe's maintained layers — skip layers for
    /// ES, every layer for dual), used only for the honest
    /// `d2h_bytes_avoided` baseline. `n_sel` is the number of selected
    /// logit rows the executable returns (`final_keep` — the full block
    /// for a dual step, the surviving positions for an ES step): the
    /// run's downlink is `B × n_sel × V` logit floats plus `B × n_sel`
    /// i32 positions, counted in `d2h_bytes_shipped`. Errors if the
    /// chain has not been seeded (a step before any grounding prefill)
    /// or if the stepped slots' rows are host-divergent — the transport
    /// has no partial write into a retained buffer, so such a step would
    /// silently execute against stale cache rows.
    #[allow(clippy::too_many_arguments)]
    pub fn sync_step_device(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        n_ind: usize,
        n_sel: usize,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
    ) -> Result<()> {
        self.sync_step_device_inner(
            caches,
            indicator,
            n_ind,
            n_sel,
            1,
            tokens,
            block_start,
            block,
            slots,
        )
    }

    /// Input sync for one **fused** device-apply step (`step_apply_k`):
    /// one dispatch that runs `k` diffusion iterations in-graph, with
    /// greedy unmasking between inner iterations (the host sampler rule
    /// replicated in-graph, EOS guard included), over the chained
    /// kv/ind/conf tensors **plus the fourth chain, `x_tok`**: the token
    /// tensor stays device-resident across fused dispatches, so the
    /// steady-state uplink is the batch-bit occupancy mask alone — token
    /// rows ship only when the host diverged them (an admission reset,
    /// or a host-applied commit from an unfused step), via the `tok`
    /// dirty bitmap. Downlink is the **final** iteration's selected
    /// logit rows plus positions, the per-iteration committed positions
    /// and tokens (`commit_pos`/`commit_tok`, `2 × B × k × 4` bytes —
    /// the host applies these directly instead of replaying decisions),
    /// and the per-slot committed-count audit vector (`B × 4` bytes).
    /// Confidence is computed in-graph `k` times, the equivalent of `k`
    /// Host-apply block downloads is avoided, and the fused ledger
    /// records one `fused_execs`, `k` `inner_iters_fused`, and `k − 1`
    /// `dispatches_avoided`. Both backends route their fused ticks
    /// through this one planner, which is what keeps the sim and PJRT
    /// fused ledgers byte-exact.
    #[allow(clippy::too_many_arguments)]
    pub fn sync_step_device_k(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        n_ind: usize,
        n_sel: usize,
        k: usize,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
    ) -> Result<()> {
        if k < 2 {
            return Err(anyhow!(
                "fused device-apply step with k = {k}; a depth-1 run is \
                 sync_step_device"
            ));
        }
        self.sync_step_device_inner(
            caches,
            indicator,
            n_ind,
            n_sel,
            k,
            tokens,
            block_start,
            block,
            slots,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn sync_step_device_inner(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        n_ind: usize,
        n_sel: usize,
        k: usize,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
    ) -> Result<()> {
        if self.apply != ApplyMode::Device {
            return Err(anyhow!("sync_step_device requires ApplyMode::Device"));
        }
        if !self.chain.plan.kv_seeded || !self.chain.plan.conf_seeded {
            return Err(anyhow!(
                "device-apply step before the seeding prefill (cache chain missing)"
            ));
        }
        let ind_bm = caches
            .dirty
            .ind
            .get(indicator)
            .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?;
        for &b in slots {
            let kv_dirty = caches.dirty.kv.count_slot(b);
            let ind_dirty = ind_bm.count_slot(b);
            if kv_dirty > 0 || ind_dirty > 0 {
                return Err(anyhow!(
                    "device-apply step on slot {b} with {kv_dirty} host-dirty KV \
                     rows and {ind_dirty} indicator rows the chained transport \
                     cannot deliver; ground the slot with a prefill first"
                ));
            }
        }
        if k > 1 {
            // x_tok rides the fourth retained chain: the grounding
            // prefill's token staging seeded it, admissions and
            // host-applied commits re-dirty exactly the rows they
            // rewrote, and the device advances its own tokens (and
            // argmax caches) in-graph between and across fused
            // dispatches — so a steady-state fused run ships ZERO token
            // bytes and only the batch-bit occupancy mask rides up. The
            // pooled staging below still feeds the current executable
            // generation's x_tok/tok_seed inputs; the planner models the
            // chained transport.
            self.copy_step_tokens(tokens, block_start, block, slots);
            let tok_full = (self.batch * self.live_ctx * 4) as u64;
            let shipped = plan_sync(
                &mut caches.dirty.tok,
                &mut self.chain.plan.tok_seeded,
                slots,
                4,
                tok_full,
            );
            self.stats
                .record(TransferKind::Tokens, shipped, (self.batch * block * 4) as u64);
            if shipped == 0 {
                self.stats.retained_out_reuses += 1;
            }
        } else {
            self.stage_step_tokens(tokens, block_start, block, slots);
            // the host sampler will commit this step's unmask decisions
            // into the token rows, diverging them from the chained
            // device copy the next fused dispatch would read
            for &b in slots {
                caches.dirty.tok.mark_range(b, block_start, block_start + block);
            }
        }
        self.stage_occ_mask(slots);
        let kv_full = caches.kv_bytes() as u64;
        let ind_full = self.ind_cache_bytes();
        let conf_full = self.conf_bytes();
        self.stats.record(TransferKind::Kv, 0, kv_full);
        self.stats.record(TransferKind::Ind, 0, ind_full);
        self.stats.record(TransferKind::Conf, 0, conf_full);
        self.stats.retained_out_reuses += 3;
        // confidence is recomputed in-graph at every inner iteration
        self.stats.ingraph_conf_steps += k as u64;
        // the Host-apply step downloads the KV block slice plus the
        // maintained layers' indicator block slice for the host scatter —
        // once per iteration; this plan retains the whole updated caches
        // on device across all k inner iterations instead
        let kv_block = (self.batch * block * caches.kv_row_bytes()) as u64;
        let ind_block = (n_ind * self.batch * block * self.dims.d_model * 2) as u64;
        self.stats.d2h_bytes_avoided += k as u64 * (kv_block + ind_block);
        // the downlink is the FINAL iteration's selected logit rows +
        // their positions (intermediate iterations never touch the bus)
        self.account_d2h_logits(n_sel, true);
        // each of the k inner iterations computes `block` query rows
        // over the live context; the converged suffix blocks past the
        // tier are the rows a full-context step would have attended over
        self.account_live_rows(k * block);
        if self.live_ctx < self.dims.ctx {
            self.stats.suffix_blocks_pruned +=
                ((self.dims.ctx - self.live_ctx) / block) as u64;
        }
        if k > 1 {
            // downlinked: the per-iteration committed positions and
            // tokens [B, k] i32 each (applied directly by the host) and
            // the per-slot committed-count audit vector. The argmax-
            // cache seed no longer ships: with the token tensor chained
            // the device derives its argmax caches from its own resident
            // logits, so rows the skip chain drops mid-run still commit
            // the token the host mirror would have picked
            self.stats.d2h_bytes_shipped += (2 * self.batch * k * 4) as u64;
            self.stats.d2h_bytes_shipped += (self.batch * 4) as u64;
            self.stats.fused_execs += 1;
            self.stats.inner_iters_fused += k as u64;
            self.stats.dispatches_avoided += (k - 1) as u64;
        }
        Ok(())
    }

    /// Forget everything the device supposedly holds: drop every
    /// retained handle, reset the seeded flags, and mark the entire host
    /// state dirty. Called after a failed upload/execute — the sync
    /// planner cleared bits (a promise that the device copy matches the
    /// host) for a transfer that never completed, so the promise must be
    /// taken back wholesale. The next syncs re-seed, so the ledger stays
    /// conservative (it may double-count the failed step's bytes, never
    /// undercount the re-sync).
    pub fn invalidate(&mut self, caches: &mut GroupCaches) {
        self.chain = ResidentChain::default();
        caches.dirty.mark_all();
    }

    /// A step's outputs (KV block + indicator block) were scattered into
    /// the host mirror for `slots`. Under [`ApplyMode::Device`] the same
    /// row-filtered scatter ran on the resident copy (the outputs were
    /// already on device), so those rows are back in sync.
    pub fn note_step_applied(
        &mut self,
        caches: &mut GroupCaches,
        indicator: &str,
        sparse: bool,
        block_start: usize,
        block: usize,
        slots: &[usize],
    ) {
        if self.apply != ApplyMode::Device {
            return;
        }
        let g0 = block_start - self.dims.prompt_len;
        for &b in slots {
            if sparse {
                if let (Some(bm), Some(sp)) =
                    (caches.dirty.kv_sparse.as_mut(), caches.kv_sparse.as_ref())
                {
                    let row0 = sp.keep_prompt + g0;
                    bm.clear_range(b, row0, row0 + block);
                }
            } else {
                caches.dirty.kv.clear_range(b, block_start, block_start + block);
            }
            if let Some(bm) = caches.dirty.ind.get_mut(indicator) {
                bm.clear_range(b, g0, g0 + block);
            }
            // the step merged its confidence in-graph over the same
            // block window; the host mirror applies the identical update
            // from the downloaded logit rows
            caches.dirty.conf.clear_range(b, g0, g0 + block);
        }
    }

    /// A prefill's outputs were merged into the host mirror for `slots`;
    /// under [`ApplyMode::Device`] the resident copy received the same
    /// row-filtered merge in-graph (including the in-graph confidence
    /// refresh). A sparse rebuild stays dirty (host-side top-k — the
    /// sparse path runs in `Host` mode).
    pub fn note_prefill_applied(&mut self, caches: &mut GroupCaches, slots: &[usize]) {
        if self.apply != ApplyMode::Device {
            return;
        }
        for &b in slots {
            caches.dirty.kv.clear_slot(b);
            for bm in caches.dirty.ind.values_mut() {
                bm.clear_slot(b);
            }
            caches.dirty.conf.clear_slot(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::HostTensor;

    fn dims() -> Dims {
        Dims {
            vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, n_kv_heads: 1,
            d_ff: 8, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
        }
    }

    fn kv_block_tensor(d: &Dims, batch: usize, block: usize) -> HostTensor {
        let n = d.n_layers * 2 * batch * d.n_kv_heads * block * d.head_dim;
        HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, batch, d.n_kv_heads, block, d.head_dim],
            data: vec![1u16; n],
        }
    }

    #[test]
    fn first_sync_seeds_then_device_apply_keeps_kv_clean() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let slots = [0usize, 1];

        let seed = r.sync_kv(&mut c, &slots);
        assert_eq!(seed.shipped, c.kv_bytes() as u64, "first touch ships all");
        assert_eq!(r.stats.full_kv_uploads, 1);

        // a step: scatter outputs (marks), then device-apply (clears)
        let block = 2;
        let t = kv_block_tensor(&d, 2, block);
        c.scatter_kv_block_slots(4, block, &t, &slots).unwrap();
        r.note_step_applied(&mut c, "h", false, 4, block, &slots);
        let steady = r.sync_kv(&mut c, &slots);
        assert_eq!(steady.shipped, 0, "steady state uploads no KV bytes");
        assert_eq!(r.stats.full_kv_uploads, 1, "no further full uploads");
        assert!(r.stats.upload_bytes_saved >= c.kv_bytes() as u64);
        assert_eq!(r.stats.resident_reuses, 1);
    }

    // The Host-apply delta behavior (a step's own scatter re-ships
    // exactly the dirty rows) is asserted end-to-end in
    // tests/transfer_accounting.rs.

    #[test]
    fn admission_reset_dirties_one_slot_and_prefill_apply_clears_it() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        r.sync_kv(&mut c, &[0, 1]);
        let _ = r.sync_ind(&mut c, "h", &[0, 1], &[0, 1]).unwrap();

        c.reset_slot(1); // mid-flight admission
        assert_eq!(c.dirty.kv.count_slot(1), d.ctx);
        assert_eq!(c.dirty.kv.count_slot(0), 0, "exactly one slot dirtied");

        // the admitted slot's grounding prefill regenerates its rows on
        // device — no upload needed
        r.note_prefill_applied(&mut c, &[1]);
        assert_eq!(c.dirty.kv.count_slot(1), 0);
        let after = r.sync_kv(&mut c, &[0, 1]);
        assert_eq!(after.shipped, 0);
    }

    #[test]
    fn pooled_staging_copies_only_requested_rows() {
        let d = dims();
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let mut tokens = vec![0i32; 2 * d.ctx];
        for (i, t) in tokens.iter_mut().enumerate() {
            *t = i as i32;
        }
        let out = r.stage_prefill_tokens(&tokens, &[1]);
        assert_eq!(out.shipped, (d.ctx * 4) as u64);
        assert_eq!(out.full, (2 * d.ctx * 4) as u64);
        let data = r.prefill_tokens.as_i32().unwrap();
        assert_eq!(data[d.ctx], d.ctx as i32, "slot 1 row copied");
        assert_eq!(data[0], 0, "slot 0 row untouched");

        let s = r.stage_step_tokens(&tokens, d.prompt_len, 2, &[0]);
        assert_eq!(s.shipped, 8);
        assert_eq!(r.step_tokens.shape(), &[2, 2]);
        assert_eq!(
            r.step_tokens.as_i32().unwrap()[0],
            d.prompt_len as i32,
            "block tokens staged from block_start"
        );
    }

    #[test]
    fn invalidate_takes_back_the_cleared_bit_promise() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Host);
        r.sync_kv(&mut c, &[0, 1]);
        let _ = r.sync_ind(&mut c, "h", &[0, 1], &[0, 1]).unwrap();
        assert_eq!(c.dirty.kv.count(), 0);

        // a failed upload/execute: the planner's clears must be undone
        r.invalidate(&mut c);
        assert_eq!(c.dirty.kv.count(), 2 * d.ctx, "everything dirty again");
        assert!(r.chain.handles.kv.is_none() && r.chain.handles.ind.is_none());
        let reseed = r.sync_kv(&mut c, &[0, 1]);
        assert_eq!(reseed.shipped, c.kv_bytes() as u64, "next sync re-seeds");
        assert_eq!(r.stats.full_kv_uploads, 2);
    }

    #[test]
    fn device_planner_seed_then_zero_byte_steady_state() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let tokens = vec![0i32; 2 * d.ctx];
        let slots = [0usize, 1];

        // a step before any grounding prefill must refuse to run
        assert!(r
            .sync_step_device(&mut c, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &slots)
            .is_err());

        // grounding prefill: seeds all three chains (one full upload each)
        r.sync_prefill_device(&mut c, "h", &tokens, &slots).unwrap();
        assert_eq!(r.stats.full_kv_uploads, 1);
        assert_eq!(r.stats.kv_upload_bytes, c.kv_bytes() as u64);
        assert!(r.stats.ind_upload_bytes > 0);
        assert!(r.stats.conf_upload_bytes > 0);
        assert!(r.stats.d2h_bytes_avoided > 0);
        // downlink: the gen-region logit slice, not the full context
        let gen_logits = (2 * d.gen_len * d.vocab * 4) as u64;
        let ctx_logits = (2 * d.ctx * d.vocab * 4) as u64;
        assert_eq!(r.stats.d2h_bytes_shipped, gen_logits);
        assert_eq!(r.stats.d2h_bytes_saved, ctx_logits - gen_logits);
        assert_eq!(r.stats.donated_execs, 1);
        r.note_prefill_applied(&mut c, &slots);

        // steady-state step: only tokens + the batch-bit mask ship
        let snap = r.stats;
        r.sync_step_device(&mut c, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &slots)
            .unwrap();
        r.note_step_applied(&mut c, "h", false, d.prompt_len, 2, &slots);
        let delta = r.stats.since(&snap);
        assert_eq!(delta.kv_upload_bytes, 0);
        assert_eq!(delta.ind_upload_bytes, 0);
        assert_eq!(delta.conf_upload_bytes, 0);
        assert_eq!(delta.full_kv_uploads, 0);
        let expected_tokens = (2 * 2 * 4 + 2 * 4) as u64; // block rows + mask
        assert_eq!(delta.token_upload_bytes, expected_tokens);
        assert_eq!(delta.upload_bytes, expected_tokens);
        assert_eq!(delta.retained_out_reuses, 3, "kv+ind+conf all chained");
        assert_eq!(delta.ingraph_conf_steps, 1);
        assert!(delta.d2h_bytes_avoided > 0, "block downloads avoided");
        assert_eq!(delta.resident_reuses, 3);
        // downlink: n_sel = 2 selected rows' logits + their positions
        assert_eq!(delta.d2h_bytes_shipped, (2 * 2 * d.vocab * 4 + 2 * 2 * 4) as u64);
        assert_eq!(delta.d2h_bytes_saved, (2 * (d.ctx - 2) * d.vocab * 4) as u64);
        assert_eq!(delta.donated_execs, 1, "the chain was donated in place");
    }

    #[test]
    fn fused_planner_accounts_k_iterations_per_dispatch() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let tokens = vec![0i32; 2 * d.ctx];
        let slots = [0usize, 1];

        // depth 1 is not a fused run, and a fused step still needs the
        // seeded chain
        assert!(r
            .sync_step_device_k(&mut c, "h", d.n_layers, 2, 1, &tokens, d.prompt_len, 2, &slots)
            .is_err());
        assert!(r
            .sync_step_device_k(&mut c, "h", d.n_layers, 2, 4, &tokens, d.prompt_len, 2, &slots)
            .is_err());

        r.sync_prefill_device(&mut c, "h", &tokens, &slots).unwrap();
        r.note_prefill_applied(&mut c, &slots);

        // one fused dispatch of k = 4 inner iterations
        let snap = r.stats;
        r.sync_step_device_k(&mut c, "h", d.n_layers, 2, 4, &tokens, d.prompt_len, 2, &slots)
            .unwrap();
        r.note_step_applied(&mut c, "h", false, d.prompt_len, 2, &slots);
        let delta = r.stats.since(&snap);
        // uplink: the occupancy mask alone — x_tok rides the fourth
        // retained chain, and the grounding prefill's token staging
        // already seeded it (the slots' tok bits are clean)
        let expected_tokens = (2 * 4) as u64;
        assert_eq!(delta.upload_bytes, expected_tokens);
        assert_eq!(delta.retained_out_reuses, 4, "kv+ind+conf+tok all chained");
        assert_eq!(delta.ingraph_conf_steps, 4, "conf computed at every inner iter");
        assert_eq!(delta.fused_execs, 1);
        assert_eq!(delta.inner_iters_fused, 4);
        assert_eq!(delta.dispatches_avoided, 3);
        // downlink: the FINAL iteration's selected rows + positions,
        // plus the per-slot committed-count vector
        assert_eq!(
            delta.d2h_bytes_shipped,
            (2 * 2 * d.vocab * 4 + 2 * 2 * 4 + 2 * 4) as u64
        );
        // k block-slice downloads avoided vs the Host-apply path
        let single = {
            let mut c1 = GroupCaches::new(&d, 2);
            let mut r1 = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
            r1.sync_prefill_device(&mut c1, "h", &tokens, &slots).unwrap();
            r1.note_prefill_applied(&mut c1, &slots);
            let s = r1.stats;
            r1.sync_step_device(&mut c1, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &slots)
                .unwrap();
            r1.stats.since(&s)
        };
        assert_eq!(delta.d2h_bytes_avoided, 4 * single.d2h_bytes_avoided);
        assert_eq!(single.fused_execs, 0, "single steps never count as fused");
        assert_eq!(single.dispatches_avoided, 0);
    }

    #[test]
    fn donation_off_keeps_d2h_ledger_but_counts_no_donated_execs() {
        // an alias-less artifact set (no `alias` signatures in the
        // manifest) still chains and still downloads the sliced logits,
        // but must not report in-place donation it cannot perform
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        assert!(r.donation(), "device planner models donation by default");
        r.set_donation(false);
        let tokens = vec![0i32; 2 * d.ctx];
        r.sync_prefill_device(&mut c, "h", &tokens, &[0, 1]).unwrap();
        r.note_prefill_applied(&mut c, &[0, 1]);
        r.sync_step_device(&mut c, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &[0, 1])
            .unwrap();
        assert_eq!(r.stats.donated_execs, 0, "no alias config, no donation");
        assert!(r.stats.d2h_bytes_shipped > 0, "sliced downlink still counted");
        assert!(r.stats.d2h_bytes_saved > 0);
    }

    #[test]
    fn device_step_refuses_host_divergent_slot() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let tokens = vec![0i32; 2 * d.ctx];
        r.sync_prefill_device(&mut c, "h", &tokens, &[0, 1]).unwrap();
        r.note_prefill_applied(&mut c, &[0, 1]);

        // an admission reset dirties slot 1; stepping it without the
        // grounding prefill must fail loudly, naming the slot
        c.reset_slot(1);
        let err = r
            .sync_step_device(&mut c, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &[1])
            .unwrap_err();
        assert!(format!("{err}").contains("slot 1"), "{err}");
        // the co-resident slot is unaffected and can still step
        r.sync_step_device(&mut c, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &[0])
            .unwrap();
        // after the grounding prefill the admitted slot steps again
        r.sync_prefill_device(&mut c, "h", &tokens, &[1]).unwrap();
        r.note_prefill_applied(&mut c, &[1]);
        let snap = r.stats;
        r.sync_step_device(&mut c, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &[1])
            .unwrap();
        assert_eq!(r.stats.since(&snap).kv_upload_bytes, 0, "regenerated on device");
    }

    #[test]
    fn invalidate_resets_the_device_chain_for_reseed() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let tokens = vec![0i32; 2 * d.ctx];
        r.sync_prefill_device(&mut c, "h", &tokens, &[0, 1]).unwrap();
        r.note_prefill_applied(&mut c, &[0, 1]);

        r.invalidate(&mut c);
        assert!(r.chain.handles.kv_chain.is_none() && r.chain.handles.conf_chain.is_none());
        // a step against the dropped chain is refused...
        assert!(r
            .sync_step_device(&mut c, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &[0])
            .is_err());
        // ...and the next grounding prefill re-seeds (a second full upload)
        r.sync_prefill_device(&mut c, "h", &tokens, &[0, 1]).unwrap();
        assert_eq!(r.stats.full_kv_uploads, 2);
    }

    #[test]
    fn occ_mask_stages_requested_slots() {
        let d = dims();
        let mut r = DeviceGroupCaches::new(&d, 3, ApplyMode::Device);
        let out = r.stage_occ_mask(&[1]);
        assert_eq!(out.shipped, 12, "B x 4 bytes");
        assert_eq!(r.occ_mask.as_i32().unwrap(), &[0, 1, 0]);
        r.stage_occ_mask(&[0, 2]);
        assert_eq!(r.occ_mask.as_i32().unwrap(), &[1, 0, 1]);
    }

    #[test]
    fn pool_checkout_park_roundtrip_and_counters() {
        let d = dims();
        let pool = ResidencyPool::new();
        let seed = chain_seed_bytes(&d, 2);

        // cold start: miss, fresh registration
        assert!(pool.checkout("a", 2, None, seed).is_none());
        pool.register_fresh();
        assert_eq!(pool.stats().resident_chains, 1);
        assert_eq!(pool.stats().chain_rebuilds_avoided, 0);

        // seed the chain, park it, check it back out: an avoided rebuild
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let tokens = vec![0i32; 2 * d.ctx];
        r.sync_prefill_device(&mut c, "h", &tokens, &[0, 1]).unwrap();
        pool.park("a", 2, None, r.park_plan(), true);
        assert_eq!(pool.stats().resident_chains, 1, "parked still resident");
        let plan = pool.checkout("a", 2, None, seed).expect("parked plan");
        assert!(plan.kv_seeded && plan.conf_seeded);
        r.restore_plan(plan);
        let st = pool.stats();
        assert_eq!(st.chain_rebuilds_avoided, 1);
        assert_eq!(st.reseed_bytes_saved, seed);
        assert_eq!(st.resident_chains, 1);

        // owner keys separate PJRT workers: worker 1's parked chain is
        // invisible to worker 2 (its device buffers are thread-local)
        pool.park("a", 2, Some(1), r.park_plan(), false);
        assert!(pool.checkout("a", 2, Some(2), seed).is_none());
        assert!(pool.checkout("a", 2, Some(1), seed).is_some());
    }

    #[test]
    fn pool_parks_unseeded_plan_without_rebuild_credit() {
        let d = dims();
        let pool = ResidencyPool::new();
        pool.register_fresh();
        pool.park("a", 1, None, ChainPlan::default(), true);
        // an unseeded parked plan is a hit, but saved nothing
        let plan = pool.checkout("a", 1, None, chain_seed_bytes(&d, 1)).unwrap();
        assert!(!plan.kv_seeded);
        assert_eq!(pool.stats().chain_rebuilds_avoided, 0);
        assert_eq!(pool.stats().reseed_bytes_saved, 0);
    }

    #[test]
    fn pool_evict_removes_parked_and_live_entries() {
        let pool = ResidencyPool::new();
        pool.register_fresh(); // live b8 chain
        pool.register_fresh(); // live b1 chain, about to park
        pool.park("a", 1, None, ChainPlan { kv_seeded: true, ..Default::default() }, true);
        assert_eq!(pool.stats().resident_chains, 2, "one live + one parked");
        pool.evict("a", 1, None, false); // the parked entry
        assert_eq!(pool.stats().resident_chains, 1);
        pool.evict("a", 8, None, true); // the live chain
        assert_eq!(pool.stats().resident_chains, 0);
        // the evicted plan is unreachable: a later checkout must rebuild
        assert!(pool.checkout("a", 1, None, 0).is_none());
    }

    #[test]
    fn pool_evict_lru_frees_oldest_parked_entries_first() {
        let pool = ResidencyPool::new();
        let seeded = ChainPlan { kv_seeded: true, ..Default::default() };
        pool.park("a", 1, None, seeded.clone(), false); // oldest
        pool.park("a", 8, None, seeded.clone(), false);
        pool.park("b", 8, None, seeded.clone(), false); // newest
        // touching b1 via a shared checkout makes it most-recently-used
        assert!(pool.checkout("a", 1, None, 0).is_some());

        let evicted = pool.evict_lru(1);
        assert_eq!(evicted, vec![("a".to_string(), 8, None)], "LRU is a/b8");
        assert!(pool.checkout("a", 8, None, 0).is_none(), "evicted: must re-seed");
        assert!(pool.checkout("a", 1, None, 0).is_some(), "recently used survives");

        // draining past the registry is safe and reports what it freed
        let rest = pool.evict_lru(5);
        assert_eq!(rest.len(), 2);
        assert!(pool.evict_lru(1).is_empty(), "nothing left to evict");
    }

    #[test]
    fn invalidate_resets_the_parkable_plan() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let tokens = vec![0i32; 2 * d.ctx];
        r.sync_prefill_device(&mut c, "h", &tokens, &[0, 1]).unwrap();
        assert!(r.park_plan().kv_seeded);
        r.invalidate(&mut c);
        assert_eq!(r.park_plan(), ChainPlan::default(), "nothing left to park");
    }

    #[test]
    fn transfer_stats_since_is_fieldwise() {
        let mut a = TransferStats::default();
        a.record(TransferKind::Kv, 100, 100);
        let snap = a;
        a.record(TransferKind::Conf, 4, 16);
        a.record(TransferKind::Kv, 0, 100);
        let delta = a.since(&snap);
        assert_eq!(delta.conf_upload_bytes, 4);
        assert_eq!(delta.upload_bytes, 4);
        assert_eq!(delta.upload_bytes_saved, 112);
        assert_eq!(delta.full_kv_uploads, 0);
        assert_eq!(delta.resident_reuses, 1);
    }

    #[test]
    fn fused_tok_chain_reseeds_after_admission_and_k1_commit_marks() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
        let tokens = vec![0i32; 2 * d.ctx];
        let slots = [0usize, 1];
        r.sync_prefill_device(&mut c, "h", &tokens, &slots).unwrap();
        r.note_prefill_applied(&mut c, &slots);
        assert!(r.chain.plan.tok_seeded, "prefill staging seeds the tok chain");
        assert_eq!(c.dirty.tok.count(), 0);

        // a k=1 device step stages its block rows and marks them dirty:
        // the HOST sampler will commit this step's unmask decisions, so
        // the device's chained tokens diverge over the block window
        r.sync_step_device(&mut c, "h", d.n_layers, 2, &tokens, d.prompt_len, 2, &slots)
            .unwrap();
        r.note_step_applied(&mut c, "h", false, d.prompt_len, 2, &slots);
        assert_eq!(c.dirty.tok.count(), 2 * 2, "block window dirty per slot");

        // the next fused dispatch re-ships exactly those dirty rows (the
        // device commits its own unmasking in-graph, so no re-marking)
        let snap = r.stats;
        r.sync_step_device_k(&mut c, "h", d.n_layers, 2, 4, &tokens, d.prompt_len, 2, &slots)
            .unwrap();
        r.note_step_applied(&mut c, "h", false, d.prompt_len, 2, &slots);
        let delta = r.stats.since(&snap);
        // dirty tok rows re-ship (2 rows × 2 slots × 4B) plus the mask
        assert_eq!(delta.token_upload_bytes, (2 * 2 * 4 + 2 * 4) as u64);
        assert_eq!(c.dirty.tok.count(), 0);

        // steady fused state: uplink is the occupancy mask alone
        let snap2 = r.stats;
        r.sync_step_device_k(&mut c, "h", d.n_layers, 2, 4, &tokens, d.prompt_len, 2, &slots)
            .unwrap();
        let d2 = r.stats.since(&snap2);
        assert_eq!(d2.token_upload_bytes, (2 * 4) as u64, "mask only");
        assert_eq!(d2.upload_bytes, (2 * 4) as u64);

        // an admission reset dirties the slot's whole context row, and
        // invalidate takes the seeding promise back entirely
        c.reset_slot(1);
        assert_eq!(c.dirty.tok.count_slot(1), d.ctx);
        r.invalidate(&mut c);
        assert!(!r.chain.plan.tok_seeded);
        assert_eq!(c.dirty.tok.count(), 2 * d.ctx);
    }

    #[test]
    fn prefix_cache_probe_hits_longest_aligned_prefix_and_credits_saved_bytes() {
        let cache = PrefixCache::new(1 << 20);
        let row_bytes = 16u64;
        let toks: Vec<i32> = (0..12).collect();
        // cold probe: a miss, nothing credited
        assert!(cache.probe("h", None, &toks, 4, row_bytes).is_none());
        let s = cache.stats();
        assert_eq!((s.prefix_hits, s.prefix_misses, s.prefill_bytes_saved), (0, 1, 0));

        cache.insert("h", None, &toks[..4], vec![1u16; 8]);
        cache.insert("h", None, &toks[..8], vec![2u16; 16]);
        // the longest block-aligned cached prefix wins: content len 11
        // aligns to 8, which is cached
        let (p, rows) = cache.probe("h", None, &toks[..11], 4, row_bytes).unwrap();
        assert_eq!(p, 8);
        assert_eq!(rows, vec![2u16; 16]);
        // a shorter prompt steps down to the 4-row entry
        let (p2, rows2) = cache.probe("h", None, &toks[..6], 4, row_bytes).unwrap();
        assert_eq!((p2, rows2), (4, vec![1u16; 8]));
        // diverging tokens miss even at a cached length
        let other = [9i32, 9, 9, 9];
        assert!(cache.probe("h", None, &other, 4, row_bytes).is_none());
        // sub-block prompts never probe a key
        assert!(cache.probe("h", None, &toks[..3], 4, row_bytes).is_none());

        let s = cache.stats();
        assert_eq!(s.prefix_hits, 2);
        assert_eq!(s.prefix_misses, 3);
        assert_eq!(s.prefill_bytes_saved, (8 + 4) * row_bytes);
        assert_eq!(s.prefix_cache_bytes, (8 + 16) * 2);

        // owner keys split the PJRT workers from the shared sim space
        assert!(cache.probe("h", Some(7), &toks[..11], 4, row_bytes).is_none());
        // and arch is part of the key too
        assert!(cache.probe("g", None, &toks[..11], 4, row_bytes).is_none());
    }

    #[test]
    fn prefix_cache_evicts_lru_by_bytes_and_enforces_the_budget() {
        // budget holds two 16-element payloads (32 bytes each)
        let cache = PrefixCache::new(64);
        let a: Vec<i32> = (0..4).collect();
        let b: Vec<i32> = (10..14).collect();
        let c: Vec<i32> = (20..24).collect();
        cache.insert("h", None, &a, vec![1u16; 16]); // oldest
        cache.insert("h", None, &b, vec![2u16; 16]);
        assert_eq!(cache.stats().prefix_cache_bytes, 64);
        // touching `a` makes `b` the LRU victim of the next insert
        assert!(cache.probe("h", None, &a, 4, 1).is_some());
        cache.insert("h", None, &c, vec![3u16; 16]);
        let s = cache.stats();
        assert_eq!(s.prefix_evictions, 1);
        assert_eq!(s.prefix_cache_bytes, 64, "budget holds after eviction");
        assert!(cache.probe("h", None, &b, 4, 1).is_none(), "LRU entry evicted");
        assert!(cache.probe("h", None, &a, 4, 1).is_some(), "touched entry survives");
        assert!(cache.probe("h", None, &c, 4, 1).is_some(), "new entry resident");

        // re-inserting an existing key refreshes in place: no eviction,
        // byte accounting replaces rather than accumulates
        cache.insert("h", None, &a, vec![4u16; 16]);
        let s = cache.stats();
        assert_eq!(s.prefix_evictions, 1);
        assert_eq!(s.prefix_cache_bytes, 64);
        let (_, rows) = cache.probe("h", None, &a, 4, 1).unwrap();
        assert_eq!(rows, vec![4u16; 16], "payload refreshed");

        // a payload no budget can hold is dropped, not cached at any cost
        cache.insert("h", None, &b, vec![5u16; 64]);
        let s = cache.stats();
        assert_eq!(s.prefix_cache_bytes, 64, "oversize insert rejected");
        assert!(cache.probe("h", None, &b, 4, 1).is_none());
        // an empty offer is ignored outright
        cache.insert("h", None, &[], vec![6u16; 4]);
        cache.insert("h", None, &a[..1], vec![]);
        assert_eq!(cache.stats().prefix_cache_bytes, 64);
    }
}
