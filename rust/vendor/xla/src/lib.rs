//! Stub of the `xla` PJRT bindings used by `crate::runtime`.
//!
//! This container image ships no PJRT shared library, so the real
//! bindings cannot link. The stub exposes the exact API surface the
//! runtime uses and fails fast at [`PjRtClient::cpu`] with a clear
//! message; everything downstream (router, scheduler, HTTP front end)
//! degrades gracefully, and the simulation backend plus all host-side
//! tests run without it. Point the `xla` path dependency in the root
//! `Cargo.toml` at the real bindings to enable PJRT execution — no
//! source changes are needed.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (stub xla crate; link the real \
         xla bindings to enable execution)"
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Bf16,
    F32,
    S32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    Bf16,
    S32,
}

pub struct PjRtDevice;

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("buffer_from_host_literal"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_b"))
    }

    /// Untupled execution: the real bindings run with
    /// `ExecuteOptions.untuple_result = true`, so the inner vector holds
    /// one `PjRtBuffer` per root-tuple element. This is what lets the
    /// runtime retain individual outputs on device (device-apply cache
    /// chaining) instead of downloading one fused result tuple.
    pub fn execute_untupled<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_untupled"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Err(unavailable("array_shape"))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("to_vec"))
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, Error> {
        Err(unavailable("convert"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("to_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("PJRT backend unavailable"));
    }
}
