"""Training + AOT pipeline tests: the loss decreases, checkpoints
round-trip, and lowered HLO text obeys the interchange constraints."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import tasks
from compile.modelcfg import ModelCfg, param_specs, SKIP_CONFIGS, final_keep
from compile import model as M
from compile import train as T
from compile.xlc import lower_to_hlo_text

TINY = ModelCfg(name="tiny", d_model=32, n_layers=2, n_heads=2,
                n_kv_heads=2, d_ff=64, prompt_len=16, gen_len=8)


def test_loss_decreases_on_tiny_model():
    rng = np.random.RandomState(0)
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    m, v = T.adam_init(params)

    @jax.jit
    def step(params, m, v, toks, tgt, w, s):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(TINY, p, toks, tgt, w))(params)
        params, m, v = T.adam_update(params, grads, m, v, s, 3e-3)
        return params, m, v, loss

    losses = []
    for s in range(1, 41):
        toks, tgt, w = T.make_batch(TINY, rng, 16)
        params, m, v, loss = step(params, m, v, jnp.asarray(toks),
                                  jnp.asarray(tgt), jnp.asarray(w),
                                  jnp.float32(s))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.7, losses[::8]


def test_make_batch_masks_only_answers():
    rng = np.random.RandomState(1)
    toks, tgt, w = T.make_batch(TINY, rng, 8)
    # prompt region never masked, never weighted
    assert (toks[:, :TINY.prompt_len] != tasks.MASK).all()
    assert (w[:, :TINY.prompt_len] == 0).all()
    # every weighted position is masked in the input and recoverable
    m = w > 0
    assert (toks[:, TINY.prompt_len:][m[:, TINY.prompt_len:]]
            == tasks.MASK).all()
    assert m.any(axis=1).all()


def test_checkpoint_roundtrip(tmp_path):
    params = M.init_params(TINY, jax.random.PRNGKey(2))
    path = str(tmp_path / "w.bin")
    T.write_checkpoint(path, TINY, params)
    loaded = T.read_checkpoint(path, TINY)
    for a, b in zip(M.params_to_flat(params), M.params_to_flat(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lowered_hlo_has_no_topk_and_keeps_unused():
    def fn(x, unused):
        return (jnp.argsort(-x, axis=-1)[..., :2],)

    text = lower_to_hlo_text(
        fn,
        jax.ShapeDtypeStruct((2, 8), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    )
    assert " topk(" not in text
    assert "sort(" in text
    # keep_unused: both parameters present
    assert "parameter(0)" in text and "parameter(1)" in text


def test_final_keep_matches_skip_chain():
    assert final_keep(8, SKIP_CONFIGS["default"]) == 2
    assert final_keep(32, SKIP_CONFIGS["default"]) == 8
    assert final_keep(8, SKIP_CONFIGS["r1_only_70"]) == 2
    assert final_keep(32, SKIP_CONFIGS["triple_405"]) == 7


def test_param_specs_order_is_stable():
    names = [n for n, _ in param_specs(TINY)]
    assert names[0] == "embed"
    assert names[-2:] == ["out_norm", "head"]
    assert names[1:10] == [
        "layer00.attn_norm", "layer00.wq", "layer00.wk", "layer00.wv",
        "layer00.wo", "layer00.ffn_norm", "layer00.w_gate", "layer00.w_up",
        "layer00.w_down"]


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built")
def test_manifest_consistency():
    import json
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    assert man["generation"]["ctx"] == 80
    for arch_name, arch in man["archs"].items():
        n_params = len(arch["params"])
        for exe_name, exe in arch["executables"].items():
            path = os.path.join(ARTIFACTS, exe["file"])
            assert os.path.exists(path), exe["file"]
            assert len(exe["inputs"]) > n_params, exe_name
            assert len(exe["outputs"]) == len(exe["output_names"]), exe_name
            if exe["kind"] == "step":
                k = exe["final_keep"]
                logits = exe["outputs"][0]
                assert logits["shape"][1] == k, exe_name


def test_apply_variants_lower_to_parseable_hlo():
    """The device-apply executables must obey the same interchange
    constraints as the block-output ones (no `topk`, all params kept)."""
    import functools
    cfg = TINY
    L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    B, blk = 2, 4
    params = [jax.ShapeDtypeStruct(s, jnp.float32)
              for _, s in param_specs(cfg)]

    def step_fn(*flat):
        p = M.params_from_flat(cfg, flat[:len(params)])
        x_tok, bs, kv, ind, conf, occ, alpha = flat[len(params):]
        return M.step(cfg, p, x_tok, bs, kv, ind, conf, alpha, block=blk,
                      skip=[(1, 0.5)], ind_layers=[1], apply=True, occ=occ)

    text = lower_to_hlo_text(
        step_fn, *params,
        jax.ShapeDtypeStruct((B, blk), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((L, 2, B, Hkv, cfg.ctx, hd), jnp.bfloat16),
        jax.ShapeDtypeStruct((L, B, cfg.gen_len, cfg.d_model), jnp.bfloat16),
        jax.ShapeDtypeStruct((B, cfg.gen_len), jnp.float32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    assert " topk(" not in text

    def step_k_fn(*flat):
        p = M.params_from_flat(cfg, flat[:len(params)])
        x_tok, bs, kv, ind, conf, occ, alpha, thr, seed = flat[len(params):]
        return M.step_k(cfg, p, x_tok, bs, kv, ind, conf, occ, alpha,
                        thr, seed, k=2, block=blk, skip=[(1, 0.5)],
                        mask_id=tasks.MASK, eos_id=tasks.EOS,
                        ind_layers=[1])

    text = lower_to_hlo_text(
        step_k_fn, *params,
        jax.ShapeDtypeStruct((B, blk), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((L, 2, B, Hkv, cfg.ctx, hd), jnp.bfloat16),
        jax.ShapeDtypeStruct((L, B, cfg.gen_len, cfg.d_model), jnp.bfloat16),
        jax.ShapeDtypeStruct((B, cfg.gen_len), jnp.float32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((2, B, blk), jnp.int32),
    )
    assert " topk(" not in text

    def prefill_fn(*flat):
        p = M.params_from_flat(cfg, flat[:len(params)])
        toks, kv, ind, conf, refresh = flat[len(params):]
        return M.prefill_apply(cfg, p, toks, kv, ind, conf, refresh)

    text = lower_to_hlo_text(
        prefill_fn, *params,
        jax.ShapeDtypeStruct((B, cfg.ctx), jnp.int32),
        jax.ShapeDtypeStruct((L, 2, B, Hkv, cfg.ctx, hd), jnp.bfloat16),
        jax.ShapeDtypeStruct((L, B, cfg.gen_len, cfg.d_model), jnp.bfloat16),
        jax.ShapeDtypeStruct((B, cfg.gen_len), jnp.float32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
    )
    assert " topk(" not in text
