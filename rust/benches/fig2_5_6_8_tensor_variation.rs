//! Figures 2, 5, 6 (llada) & 8 (dream) + Table 3: intermediate-tensor
//! variation statistics — normalized-L1 variation distributions for
//! hidden/Q/K/V at the probe layers (2/5/7 ≙ paper layers 10/20/30), the
//! per-layer distribution sweep, and the Pearson correlation between
//! tensor variation and |Δconfidence| by layer.

use esdllm::analysis::{histogram, observe_generation, pearson, PROBE_TENSORS};
use esdllm::bench::{bench_archs, bench_n, Table};
use esdllm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let groups = (bench_n(24) / 8).max(1);

    for arch in bench_archs() {
        let figs = if arch.starts_with("llada") { "fig2_5_6" } else { "fig8" };
        let stats = observe_generation(&rt, &arch, groups)?;
        let bins = [0.001f32, 0.005, 0.01, 0.05, 0.1, 0.3, 0.6, 1.0];

        // variation distribution per probe layer × tensor
        let mut dist = Table::new(
            &format!("{figs} analog: tensor-variation distributions ({arch})"),
            &["layer", "tensor", "frac<0.05", "frac<0.1", "mean", "p90"],
        );
        for (pi, layer) in stats.probe_layers.iter().enumerate() {
            for (ti, tensor) in PROBE_TENSORS.iter().enumerate() {
                let mut vals: Vec<f32> = stats
                    .records
                    .iter()
                    .flat_map(|r| r.var[pi][ti].iter().cloned())
                    .collect();
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let n = vals.len().max(1);
                let below = |t: f32| {
                    vals.partition_point(|v| *v < t) as f64 / n as f64
                };
                let mean: f64 =
                    vals.iter().map(|v| *v as f64).sum::<f64>() / n as f64;
                let p90 = vals[((n - 1) as f64 * 0.9) as usize];
                dist.row(&[
                    format!("{layer}"),
                    tensor.to_string(),
                    format!("{:.3}", below(0.05)),
                    format!("{:.3}", below(0.1)),
                    format!("{mean:.4}"),
                    format!("{p90:.4}"),
                ]);
                // full histogram CSV for the figure pipeline
                let h = histogram(vals.iter().cloned(), &bins);
                let mut ht = Table::new("hist", &["bin_lo", "count"]);
                let mut lo = 0.0f32;
                for (i, c) in h.iter().enumerate() {
                    ht.row(&[format!("{lo:.3}"), format!("{c}")]);
                    lo = bins.get(i).copied().unwrap_or(f32::INFINITY);
                }
                ht.write_csv(&format!(
                    "artifacts/figures/{figs}_var_{arch}_l{layer}_{tensor}.csv"
                ))?;
            }
        }
        dist.print();
        dist.write_csv(&format!("artifacts/figures/{figs}_var_summary_{arch}.csv"))?;

        // Table 3 analog: correlation between variation and |Δconf|
        let mut corr = Table::new(
            &format!("Table 3 analog: Pearson(variation, |Δconf|) by layer ({arch})"),
            &["tensor", "layer2", "layer5", "layer7"],
        );
        for (ti, tensor) in PROBE_TENSORS.iter().enumerate() {
            let mut row = vec![tensor.to_string()];
            for pi in 0..stats.probe_layers.len() {
                let xs: Vec<f32> = stats
                    .records
                    .iter()
                    .flat_map(|r| r.var[pi][ti].iter().cloned())
                    .collect();
                let ys: Vec<f32> = stats
                    .records
                    .iter()
                    .flat_map(|r| r.conf_delta.iter().cloned())
                    .collect();
                row.push(format!("{:.3}", pearson(&xs, &ys)));
            }
            corr.row(&row);
        }
        corr.print();
        corr.write_csv(&format!("artifacts/figures/table3_corr_{arch}.csv"))?;
    }
    Ok(())
}
