//! Artifact manifest: the typed view of `artifacts/manifest.json`, the
//! contract between the build path (python) and the request path (rust).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    Bf16,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "bf16" => DType::Bf16,
            other => return Err(anyhow!("unknown dtype {other}")),
        })
    }

    pub fn bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::Bf16 => 2,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype.bytes()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExeKind {
    Prefill,
    Step,
    Observe,
    /// device-apply prefill: merges its own outputs into the resident
    /// cache tensors in-graph (row-filtered by the refresh mask) and
    /// computes confidence in-graph; kv/ind/conf outputs are retained
    PrefillApply,
    /// device-apply decode step: dynamic-update-slice cache scatter +
    /// in-graph confidence, occupancy mask as a batch-bit input
    StepApply,
    /// fused k-step decode: k diffusion iterations unrolled in one
    /// execution, with greedy/threshold unmasking between inner
    /// iterations in-graph; downlinks only the final iteration's logit
    /// rows plus a per-slot committed-count vector. Carries a required
    /// `k` field (the unroll depth, >= 2).
    StepApplyK,
}

/// A device-retained output signature: the named output is produced on
/// device, left there (never downloaded), and fed back as the named
/// input on the next call — the KV-chaining contract between the
/// compile pipeline and the runtime. `donate` (manifest field `alias`)
/// additionally declares the pair as a PJRT input-output alias: the
/// runtime configures donation at compile time so the update writes the
/// input's device buffer in place — one live copy per chained tensor,
/// with no transient second allocation during execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetainedSig {
    pub output: String,
    pub input: String,
    pub donate: bool,
}

#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub name: String,
    pub kind: ExeKind,
    pub file: PathBuf,
    pub batch: usize,
    /// block length for step executables
    pub block: Option<usize>,
    /// (layer, ratio) skip spec; empty = DualCache-style full block
    pub skip: Vec<(usize, f64)>,
    pub skip_layers: Vec<usize>,
    pub final_keep: Option<usize>,
    pub indicator: Option<String>,
    pub kv_len: usize,
    /// unroll depth for `step_apply_k` executables (`None` otherwise)
    pub k: Option<usize>,
    /// live gen length for a suffix-pruned context-tier variant: the
    /// chained gen-region state (ind/conf) covers only this many rows
    /// and `kv_len == prompt_len + gen_live`. `None` for full-context
    /// executables (gen_live == gen_len).
    pub gen_live: Option<usize>,
    /// non-parameter inputs, in call order after the parameter list
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub output_names: Vec<String>,
    /// outputs retained on device and chained into the next call's
    /// inputs (device-apply executables; empty otherwise)
    pub retained: Vec<RetainedSig>,
}

#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: String,
    pub dims: Dims,
    pub checkpoints: BTreeMap<String, String>,
    pub params: Vec<(String, Vec<usize>)>,
    pub executables: BTreeMap<String, ExeSpec>,
}

// ten plain usizes: `Copy` so geometry travels by value and the hot
// paths don't accumulate `dims.clone()` noise
#[derive(Debug, Clone, Copy)]
pub struct Dims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub head_dim: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub ctx: usize,
}

#[derive(Debug, Clone)]
pub struct GenCfg {
    pub prompt_len: usize,
    pub gen_len: usize,
    pub ctx: usize,
    pub vocab: usize,
    pub pad: i32,
    pub mask: i32,
    pub eos: i32,
    pub bos: i32,
    pub sparse_keep_prompt: usize,
    pub observe_probe_layers: Vec<usize>,
    /// live-context tiers: absolute kv lengths (prompt + live gen rows)
    /// for which the compile pipeline lowered dedicated executables,
    /// ascending, ending at the full compiled context. Manifests from
    /// older pipelines omit the field and get the single full tier.
    pub ctx_tiers: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub generation: GenCfg,
    pub archs: BTreeMap<String, ArchSpec>,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key).as_usize().ok_or_else(|| anyhow!("missing usize field {key}"))
}

fn tensor_sigs(j: &Json) -> Result<Vec<TensorSig>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensors"))?
        .iter()
        .map(|t| {
            Ok(TensorSig {
                name: t.get("name").as_str().unwrap_or("").to_string(),
                shape: t
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow!("tensor missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
                dtype: DType::parse(t.get("dtype").as_str().unwrap_or("f32"))?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&src).map_err(|e| anyhow!("{e}"))?;

        let g = j.get("generation");
        let generation = GenCfg {
            prompt_len: req_usize(g, "prompt_len")?,
            gen_len: req_usize(g, "gen_len")?,
            ctx: req_usize(g, "ctx")?,
            vocab: req_usize(g, "vocab")?,
            pad: g.get("pad").as_i64().unwrap_or(0) as i32,
            mask: g.get("mask").as_i64().unwrap_or(1) as i32,
            eos: g.get("eos").as_i64().unwrap_or(2) as i32,
            bos: g.get("bos").as_i64().unwrap_or(3) as i32,
            sparse_keep_prompt: req_usize(g, "sparse_keep_prompt")?,
            observe_probe_layers: g
                .get("observe_probe_layers")
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            ctx_tiers: match g.get("ctx_tiers").as_arr() {
                None => vec![req_usize(g, "ctx")?],
                Some(a) => {
                    let tiers: Vec<usize> =
                        a.iter().filter_map(|x| x.as_usize()).collect();
                    if tiers.len() != a.len() {
                        return Err(anyhow!(
                            "generation.ctx_tiers must be an array of \
                             positive integers"
                        ));
                    }
                    let (prompt, ctx) =
                        (req_usize(g, "prompt_len")?, req_usize(g, "ctx")?);
                    if !tiers.windows(2).all(|w| w[0] < w[1]) {
                        return Err(anyhow!(
                            "generation.ctx_tiers must be strictly \
                             ascending, got {tiers:?}"
                        ));
                    }
                    if tiers.iter().any(|&t| t <= prompt || t > ctx) {
                        return Err(anyhow!(
                            "generation.ctx_tiers entries must lie in \
                             (prompt_len, ctx] = ({prompt}, {ctx}], got \
                             {tiers:?}"
                        ));
                    }
                    if tiers.last() != Some(&ctx) {
                        return Err(anyhow!(
                            "generation.ctx_tiers must end at the full \
                             compiled context {ctx}, got {tiers:?} — the \
                             untiered executables ARE the last tier"
                        ));
                    }
                    tiers
                }
            },
        };

        let mut archs = BTreeMap::new();
        let arch_obj =
            j.get("archs").as_obj().ok_or_else(|| anyhow!("missing archs"))?;
        for (name, a) in arch_obj {
            archs.insert(name.clone(), Self::parse_arch(name, a)?);
        }
        Ok(Manifest { root: artifacts_dir.to_path_buf(), generation, archs })
    }

    fn parse_arch(name: &str, a: &Json) -> Result<ArchSpec> {
        let d = a.get("dims");
        let dims = Dims {
            vocab: req_usize(d, "vocab")?,
            d_model: req_usize(d, "d_model")?,
            n_layers: req_usize(d, "n_layers")?,
            n_heads: req_usize(d, "n_heads")?,
            n_kv_heads: req_usize(d, "n_kv_heads")?,
            d_ff: req_usize(d, "d_ff")?,
            head_dim: req_usize(d, "head_dim")?,
            prompt_len: req_usize(d, "prompt_len")?,
            gen_len: req_usize(d, "gen_len")?,
            ctx: req_usize(d, "ctx")?,
        };
        let checkpoints = a
            .get("checkpoints")
            .as_obj()
            .ok_or_else(|| anyhow!("missing checkpoints"))?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
            .collect();
        let params = a
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("missing params"))?
            .iter()
            .map(|p| {
                Ok((
                    p.get("name").as_str().unwrap_or("").to_string(),
                    p.get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .filter_map(|x| x.as_usize())
                        .collect(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;

        let n_params = params.len();
        let mut executables = BTreeMap::new();
        for (exe_name, e) in
            a.get("executables").as_obj().ok_or_else(|| anyhow!("missing executables"))?
        {
            let kind = match e.get("kind").as_str() {
                Some("prefill") => ExeKind::Prefill,
                Some("step") => ExeKind::Step,
                Some("observe") => ExeKind::Observe,
                Some("prefill_apply") => ExeKind::PrefillApply,
                Some("step_apply") => ExeKind::StepApply,
                Some("step_apply_k") => ExeKind::StepApplyK,
                other => {
                    return Err(anyhow!(
                        "executable {exe_name}: unknown `kind` {other:?} \
                         (expected one of prefill | step | observe | \
                         prefill_apply | step_apply | step_apply_k — is \
                         this manifest newer than the runtime?)"
                    ))
                }
            };
            let k = e.get("k").as_usize();
            if kind == ExeKind::StepApplyK {
                match k {
                    Some(k) if k >= 2 => {}
                    Some(k) => {
                        return Err(anyhow!(
                            "executable {exe_name}: `k` = {k} is not a \
                             valid unroll depth for kind step_apply_k \
                             (need k >= 2; a depth-1 loop is just \
                             step_apply)"
                        ))
                    }
                    None => {
                        return Err(anyhow!(
                            "executable {exe_name}: kind step_apply_k \
                             requires a `k` field (the in-graph unroll \
                             depth) — is this manifest older than the \
                             runtime?"
                        ))
                    }
                }
            }
            let all_inputs = tensor_sigs(e.get("inputs"))?;
            if all_inputs.len() < n_params {
                return Err(anyhow!("{exe_name}: fewer inputs than params"));
            }
            let output_names: Vec<String> = e
                .get("output_names")
                .as_arr()
                .map(|a| {
                    a.iter()
                        .map(|x| x.as_str().unwrap_or("").to_string())
                        .collect()
                })
                .unwrap_or_default();
            let mut retained = Vec::new();
            if let Some(arr) = e.get("retained_outputs").as_arr() {
                for r in arr {
                    let alias = r.get("alias");
                    let donate = if alias.is_null() {
                        false
                    } else {
                        alias.as_bool().ok_or_else(|| {
                            anyhow!(
                                "executable {exe_name}: `retained_outputs` \
                                 field `alias` must be a boolean, got {}",
                                alias.to_string()
                            )
                        })?
                    };
                    let sig = RetainedSig {
                        output: r.get("output").as_str().unwrap_or("").to_string(),
                        input: r.get("input").as_str().unwrap_or("").to_string(),
                        donate,
                    };
                    if !output_names.iter().any(|n| n == &sig.output) {
                        return Err(anyhow!(
                            "executable {exe_name}: `retained_outputs` names \
                             output {:?} which is not in output_names {:?}",
                            sig.output,
                            output_names
                        ));
                    }
                    if !all_inputs[n_params..].iter().any(|i| i.name == sig.input) {
                        return Err(anyhow!(
                            "executable {exe_name}: `retained_outputs` chains \
                             into input {:?} which is not a non-parameter \
                             input of this executable",
                            sig.input
                        ));
                    }
                    retained.push(sig);
                }
            }
            let kv_len = req_usize(e, "kv_len")?;
            let gen_live = e.get("gen_live").as_usize();
            if let Some(gl) = gen_live {
                if gl == 0 || gl >= dims.gen_len {
                    return Err(anyhow!(
                        "executable {exe_name}: `gen_live` = {gl} must lie \
                         in (0, gen_len) = (0, {}) — a full-length variant \
                         omits the field",
                        dims.gen_len
                    ));
                }
                if kv_len != dims.prompt_len + gl {
                    return Err(anyhow!(
                        "executable {exe_name}: a context-tier variant must \
                         satisfy kv_len == prompt_len + gen_live \
                         ({} + {gl}), got kv_len = {kv_len}",
                        dims.prompt_len
                    ));
                }
            }
            let spec = ExeSpec {
                name: exe_name.clone(),
                kind,
                file: PathBuf::from(e.get("file").as_str().unwrap_or("")),
                batch: req_usize(e, "batch")?,
                block: e.get("block").as_usize(),
                skip: e
                    .get("skip")
                    .as_arr()
                    .map(|a| {
                        a.iter()
                            .filter_map(|p| {
                                Some((
                                    p.idx(0).as_usize()?,
                                    p.idx(1).as_f64()?,
                                ))
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
                skip_layers: e
                    .get("skip_layers")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default(),
                final_keep: e.get("final_keep").as_usize(),
                indicator: e.get("indicator").as_str().map(|s| s.to_string()),
                kv_len,
                k,
                gen_live,
                inputs: all_inputs[n_params..].to_vec(),
                outputs: tensor_sigs(e.get("outputs"))?,
                output_names,
                retained,
            };
            executables.insert(exe_name.clone(), spec);
        }
        Ok(ArchSpec {
            name: name.to_string(),
            dims,
            checkpoints,
            params,
            executables,
        })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchSpec> {
        self.archs.get(name).ok_or_else(|| anyhow!("unknown arch {name}"))
    }
}

impl ExeSpec {
    /// Per-output device-retain flags in manifest output order: `true`
    /// means the runtime leaves this output on the device (chained into
    /// the next call) instead of downloading it.
    pub fn retain_flags(&self) -> Vec<bool> {
        self.output_names
            .iter()
            .map(|n| self.retained.iter().any(|r| &r.output == n))
            .collect()
    }

    /// Position of a named output in the output tuple.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.output_names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| {
                anyhow!("executable {}: no output named {name:?}", self.name)
            })
    }

    /// PJRT input-output alias (donation) pairs declared by the
    /// retained-chaining signatures marked `alias` in the manifest:
    /// `(output_index, parameter_number)`, where the parameter number is
    /// in the executable's true argument order — the `n_params` model
    /// parameters first, then the non-parameter inputs. The compile
    /// pipeline guarantees shape/dtype equality for chained pairs, so an
    /// aliased output can write its input's device buffer in place
    /// (donation: at most one live copy per chained tensor).
    pub fn alias_pairs(&self, n_params: usize) -> Vec<(usize, usize)> {
        self.retained
            .iter()
            .filter(|r| r.donate)
            .filter_map(|r| {
                let out = self.output_names.iter().position(|n| n == &r.output)?;
                let inp = self.inputs.iter().position(|i| i.name == r.input)?;
                Some((out, n_params + inp))
            })
            .collect()
    }
}

impl ArchSpec {
    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("arch {} has no executable {name}", self.name))
    }

    /// Pick the step executable for (method, block, batch, indicator).
    pub fn step_exe_name(
        &self,
        es: bool,
        sparse: bool,
        block: usize,
        batch: usize,
        indicator: &str,
    ) -> String {
        let base = match (es, sparse) {
            (true, true) => "es_sp",
            (true, false) => "es",
            (false, true) => "dual_sp",
            (false, false) => "dual",
        };
        if es && !sparse && indicator != "h" {
            format!("es_ind_{indicator}_blk{block}_b{batch}")
        } else {
            format!("{base}_blk{block}_b{batch}")
        }
    }

    /// Name of the live-context tier variant of a device-apply
    /// executable: the base name at the full context, `{base}_ctx{T}`
    /// for a suffix-pruned tier T (absolute kv length).
    pub fn tier_exe_name(&self, base: &str, live_ctx: usize) -> String {
        if live_ctx >= self.dims.ctx {
            base.to_string()
        } else {
            format!("{base}_ctx{live_ctx}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::Bf16.bytes(), 2);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn tensor_sig_sizes() {
        let t = TensorSig { name: "x".into(), shape: vec![2, 3, 4], dtype: DType::Bf16 };
        assert_eq!(t.elements(), 24);
        assert_eq!(t.byte_len(), 48);
    }

    #[test]
    fn parse_minimal_manifest() {
        let src = r#"{
          "version": 1,
          "generation": {"prompt_len":48,"gen_len":32,"ctx":80,"vocab":64,
            "pad":0,"mask":1,"eos":2,"bos":3,"sparse_keep_prompt":24,
            "observe_probe_layers":[2,5,7]},
          "archs": {"a": {
            "dims": {"vocab":64,"d_model":64,"n_layers":8,"n_heads":4,
              "n_kv_heads":4,"d_ff":256,"head_dim":16,"prompt_len":48,
              "gen_len":32,"ctx":80,"name":"a","rope_base":10000.0,"d_kv":64},
            "checkpoints": {"instruct":"w.bin"},
            "params": [{"name":"embed","shape":[64,64]}],
            "executables": {"prefill_b1": {
               "kind":"prefill","batch":1,"block":null,"skip":[],
               "indicator":null,"kv_len":80,"file":"a/prefill_b1.hlo.txt",
               "inputs":[{"name":"embed","shape":[64,64],"dtype":"f32"},
                         {"name":"tokens","shape":[1,80],"dtype":"i32"}],
               "outputs":[{"name":"out0","shape":[1,80,64],"dtype":"f32"}],
               "output_names":["logits"]}}}}}"#;
        let dir = std::env::temp_dir().join("esdllm-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), src).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.generation.ctx, 80);
        // older manifests omit ctx_tiers: single full tier
        assert_eq!(m.generation.ctx_tiers, vec![80]);
        let a = m.arch("a").unwrap();
        assert_eq!(a.dims.n_layers, 8);
        let e = a.exe("prefill_b1").unwrap();
        assert_eq!(e.kind, ExeKind::Prefill);
        assert_eq!(e.gen_live, None);
        // non-param inputs only
        assert_eq!(e.inputs.len(), 1);
        assert_eq!(e.inputs[0].name, "tokens");
    }

    fn load_src(src: &str, subdir: &str) -> Result<Manifest> {
        let dir = std::env::temp_dir().join(format!("esdllm-mf-{subdir}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), src).unwrap();
        Manifest::load(&dir)
    }

    const TIER_SRC: &str = r#"{
      "version": 1,
      "generation": {"prompt_len":48,"gen_len":32,"ctx":80,"vocab":64,
        "pad":0,"mask":1,"eos":2,"bos":3,"sparse_keep_prompt":24,
        "observe_probe_layers":[2,5,7],"ctx_tiers":CTX_TIERS},
      "archs": {"a": {
        "dims": {"vocab":64,"d_model":64,"n_layers":8,"n_heads":4,
          "n_kv_heads":4,"d_ff":256,"head_dim":16,"prompt_len":48,
          "gen_len":32,"ctx":80,"name":"a","rope_base":10000.0,"d_kv":64},
        "checkpoints": {"instruct":"w.bin"},
        "params": [{"name":"embed","shape":[64,64]}],
        "executables": {"es_apply_blk8_b8_ctx64": {
           "kind":"step_apply","batch":8,"block":8,"skip":[[2,0.5]],
           "indicator":"h","kv_len":KV_LEN,"gen_live":GEN_LIVE,
           "file":"a/es_apply_blk8_b8_ctx64.hlo.txt",
           "inputs":[{"name":"embed","shape":[64,64],"dtype":"f32"},
                     {"name":"x_tok","shape":[8,8],"dtype":"i32"}],
           "outputs":[{"name":"out0","shape":[8,8,64],"dtype":"f32"}],
           "output_names":["logits"]}}}}}"#;

    fn tier_src(tiers: &str, kv_len: &str, gen_live: &str) -> String {
        TIER_SRC
            .replace("CTX_TIERS", tiers)
            .replace("KV_LEN", kv_len)
            .replace("GEN_LIVE", gen_live)
    }

    #[test]
    fn ctx_tiers_parse_and_validate() {
        let m =
            load_src(&tier_src("[56,64,72,80]", "64", "16"), "tiers-ok").unwrap();
        assert_eq!(m.generation.ctx_tiers, vec![56, 64, 72, 80]);
        let e = m.arch("a").unwrap().exe("es_apply_blk8_b8_ctx64").unwrap();
        assert_eq!(e.gen_live, Some(16));
        assert_eq!(e.kv_len, 64);

        // not ascending
        let err = load_src(&tier_src("[64,56,80]", "64", "16"), "tiers-ord")
            .unwrap_err()
            .to_string();
        assert!(err.contains("strictly ascending"), "{err}");
        // below the prompt
        let err = load_src(&tier_src("[40,80]", "64", "16"), "tiers-lo")
            .unwrap_err()
            .to_string();
        assert!(err.contains("(prompt_len, ctx]"), "{err}");
        // missing the full-context terminal tier
        let err = load_src(&tier_src("[56,64]", "64", "16"), "tiers-end")
            .unwrap_err()
            .to_string();
        assert!(err.contains("must end at the full compiled context"), "{err}");
    }

    #[test]
    fn gen_live_must_match_kv_len() {
        // kv_len != prompt + gen_live
        let err = load_src(&tier_src("[56,64,72,80]", "72", "16"), "gl-kv")
            .unwrap_err()
            .to_string();
        assert!(err.contains("kv_len == prompt_len + gen_live"), "{err}");
        // gen_live out of range
        let err = load_src(&tier_src("[56,64,72,80]", "80", "32"), "gl-rng")
            .unwrap_err()
            .to_string();
        assert!(err.contains("must lie in (0, gen_len)"), "{err}");
    }

    #[test]
    fn tier_exe_name_suffix() {
        let a = ArchSpec {
            name: "x".into(),
            dims: Dims {
                vocab: 64, d_model: 64, n_layers: 8, n_heads: 4, n_kv_heads: 4,
                d_ff: 256, head_dim: 16, prompt_len: 48, gen_len: 32, ctx: 80,
            },
            checkpoints: BTreeMap::new(),
            params: vec![],
            executables: BTreeMap::new(),
        };
        assert_eq!(a.tier_exe_name("es_apply_blk8_b8", 80), "es_apply_blk8_b8");
        assert_eq!(
            a.tier_exe_name("es_apply_blk8_b8", 64),
            "es_apply_blk8_b8_ctx64"
        );
    }

    #[test]
    fn step_exe_names() {
        let a = ArchSpec {
            name: "x".into(),
            dims: Dims {
                vocab: 64, d_model: 64, n_layers: 8, n_heads: 4, n_kv_heads: 4,
                d_ff: 256, head_dim: 16, prompt_len: 48, gen_len: 32, ctx: 80,
            },
            checkpoints: BTreeMap::new(),
            params: vec![],
            executables: BTreeMap::new(),
        };
        assert_eq!(a.step_exe_name(true, false, 8, 8, "h"), "es_blk8_b8");
        assert_eq!(a.step_exe_name(false, false, 32, 8, "h"), "dual_blk32_b8");
        assert_eq!(a.step_exe_name(true, true, 8, 8, "h"), "es_sp_blk8_b8");
        assert_eq!(a.step_exe_name(true, false, 8, 8, "q"), "es_ind_q_blk8_b8");
    }
}
