//! Table 2: main results on dream-nano (Instruct) — GQA architecture with
//! maskgit-plus sampling; same columns as Table 1 (ES-dLLM* on the
//! BBH~logic and MBPP~listops analogs, as in the paper).

use esdllm::bench::{bench_n, Table};
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::runtime::Runtime;
use esdllm::workload::{paper_name, BENCHMARKS};

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let n = bench_n(16);
    let arch = "dream-nano";

    let mut table = Table::new(
        &format!("Table 2 analog: {arch}-Instruct, {n} samples/cell"),
        &["Benchmark", "Method", "TPS", "Speedup", "Score"],
    );
    for bench in BENCHMARKS {
        let mut cells: Vec<(Method, EvalOpts)> = vec![
            (Method::Vanilla, EvalOpts::default()),
            (Method::DualCache, EvalOpts::default()),
            (Method::EsDllm, EvalOpts::default()),
        ];
        if bench == "logic" || bench == "listops" {
            cells.push((
                Method::EsDllm,
                EvalOpts { refresh_star: true, ..Default::default() },
            ));
        }
        let mut base_tps = None;
        for (method, opts) in cells {
            let r = evaluate(&rt, arch, method, bench, n, &opts)?;
            let base = *base_tps.get_or_insert(r.tps);
            table.row(&[
                paper_name(bench).to_string(),
                r.method.clone(),
                format!("{:.2}", r.tps),
                format!("{:.1}x", r.tps / base),
                format!("{:.2}", r.score),
            ]);
        }
    }
    table.print();
    table.write_csv("artifacts/results/table2.csv")?;
    Ok(())
}
