//! Property-based tests over coordinator invariants (routing, batching,
//! sampling, cache state) using the in-tree prop framework.

use esdllm::cache::{GroupCaches, RefreshPolicy, StepPlan};
use esdllm::manifest::Dims;
use esdllm::prop::{check, Gen};
use esdllm::rng::SplitMix;
use esdllm::runtime::tensor::{bf16_to_f32, f32_to_bf16, HostTensor};
use esdllm::sampler::{decide_unmask, SamplerCfg, UnmaskInput};
use esdllm::{json::Json, prop_assert};

fn dims(g: &mut Gen) -> Dims {
    let head_dim = 8;
    let n_heads = *g.pick(&[2usize, 4]);
    Dims {
        vocab: 16,
        d_model: n_heads * head_dim,
        n_layers: *g.pick(&[2usize, 4]),
        n_heads,
        n_kv_heads: *g.pick(&[1usize, 2]),
        d_ff: 32,
        head_dim,
        prompt_len: 8,
        gen_len: 8,
        ctx: 16,
    }
}

#[test]
fn prop_sampler_unmasks_only_masked_block_positions() {
    check("sampler-unmask-valid", 200, |g| {
        let gen = 8;
        let v = 16;
        let block_lo = g.usize_in(0, 4);
        let block_hi = block_lo + g.usize_in(1, gen - block_lo);
        let logits = g.vec_f32(gen * v, -5.0, 5.0);
        let conf = g.vec_f32(gen, 0.0, 1.0);
        let gen_tokens: Vec<i32> =
            (0..gen).map(|_| if g.bool() { 1 } else { 5 }).collect();
        let cfg = if g.bool() {
            SamplerCfg::llada()
        } else {
            SamplerCfg::llada().with_parallel(g.f32_in(0.1, 0.99))
        };
        let inp = UnmaskInput {
            logits: &logits,
            conf: &conf,
            gen_tokens: &gen_tokens,
            block_lo,
            block_hi,
            vocab: v,
            mask_id: 1,
            eos_id: 2,
        };
        let mut rng = SplitMix::new(g.rng.next64());
        let d = decide_unmask(&cfg, &inp, &mut rng);
        let any_masked =
            gen_tokens[block_lo..block_hi].iter().any(|&t| t == 1);
        prop_assert!(
            d.positions.is_empty() == !any_masked,
            "unmasked exactly when nothing masked"
        );
        for (p, t) in d.positions.iter().zip(&d.tokens) {
            prop_assert!(*p >= block_lo && *p < block_hi, "position in block");
            prop_assert!(gen_tokens[*p] == 1, "position was masked");
            prop_assert!(*t != 1, "never emits the mask token");
        }
        // positions unique
        let mut ps = d.positions.clone();
        ps.dedup();
        prop_assert!(ps.len() == d.positions.len(), "duplicate positions");
        Ok(())
    });
}

#[test]
fn prop_parallel_decoding_superset_of_greedy() {
    check("pd-superset", 100, |g| {
        let gen = 8;
        let v = 8;
        let logits = g.vec_f32(gen * v, -3.0, 3.0);
        let conf = g.vec_f32(gen, 0.0, 1.0);
        let gen_tokens = vec![1i32; gen];
        let inp = UnmaskInput {
            logits: &logits,
            conf: &conf,
            gen_tokens: &gen_tokens,
            block_lo: 0,
            block_hi: gen,
            vocab: v,
            mask_id: 1,
            eos_id: 2,
        };
        let mut r1 = SplitMix::new(7);
        let mut r2 = SplitMix::new(7);
        let greedy = decide_unmask(&SamplerCfg::llada(), &inp, &mut r1);
        let pd = decide_unmask(
            &SamplerCfg::llada().with_parallel(g.f32_in(0.0, 1.0)),
            &inp,
            &mut r2,
        );
        prop_assert!(
            greedy.positions.iter().all(|p| pd.positions.contains(p)),
            "PD must include the greedy position"
        );
        prop_assert!(pd.positions.len() >= 1, "PD unmasks at least one");
        Ok(())
    });
}

#[test]
fn prop_kv_scatter_roundtrip_random_blocks() {
    check("kv-scatter-roundtrip", 60, |g| {
        let d = dims(g);
        let batch = g.usize_in(1, 2);
        let mut c = GroupCaches::new(&d, batch);
        let block = *g.pick(&[2usize, 4]);
        let block_start = d.prompt_len + g.usize_in(0, d.gen_len - block);
        let n = d.n_layers * 2 * batch * d.n_kv_heads * block * d.head_dim;
        let data: Vec<u16> = (0..n).map(|_| g.rng.next64() as u16).collect();
        let t = HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, batch, d.n_kv_heads, block, d.head_dim],
            data: data.clone(),
        };
        c.scatter_kv_block(block_start, block, &t).map_err(|e| e.to_string())?;
        // kv_tensor must contain exactly those rows at the block offset
        let full = c.kv_tensor();
        let full_data = full.as_bf16().map_err(|e| e.to_string())?;
        let mut src = 0;
        for l in 0..d.n_layers {
            for s in 0..2 {
                for b in 0..batch {
                    for h in 0..d.n_kv_heads {
                        let off = ((((l * 2 + s) * batch + b) * d.n_kv_heads
                            + h)
                            * d.ctx
                            + block_start)
                            * d.head_dim;
                        let rows = block * d.head_dim;
                        prop_assert!(
                            full_data[off..off + rows] == data[src..src + rows],
                            "block rows mismatch at l{l} s{s} b{b} h{h}"
                        );
                        src += rows;
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ind_gather_scatter_consistent() {
    check("ind-gather-scatter", 60, |g| {
        let d = dims(g);
        let batch = 1;
        let mut c = GroupCaches::new(&d, batch);
        let layers: Vec<usize> = (0..d.n_layers).filter(|_| g.bool()).collect();
        let layers = if layers.is_empty() { vec![0] } else { layers };
        let block = 4;
        let block_start = d.prompt_len + if g.bool() { 0 } else { 4 };
        let n = layers.len() * batch * block * d.d_model;
        let data: Vec<u16> = (0..n).map(|_| g.rng.next64() as u16).collect();
        let t = HostTensor::Bf16 {
            shape: vec![layers.len(), batch, block, d.d_model],
            data: data.clone(),
        };
        c.scatter_ind_block("h", &layers, block_start, block, &t)
            .map_err(|e| e.to_string())?;
        let gathered = c.gather_ind("h", &layers).map_err(|e| e.to_string())?;
        let gd = gathered.as_bf16().map_err(|e| e.to_string())?;
        let g0 = block_start - d.prompt_len;
        for (i, _l) in layers.iter().enumerate() {
            for j in 0..block {
                let src = (i * block + j) * d.d_model;
                let dst = (i * d.gen_len + g0 + j) * d.d_model;
                prop_assert!(
                    gd[dst..dst + d.d_model] == data[src..src + d.d_model],
                    "row {i}/{j} mismatch"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_refresh_policy_prefill_at_block_start() {
    check("refresh-plan", 200, |g| {
        let p = RefreshPolicy {
            prompt_period: g.usize_in(1, 64),
            block_period: g.usize_in(1, 16),
        };
        let g_iter = g.usize_in(0, 200);
        let i_b = g.usize_in(0, 31);
        let plan = p.plan_es(g_iter, i_b);
        if i_b == 0 {
            prop_assert!(plan == StepPlan::Prefill, "block start must prefill");
        }
        if plan == StepPlan::EsStep {
            prop_assert!(i_b % p.block_period != 0 || p.block_period == 0,
                "es step only off the block-refresh cadence");
            prop_assert!(g_iter % p.prompt_period != 0,
                "es step only off the prompt-refresh cadence");
        }
        Ok(())
    });
}

#[test]
fn prop_bf16_roundtrip_via_f32_is_identity() {
    check("bf16-roundtrip", 300, |g| {
        let bits = g.rng.next64() as u16;
        let f = bf16_to_f32(bits);
        if f.is_nan() {
            return Ok(());
        }
        prop_assert!(f32_to_bf16(f) == bits, "bits {bits:#06x} -> {f} -> back");
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    check("json-roundtrip", 150, |g| {
        fn value(g: &mut Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.rng.range(-1_000_000, 1_000_000)) as f64),
                3 => Json::Str(
                    (0..g.usize_in(0, 12))
                        .map(|_| *g.pick(&['a', 'Ω', '"', '\\', '\n', '7']))
                        .collect(),
                ),
                4 => Json::Arr((0..g.usize_in(0, 4))
                    .map(|_| value(g, depth - 1))
                    .collect()),
                _ => Json::Obj(
                    (0..g.usize_in(0, 4))
                        .map(|i| (format!("k{i}"), value(g, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = value(g, 3);
        let text = v.to_string();
        let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
        prop_assert!(parsed == v, "roundtrip failed for {text}");
        Ok(())
    });
}

#[test]
fn prop_merge_step_logits_only_touches_given_positions() {
    check("merge-logits", 80, |g| {
        let d = dims(g);
        let batch = 1;
        let mut c = GroupCaches::new(&d, batch);
        for x in c.logits.iter_mut() {
            *x = 1.0;
        }
        c.recompute_conf();
        let before_logits = c.logits.clone();
        let k = g.usize_in(1, 4);
        let mut pos: Vec<i32> = (0..d.gen_len as i32).collect();
        // random distinct positions
        for i in (1..pos.len()).rev() {
            let j = (g.rng.below(i as u64 + 1)) as usize;
            pos.swap(i, j);
        }
        let pos: Vec<i32> =
            pos[..k].iter().map(|p| p + d.prompt_len as i32).collect();
        let logits = HostTensor::F32 {
            shape: vec![1, k, d.vocab],
            data: g.vec_f32(k * d.vocab, -4.0, 4.0),
        };
        let pos_t = HostTensor::I32 { shape: vec![1, k], data: pos.clone() };
        c.merge_step_logits(&logits, &pos_t).map_err(|e| e.to_string())?;
        for gpos in 0..d.gen_len {
            let touched = pos.contains(&((gpos + d.prompt_len) as i32));
            let row = &c.logits[gpos * d.vocab..(gpos + 1) * d.vocab];
            let brow = &before_logits[gpos * d.vocab..(gpos + 1) * d.vocab];
            if !touched {
                prop_assert!(row == brow, "untouched row {gpos} changed");
            }
        }
        Ok(())
    });
}
