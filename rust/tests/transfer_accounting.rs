//! Transfer-accounting acceptance tests for the resident-cache layer:
//! steady-state ES steps upload no full-KV bytes, a mid-flight admission
//! dirties exactly the admitted slot's rows, and ledger deltas match the
//! dirty bitmaps. Everything runs over the sim backend / the planner
//! directly — no PJRT artifacts required.

use std::time::Instant;

use esdllm::cache::{GroupCaches, RefreshPolicy};
use esdllm::engine::Method;
use esdllm::manifest::Dims;
use esdllm::runtime::resident::{ApplyMode, DeviceGroupCaches, TransferKind, TransferStats};
use esdllm::runtime::tensor::HostTensor;
use esdllm::sampler::SamplerCfg;
use esdllm::scheduler::sim::{SimBackend, SimCfg};
use esdllm::scheduler::{GroupScheduler, SchedCfg, SeqInput, SeqParams};

fn sched(n_slots: usize, block: usize) -> GroupScheduler<'static> {
    let backend = SimBackend::new(SimCfg::default());
    let cfg = SchedCfg {
        method: Method::EsDllm,
        block,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
        sampler: SamplerCfg::llada(),
        seed: 0,
    };
    GroupScheduler::new(Box::new(backend), n_slots, cfg).unwrap()
}

fn input(id: u64, prompt: &str) -> SeqInput {
    SeqInput {
        id,
        prompt: prompt.to_string(),
        params: SeqParams::default(),
        submitted: Instant::now(),
    }
}

fn drain(s: &mut GroupScheduler<'_>) {
    let mut guard = 0;
    while s.active() > 0 {
        s.tick().unwrap();
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
    }
}

#[test]
fn steady_state_es_steps_upload_no_full_kv_bytes() {
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefgh")).unwrap();
    drain(&mut s);
    let stats = s.transfer_stats();
    let kv_full = s.group_caches().kv_bytes() as u64;

    assert_eq!(
        stats.full_kv_uploads, 1,
        "exactly one full-KV upload: the residency seed"
    );
    assert_eq!(
        stats.kv_upload_bytes, kv_full,
        "steady-state steps shipped zero KV bytes past the seed"
    );
    assert!(
        stats.upload_bytes_saved > stats.upload_bytes,
        "residency saved {} B vs {} B shipped — must dominate",
        stats.upload_bytes_saved,
        stats.upload_bytes
    );
    assert!(stats.resident_reuses > 0, "KV input reused across steps");

    // a whole second generation moves no further KV or indicator bytes
    s.admit(input(2, "xyab")).unwrap();
    drain(&mut s);
    let stats2 = s.transfer_stats();
    assert_eq!(stats2.full_kv_uploads, 1);
    assert_eq!(stats2.kv_upload_bytes, kv_full);
    assert_eq!(stats2.ind_upload_bytes, stats.ind_upload_bytes);
}

#[test]
fn admission_dirties_exactly_one_slot() {
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefg")).unwrap();
    s.tick().unwrap(); // grounding prefill
    s.tick().unwrap(); // first step: seeds residency, clears all bitmaps
    let ctx = s.group_caches().dims.ctx;
    assert_eq!(s.group_caches().dirty.kv.count(), 0, "group fully in sync");

    let slot_b = s.admit(input(2, "xy")).unwrap();
    let dirty = &s.group_caches().dirty;
    assert_eq!(dirty.kv.count_slot(slot_b), ctx, "admitted slot invalidated");
    assert_eq!(dirty.kv.count(), ctx, "and nothing else");
    let gen = s.group_caches().dims.gen_len;
    assert_eq!(dirty.conf.count_slot(slot_b), gen);
    for bm in dirty.ind.values() {
        assert_eq!(bm.count_slot(slot_b), gen);
    }

    // the grounding prefill regenerates the slot's rows device-side:
    // the dirty rows drain with zero KV upload
    let before = s.transfer_stats();
    s.tick().unwrap();
    assert_eq!(s.group_caches().dirty.kv.count_slot(slot_b), 0);
    let delta = s.transfer_stats().since(&before);
    assert_eq!(delta.kv_upload_bytes, 0);
    assert_eq!(delta.full_kv_uploads, 0);
    drain(&mut s);
}

#[test]
fn ledger_delta_matches_dirty_bitmap_in_host_apply_mode() {
    // Host-apply (today's PJRT reality): a step's own output scatter
    // leaves its rows dirty, and the next sync re-ships exactly those
    // rows — the ledger delta must equal bitmap-rows × row-bytes.
    let d = Dims {
        vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, n_kv_heads: 1,
        d_ff: 8, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
    };
    let mut c = GroupCaches::new(&d, 2);
    let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Host);
    let slots = [0usize, 1];
    r.sync_kv(&mut c, &slots); // seed

    let block = 2;
    let n = d.n_layers * 2 * 2 * d.n_kv_heads * block * d.head_dim;
    let t = HostTensor::Bf16 {
        shape: vec![d.n_layers, 2, 2, d.n_kv_heads, block, d.head_dim],
        data: vec![3u16; n],
    };
    c.scatter_kv_block_slots(d.prompt_len, block, &t, &slots).unwrap();
    let dirty_rows: usize = slots.iter().map(|&b| c.dirty.kv.count_slot(b)).sum();
    assert_eq!(dirty_rows, 2 * block);

    let snap = r.stats;
    let out = r.sync_kv(&mut c, &slots);
    assert_eq!(out.shipped, (dirty_rows * c.kv_row_bytes()) as u64);
    assert!(out.shipped < out.full, "a delta, not a full re-upload");
    let delta = r.stats.since(&snap);
    assert_eq!(delta.kv_upload_bytes, out.shipped);
    assert_eq!(delta.full_kv_uploads, 0);
    assert_eq!(c.dirty.kv.count(), 0, "sync clears what it ships");
}

#[test]
fn per_kind_counters_split_the_total() {
    let mut s = sched(1, 4);
    s.admit(input(1, "abcd")).unwrap();
    drain(&mut s);
    let st: TransferStats = s.transfer_stats();
    assert_eq!(
        st.upload_bytes,
        st.kv_upload_bytes
            + st.kv_sparse_upload_bytes
            + st.ind_upload_bytes
            + st.conf_upload_bytes
            + st.token_upload_bytes,
        "per-kind counters must partition the total"
    );
    // tokens ship every run; confidence rows ship every step
    assert!(st.token_upload_bytes > 0);
    assert!(st.conf_upload_bytes > 0);
}

#[test]
fn record_classifies_kinds() {
    let mut st = TransferStats::default();
    st.record(TransferKind::Kv, 10, 10);
    st.record(TransferKind::Ind, 0, 8);
    st.record(TransferKind::Conf, 2, 4);
    assert_eq!(st.full_kv_uploads, 1);
    assert_eq!(st.resident_reuses, 1);
    assert_eq!(st.upload_bytes, 12);
    assert_eq!(st.upload_bytes_saved, 10);
    assert_eq!(st.kv_upload_bytes, 10);
    assert_eq!(st.ind_upload_bytes, 0);
    assert_eq!(st.conf_upload_bytes, 2);
}
