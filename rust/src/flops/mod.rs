//! Analytic FLOPs model + roofline estimates (paper §7 and Tables 9–10's
//! "FLOPs Prop." column).
//!
//! Counts matmul FLOPs (2·m·n·k) of the transformer forward per
//! plan kind, accounting for the early-skip active-set sizes per layer.
//! Also models the per-iteration byte traffic of the stateless-executable
//! design, which is this testbed's analog of the paper's memory-bandwidth
//! wall (§7: ES reduces FLOPs but not weight/cache traffic).

use crate::manifest::Dims;

/// FLOPs of one transformer layer over `s` active tokens against a KV
/// context of `t` rows.
fn layer_flops(d: &Dims, s: usize, t: usize) -> f64 {
    let dm = d.d_model as f64;
    let dkv = (d.n_kv_heads * d.head_dim) as f64;
    let ff = d.d_ff as f64;
    let s = s as f64;
    let t = t as f64;
    let qo = 2.0 * s * dm * dm * 2.0;           // Q proj + O proj
    let kv = 2.0 * s * dm * dkv * 2.0;          // K + V proj
    let attn = 2.0 * s * t * dm * 2.0;          // QK^T + PV (all heads)
    let ffn = 2.0 * s * dm * ff * 3.0;          // SwiGLU: gate, up, down
    qo + kv + attn + ffn
}

fn head_flops(d: &Dims, s: usize) -> f64 {
    2.0 * s as f64 * d.d_model as f64 * d.vocab as f64
}

/// Active-set size entering each layer for a skip spec.
pub fn active_sizes(d: &Dims, block: usize, skip: &[(usize, f64)]) -> Vec<usize> {
    let map: std::collections::BTreeMap<usize, f64> = skip.iter().cloned().collect();
    let mut s = block;
    (0..d.n_layers)
        .map(|l| {
            let cur = s;
            if let Some(r) = map.get(&l) {
                s = ((s as f64 * (1.0 - r)).round() as usize).max(1);
            }
            cur
        })
        .collect()
}

/// FLOPs of one full forward over the whole context (prefill / vanilla).
pub fn prefill_flops(d: &Dims) -> f64 {
    let per_layer = layer_flops(d, d.ctx, d.ctx);
    per_layer * d.n_layers as f64 + head_flops(d, d.ctx)
}

/// FLOPs of one block step with the given skip spec and KV length.
pub fn step_flops(d: &Dims, block: usize, skip: &[(usize, f64)], kv_len: usize) -> f64 {
    let sizes = active_sizes(d, block, skip);
    let mut total = 0.0;
    for s in &sizes {
        total += layer_flops(d, *s, kv_len);
    }
    let final_s = {
        let map: std::collections::BTreeMap<usize, f64> = skip.iter().cloned().collect();
        let mut s = block;
        for l in 0..d.n_layers {
            if let Some(r) = map.get(&l) {
                s = ((s as f64 * (1.0 - r)).round() as usize).max(1);
            }
        }
        s
    };
    total + head_flops(d, final_s)
}

/// FLOPs proportion of an ES config vs the DualCache baseline at the same
/// block size — the paper's Table 9 "FLOPs Prop." column.
pub fn flops_proportion(d: &Dims, block: usize, skip: &[(usize, f64)]) -> f64 {
    step_flops(d, block, skip, d.ctx) / step_flops(d, block, &[], d.ctx)
}

/// Whole-run FLOPs given iteration counts by plan kind.
pub fn run_flops(
    d: &Dims,
    block: usize,
    skip: &[(usize, f64)],
    n_prefill: usize,
    n_dual: usize,
    n_es: usize,
) -> f64 {
    n_prefill as f64 * prefill_flops(d)
        + n_dual as f64 * step_flops(d, block, &[], d.ctx)
        + n_es as f64 * step_flops(d, block, skip, d.ctx)
}

// ---------------------------------------------------------------------------
// traffic model (the stateless-executable analog of the paper's §7
// memory-bandwidth analysis)
// ---------------------------------------------------------------------------

/// Bytes streamed per step iteration: params are resident, but the KV and
/// indicator caches are uploaded each call and block slices come back.
pub fn step_traffic_bytes(d: &Dims, block: usize, n_ind: usize, kv_len: usize) -> u64 {
    let kv_up = d.n_layers * 2 * 8 * d.n_kv_heads * kv_len * d.head_dim * 2;
    let ind_up = n_ind * 8 * d.gen_len * d.d_model * 2;
    let conf_up = 8 * d.gen_len * 4;
    let kv_down = d.n_layers * 2 * 8 * d.n_kv_heads * block * d.head_dim * 2;
    let ind_down = n_ind * 8 * block * d.d_model * 2;
    let logits_down = 8 * block * d.vocab * 4;
    (kv_up + ind_up + conf_up + kv_down + ind_down + logits_down) as u64
}

/// Paper §7 memory-overhead analog: bytes of cache state per sequence.
pub fn cache_bytes_per_seq(d: &Dims, n_ind: usize) -> u64 {
    let kv = d.n_layers * 2 * d.n_kv_heads * d.ctx * d.head_dim * 2;
    let ind = n_ind * d.gen_len * d.d_model * 2;
    let logits = d.gen_len * d.vocab * 4;
    (kv + ind + logits) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims {
            vocab: 64, d_model: 128, n_layers: 8, n_heads: 8, n_kv_heads: 8,
            d_ff: 384, head_dim: 16, prompt_len: 48, gen_len: 32, ctx: 80,
        }
    }

    #[test]
    fn skip_reduces_flops_monotonically() {
        let d = dims();
        let none = step_flops(&d, 8, &[], 80);
        let half = step_flops(&d, 8, &[(1, 0.5), (2, 0.5)], 80);
        let more = step_flops(&d, 8, &[(0, 0.9)], 80);
        assert!(half < none);
        assert!(more < half);
    }

    #[test]
    fn default_skip_proportion_in_paper_ballpark() {
        // paper: r4=r8=0.5 at 32 layers → ~40% of DualCache FLOPs.
        // nano (8 layers, skips at 1,2) leaves slightly more early compute,
        // so expect ~40-60%.
        let p = flops_proportion(&dims(), 8, &[(1, 0.5), (2, 0.5)]);
        assert!(p > 0.3 && p < 0.7, "proportion {p}");
    }

    #[test]
    fn active_sizes_follow_spec() {
        let d = dims();
        let sizes = active_sizes(&d, 8, &[(1, 0.5), (2, 0.5)]);
        assert_eq!(sizes, vec![8, 8, 4, 2, 2, 2, 2, 2]);
    }

    #[test]
    fn vanilla_dominates_dual() {
        let d = dims();
        assert!(prefill_flops(&d) > 5.0 * step_flops(&d, 8, &[], 80));
    }

    #[test]
    fn sparse_kv_cuts_traffic() {
        let d = dims();
        let dense = step_traffic_bytes(&d, 8, 2, 80);
        let sparse = step_traffic_bytes(&d, 8, 2, 56);
        assert!(sparse < dense);
    }
}
