//! Transfer-accounting acceptance tests for the resident-cache layer
//! and the device-apply decode path: a steady-state ES/dual tick ships
//! zero KV, indicator, and confidence bytes in either direction (only
//! block tokens + batch-bit masks go up, sampled logit rows come down),
//! the PJRT device planner and the sim planner produce identical
//! `TransferStats` for the same workload, a mid-flight admission
//! dirties exactly the admitted slot, eviction invalidates the resident
//! chain, and Host-apply ledger deltas match the dirty bitmaps.
//! Everything runs over the sim backend / the planner directly — no
//! PJRT artifacts required.

use std::time::Instant;

use esdllm::cache::{GroupCaches, RefreshPolicy};
use esdllm::engine::Method;
use esdllm::manifest::Dims;
use esdllm::runtime::resident::{ApplyMode, DeviceGroupCaches, TransferKind, TransferStats};
use esdllm::runtime::tensor::HostTensor;
use esdllm::sampler::SamplerCfg;
use esdllm::scheduler::sim::{SimBackend, SimCfg};
use esdllm::scheduler::{GroupScheduler, SchedCfg, SeqInput, SeqParams};

fn sched_with(n_slots: usize, block: usize, sim: SimCfg) -> GroupScheduler<'static> {
    let backend = SimBackend::new(sim);
    let cfg = SchedCfg {
        method: Method::EsDllm,
        block,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
        sampler: SamplerCfg::llada(),
        seed: 0,
    };
    GroupScheduler::new(Box::new(backend), n_slots, cfg).unwrap()
}

fn sched(n_slots: usize, block: usize) -> GroupScheduler<'static> {
    sched_with(n_slots, block, SimCfg::default())
}

fn input(id: u64, prompt: &str) -> SeqInput {
    SeqInput {
        id,
        prompt: prompt.to_string(),
        params: SeqParams::default(),
        submitted: Instant::now(),
    }
}

fn drain(s: &mut GroupScheduler<'_>) {
    let mut guard = 0;
    while s.active() > 0 {
        s.tick().unwrap();
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
    }
}

#[test]
fn steady_state_es_steps_upload_no_full_kv_bytes() {
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefgh")).unwrap();
    drain(&mut s);
    let stats = s.transfer_stats();
    let kv_full = s.group_caches().kv_bytes() as u64;

    assert_eq!(
        stats.full_kv_uploads, 1,
        "exactly one full-KV upload: the residency seed"
    );
    assert_eq!(
        stats.kv_upload_bytes, kv_full,
        "steady-state steps shipped zero KV bytes past the seed"
    );
    assert!(
        stats.upload_bytes_saved > stats.upload_bytes,
        "residency saved {} B vs {} B shipped — must dominate",
        stats.upload_bytes_saved,
        stats.upload_bytes
    );
    assert!(stats.resident_reuses > 0, "KV input reused across steps");
    assert!(stats.retained_out_reuses > 0, "outputs chained across calls");
    assert!(stats.ingraph_conf_steps > 0, "steps computed conf in-graph");
    assert!(stats.d2h_bytes_avoided > 0, "cache downloads avoided");

    // a whole second generation moves no further KV, indicator, or
    // confidence bytes — the chain persists across retirements
    s.admit(input(2, "xyab")).unwrap();
    drain(&mut s);
    let stats2 = s.transfer_stats();
    assert_eq!(stats2.full_kv_uploads, 1);
    assert_eq!(stats2.kv_upload_bytes, kv_full);
    assert_eq!(stats2.ind_upload_bytes, stats.ind_upload_bytes);
    assert_eq!(stats2.conf_upload_bytes, stats.conf_upload_bytes);
}

/// The PR's acceptance criterion: with `ApplyMode::Device`, once the
/// chain is seeded every ES/dual tick ships ONLY step tokens (plus the
/// batch-bit occupancy mask) host→device and zero KV / indicator /
/// confidence bytes in either direction.
#[test]
fn device_steady_state_ships_only_tokens_and_masks() {
    let d = SimCfg::default().dims;
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefgh")).unwrap();
    s.tick().unwrap(); // grounding prefill: seeds the chain
    let batch = 2u64;

    let mut steady_ticks = 0;
    let mut guard = 0;
    while s.active() > 0 {
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
        let plans_before = s.n_prefill;
        let before = s.transfer_stats();
        s.tick().unwrap();
        let delta = s.transfer_stats().since(&before);
        if s.n_prefill > plans_before {
            // refresh-cadence prefill ticks chain too (zero cache bytes)
            assert_eq!(delta.kv_upload_bytes, 0);
            continue;
        }
        steady_ticks += 1;
        assert_eq!(delta.kv_upload_bytes, 0, "no KV bytes up");
        assert_eq!(delta.kv_sparse_upload_bytes, 0);
        assert_eq!(delta.ind_upload_bytes, 0, "no indicator bytes up");
        assert_eq!(delta.conf_upload_bytes, 0, "no confidence bytes up");
        assert_eq!(delta.full_kv_uploads, 0);
        // exactly one step ran this tick: block tokens for the stepped
        // slot + the [B] occupancy mask, nothing else
        let expected = 4 * 4 + batch * 4;
        assert_eq!(delta.token_upload_bytes, expected);
        assert_eq!(delta.upload_bytes, expected, "tokens+mask are ALL traffic");
        assert_eq!(delta.ingraph_conf_steps, 1);
        assert_eq!(delta.retained_out_reuses, 3, "kv+ind+conf all chained");
        assert!(delta.d2h_bytes_avoided > 0, "block downloads avoided");
    }
    assert!(steady_ticks >= 2, "workload exercised steady-state steps");
    // sanity: geometry used above matches the sim dims
    assert_eq!(d.gen_len % 4, 0);
}

/// Byte-exact parity: the call sequence `PjrtBackend` makes on the
/// device-apply path (sync_prefill_device / sync_step_device +
/// note_*_applied, per its plan schedule) must produce the identical
/// `TransferStats` ledger as the sim backend run through the scheduler
/// on the same workload — both backends route through the same
/// composite planner, and this pins that contract.
#[test]
fn pjrt_device_planner_matches_sim_planner() {
    // sim side: one 3-char prompt at block 4 retires after exactly
    // 4 iterations of block 0 (EOS-guard) with plans [Prefill, Es,
    // Dual, Es]
    let mut s = sched(2, 4);
    s.admit(input(1, "abc")).unwrap();
    drain(&mut s);
    assert_eq!((s.n_prefill, s.n_dual, s.n_es), (1, 1, 2), "plan schedule");
    assert_eq!(s.ticks, 4);
    let sim_stats = s.transfer_stats();

    // PJRT planner side: replicate that schedule through the planner
    // calls prefill_device_impl / step_device_impl make
    let d = SimCfg::default().dims;
    let mut c = GroupCaches::new(&d, 2);
    let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
    let tokens = vec![0i32; 2 * d.ctx];
    let slots = [0usize];
    c.reset_slot(0); // admission
    r.sync_prefill_device(&mut c, "h", &tokens, &slots).unwrap();
    r.note_prefill_applied(&mut c, &slots);
    for _ in 0..3 {
        r.sync_step_device(&mut c, "h", d.n_layers, &tokens, d.prompt_len, 4, &slots)
            .unwrap();
        r.note_step_applied(&mut c, "h", false, d.prompt_len, 4, &slots);
    }
    assert_eq!(
        r.stats, sim_stats,
        "PJRT device planner and sim planner ledgers must be byte-exact"
    );
}

#[test]
fn admission_dirties_exactly_one_slot() {
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefg")).unwrap();
    s.tick().unwrap(); // grounding prefill seeds the chain, clears bitmaps
    s.tick().unwrap(); // first step chains retained outputs
    let ctx = s.group_caches().dims.ctx;
    assert_eq!(s.group_caches().dirty.kv.count(), 0, "group fully in sync");

    let slot_b = s.admit(input(2, "xy")).unwrap();
    let dirty = &s.group_caches().dirty;
    assert_eq!(dirty.kv.count_slot(slot_b), ctx, "admitted slot invalidated");
    assert_eq!(dirty.kv.count(), ctx, "and nothing else");
    let gen = s.group_caches().dims.gen_len;
    assert_eq!(dirty.conf.count_slot(slot_b), gen);
    for bm in dirty.ind.values() {
        assert_eq!(bm.count_slot(slot_b), gen);
    }

    // the grounding prefill regenerates the slot's rows device-side:
    // the dirty rows drain with zero KV upload
    let before = s.transfer_stats();
    s.tick().unwrap();
    assert_eq!(s.group_caches().dirty.kv.count_slot(slot_b), 0);
    let delta = s.transfer_stats().since(&before);
    assert_eq!(delta.kv_upload_bytes, 0);
    assert_eq!(delta.full_kv_uploads, 0);
    drain(&mut s);
}

/// Regression (device-apply eviction): `evict_all` must invalidate the
/// resident chain — drop retained handles, reset seeded state, mark the
/// host mirrors dirty — so a sequence admitted after an eviction
/// re-grounds from a fresh seed instead of stepping against the evicted
/// group's stale device copy.
#[test]
fn evict_all_invalidates_resident_chain() {
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefgh")).unwrap();
    s.tick().unwrap(); // seed
    s.tick().unwrap(); // steady-state step
    assert_eq!(s.group_caches().dirty.kv.count(), 0);

    s.evict_all();
    assert_eq!(s.active(), 0);
    let d = s.group_caches().dims;
    assert_eq!(
        s.group_caches().dirty.kv.count(),
        2 * d.ctx,
        "eviction takes back the whole device-residency promise"
    );
    for bm in s.group_caches().dirty.ind.values() {
        assert_eq!(bm.count(), 2 * d.gen_len);
    }

    // a re-admitted sequence must run exactly (a second seed, then the
    // usual zero-byte steady state) and still decode correctly
    s.admit(input(7, "xy")).unwrap();
    let mut out = Vec::new();
    let mut guard = 0;
    while s.active() > 0 {
        out.extend(s.tick().unwrap());
        guard += 1;
        assert!(guard < 1000);
    }
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].text, "xy", "post-eviction decode is exact");
    let stats = s.transfer_stats();
    assert_eq!(stats.full_kv_uploads, 2, "the re-ground re-seeded the chain");
}

#[test]
fn ledger_delta_matches_dirty_bitmap_in_host_apply_mode() {
    // Host-apply (the stateless-executable fallback): a step's own
    // output scatter leaves its rows dirty, and the next sync re-ships
    // exactly those rows — the ledger delta must equal
    // bitmap-rows × row-bytes.
    let d = Dims {
        vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, n_kv_heads: 1,
        d_ff: 8, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
    };
    let mut c = GroupCaches::new(&d, 2);
    let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Host);
    let slots = [0usize, 1];
    r.sync_kv(&mut c, &slots); // seed

    let block = 2;
    let n = d.n_layers * 2 * 2 * d.n_kv_heads * block * d.head_dim;
    let t = HostTensor::Bf16 {
        shape: vec![d.n_layers, 2, 2, d.n_kv_heads, block, d.head_dim],
        data: vec![3u16; n],
    };
    c.scatter_kv_block_slots(d.prompt_len, block, &t, &slots).unwrap();
    let dirty_rows: usize = slots.iter().map(|&b| c.dirty.kv.count_slot(b)).sum();
    assert_eq!(dirty_rows, 2 * block);

    let snap = r.stats;
    let out = r.sync_kv(&mut c, &slots);
    assert_eq!(out.shipped, (dirty_rows * c.kv_row_bytes()) as u64);
    assert!(out.shipped < out.full, "a delta, not a full re-upload");
    let delta = r.stats.since(&snap);
    assert_eq!(delta.kv_upload_bytes, out.shipped);
    assert_eq!(delta.full_kv_uploads, 0);
    assert_eq!(c.dirty.kv.count(), 0, "sync clears what it ships");
}

/// The Host-apply sim models the stateless fallback end to end: its
/// steps re-ship their own scattered rows as deltas, so it uploads
/// strictly more than the device-apply chain on the same workload —
/// and still decodes identically.
#[test]
fn host_apply_sim_reships_deltas_and_decodes_identically() {
    let mut dev = sched(2, 4);
    dev.admit(input(1, "abcdef")).unwrap();
    let mut dev_out = Vec::new();
    let mut guard = 0;
    while dev.active() > 0 {
        dev_out.extend(dev.tick().unwrap());
        guard += 1;
        assert!(guard < 1000);
    }

    let mut host = sched_with(2, 4, SimCfg::default().with_apply(ApplyMode::Host));
    host.admit(input(1, "abcdef")).unwrap();
    let mut host_out = Vec::new();
    guard = 0;
    while host.active() > 0 {
        host_out.extend(host.tick().unwrap());
        guard += 1;
        assert!(guard < 1000);
    }

    assert_eq!(dev_out[0].text, host_out[0].text, "apply mode is transparent");
    assert_eq!(dev_out[0].iterations, host_out[0].iterations);

    let ds = dev.transfer_stats();
    let hs = host.transfer_stats();
    assert!(
        hs.kv_upload_bytes > ds.kv_upload_bytes,
        "host-apply re-ships KV deltas ({} B) that device-apply chains ({} B)",
        hs.kv_upload_bytes,
        ds.kv_upload_bytes
    );
    assert!(hs.conf_upload_bytes > ds.conf_upload_bytes);
    assert!(ds.d2h_bytes_avoided > 0);
    assert_eq!(hs.retained_out_reuses, 0, "no chaining in host mode");
}

#[test]
fn per_kind_counters_split_the_total() {
    let mut s = sched(1, 4);
    s.admit(input(1, "abcd")).unwrap();
    drain(&mut s);
    let st: TransferStats = s.transfer_stats();
    assert_eq!(
        st.upload_bytes,
        st.kv_upload_bytes
            + st.kv_sparse_upload_bytes
            + st.ind_upload_bytes
            + st.conf_upload_bytes
            + st.token_upload_bytes,
        "per-kind counters must partition the total"
    );
    // tokens (and the batch-bit masks) ship every run; kv/ind/conf ship
    // exactly once — the chain seed
    assert!(st.token_upload_bytes > 0);
    let conf_seed = (s.group_caches().dims.gen_len * 4) as u64; // batch 1
    assert_eq!(st.conf_upload_bytes, conf_seed);
}

#[test]
fn record_classifies_kinds() {
    let mut st = TransferStats::default();
    st.record(TransferKind::Kv, 10, 10);
    st.record(TransferKind::Ind, 0, 8);
    st.record(TransferKind::Conf, 2, 4);
    assert_eq!(st.full_kv_uploads, 1);
    assert_eq!(st.resident_reuses, 1);
    assert_eq!(st.upload_bytes, 12);
    assert_eq!(st.upload_bytes_saved, 10);
    assert_eq!(st.kv_upload_bytes, 10);
    assert_eq!(st.ind_upload_bytes, 0);
    assert_eq!(st.conf_upload_bytes, 2);
}
