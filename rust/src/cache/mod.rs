//! Decode-state caches for one batched sequence group, plus the refresh
//! scheduler (paper §5.2, Table 5).
//!
//! Host-owned state (bf16 raw bits for KV/indicator, f32 for
//! logits/confidence) that streams through the stateless step executables:
//!
//!   * KV cache            [L, 2, B, Hkv, T, hd]  (T = ctx, or pruned)
//!   * indicator caches    per indicator: [L, B, gen, d] — all layers so
//!                         any skip config can be served from one prefill
//!   * latest logits       [B, gen, V] and confidence [B, gen]
//!
//! The step executable returns only the *block slice* of updated KV and
//! indicator rows; [`GroupCaches::scatter_kv_block`] folds those back in.
//!
//! Every mutating op additionally marks the touched rows in a per-kind
//! [`DirtyState`] (per-slot × per-position bitmaps). The resident-cache
//! layer ([`crate::runtime::resident::DeviceGroupCaches`]) consumes those
//! bitmaps to decide which rows actually need re-syncing to the device
//! before the next executable run — steady-state steps whose outputs were
//! applied device-side re-upload nothing. The `tok` bitmap tracks the
//! context-token rows the same way for the fused path's fourth chained
//! tensor (`x_tok` stays device-resident across fused dispatches;
//! admissions and host-applied commits re-dirty exactly the rows they
//! rewrote).
//!
//! # Cross-request prefix reuse
//!
//! The prompt-region KV rows of a slot are a pure function of its prompt
//! tokens under the deterministic grounding prefill, which makes them
//! *relocatable*: [`GroupCaches::extract_prefix_rows`] copies the first
//! `p` context rows (all layers, K and V, all heads) of a retiring slot
//! out into a flat payload keyed by the prompt prefix, and
//! [`GroupCaches::merge_prefix_rows`] copies such a payload into a newly
//! admitted slot, marking (never clearing) the seeded rows' dirty bits —
//! the prefix seed is host-originated state the resident layer has not
//! seen. Prefix lengths are block-aligned by the callers so the
//! suffix-only prefill composes with the per-slot prefill-merge above.
//! The cross-request cache itself (keying, LRU-by-bytes eviction, hit
//! ledger) lives in [`crate::runtime::resident::PrefixCache`]; the
//! admission probe sits in the scheduler.

use anyhow::{anyhow, Result};

use crate::manifest::Dims;
use crate::runtime::tensor::{HostTensor, ShapeVec, TensorView};

/// Per-slot × per-position dirty bitmap for one cache kind. A "row" is
/// one (slot, position) pair spanning every layer/head — exactly the
/// granularity at which the scatter/reset/prefill-merge ops write, so a
/// bit set here means "the host copy of this row diverged from the
/// resident device copy".
#[derive(Debug, Clone)]
pub struct DirtyBitmap {
    slots: usize,
    positions: usize,
    words: Vec<u64>,
}

impl DirtyBitmap {
    /// All rows marked: the honest initial state (nothing is resident on
    /// the device yet, so everything would need a first upload).
    pub fn new_marked(slots: usize, positions: usize) -> DirtyBitmap {
        let mut bm = DirtyBitmap::new_clean(slots, positions);
        for s in 0..slots {
            bm.mark_range(s, 0, positions);
        }
        bm
    }

    pub fn new_clean(slots: usize, positions: usize) -> DirtyBitmap {
        let bits = slots * positions;
        DirtyBitmap { slots, positions, words: vec![0u64; bits.div_ceil(64)] }
    }

    pub fn n_slots(&self) -> usize {
        self.slots
    }

    pub fn positions(&self) -> usize {
        self.positions
    }

    fn bit(&self, slot: usize, pos: usize) -> usize {
        slot * self.positions + pos
    }

    /// Clamped absolute bit span of (slot, lo..hi).
    fn span(&self, slot: usize, lo: usize, hi: usize) -> (usize, usize) {
        let hi = hi.min(self.positions);
        let lo = lo.min(hi);
        (slot * self.positions + lo, slot * self.positions + hi)
    }

    /// Word-sized mask covering bits [i, i+take) within word i/64, where
    /// `take` never crosses the word boundary.
    fn word_mask(bit: usize, take: usize) -> u64 {
        if take == 64 {
            !0u64
        } else {
            ((1u64 << take) - 1) << bit
        }
    }

    pub fn mark_range(&mut self, slot: usize, lo: usize, hi: usize) {
        let (mut i, end) = self.span(slot, lo, hi);
        while i < end {
            let bit = i % 64;
            let take = (64 - bit).min(end - i);
            self.words[i / 64] |= Self::word_mask(bit, take);
            i += take;
        }
    }

    pub fn clear_range(&mut self, slot: usize, lo: usize, hi: usize) {
        let (mut i, end) = self.span(slot, lo, hi);
        while i < end {
            let bit = i % 64;
            let take = (64 - bit).min(end - i);
            self.words[i / 64] &= !Self::word_mask(bit, take);
            i += take;
        }
    }

    /// Dirty rows within (slot, lo..hi), counted a word at a time.
    pub fn count_range(&self, slot: usize, lo: usize, hi: usize) -> usize {
        let (mut i, end) = self.span(slot, lo, hi);
        let mut n = 0usize;
        while i < end {
            let bit = i % 64;
            let take = (64 - bit).min(end - i);
            n += (self.words[i / 64] & Self::word_mask(bit, take)).count_ones() as usize;
            i += take;
        }
        n
    }

    pub fn mark_slot(&mut self, slot: usize) {
        self.mark_range(slot, 0, self.positions);
    }

    pub fn clear_slot(&mut self, slot: usize) {
        self.clear_range(slot, 0, self.positions);
    }

    pub fn clear_all(&mut self) {
        for w in self.words.iter_mut() {
            *w = 0;
        }
    }

    pub fn get(&self, slot: usize, pos: usize) -> bool {
        let i = self.bit(slot, pos);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Dirty rows of one slot.
    pub fn count_slot(&self, slot: usize) -> usize {
        self.count_range(slot, 0, self.positions)
    }

    /// Dirty rows across the whole bitmap.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn any(&self) -> bool {
        self.words.iter().any(|&w| w != 0)
    }
}

/// Dirty bitmaps per cache kind. KV and token rows index the context
/// positions; indicator/confidence rows index the gen-region positions;
/// the sparse bitmap (created with the sparse cache) indexes the pruned
/// rows.
#[derive(Debug, Clone)]
pub struct DirtyState {
    pub kv: DirtyBitmap,
    pub kv_sparse: Option<DirtyBitmap>,
    pub ind: std::collections::BTreeMap<&'static str, DirtyBitmap>,
    pub conf: DirtyBitmap,
    /// host-vs-device divergence of the context-token rows — the fused
    /// path's fourth chained tensor. Admission resets and host-applied
    /// unmask commits mark; the fused sync planner ships-and-clears
    /// (fused device commits advance the chained copy in-graph, so they
    /// never mark)
    pub tok: DirtyBitmap,
}

impl DirtyState {
    fn new(dims: &Dims, batch: usize) -> DirtyState {
        DirtyState {
            kv: DirtyBitmap::new_marked(batch, dims.ctx),
            kv_sparse: None,
            ind: INDICATORS
                .iter()
                .map(|i| (*i, DirtyBitmap::new_marked(batch, dims.gen_len)))
                .collect(),
            conf: DirtyBitmap::new_marked(batch, dims.gen_len),
            tok: DirtyBitmap::new_marked(batch, dims.ctx),
        }
    }

    /// Mark every row of every kind dirty: the full host-vs-device
    /// divergence, used when a resident chain is invalidated or evicted
    /// — the next syncs must treat nothing as already on the device.
    pub fn mark_all(&mut self) {
        for s in 0..self.kv.n_slots() {
            self.kv.mark_slot(s);
            for bm in self.ind.values_mut() {
                bm.mark_slot(s);
            }
            self.conf.mark_slot(s);
            self.tok.mark_slot(s);
            if let Some(bm) = self.kv_sparse.as_mut() {
                bm.mark_slot(s);
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct GroupCaches {
    pub dims: Dims,
    pub batch: usize,
    /// dense KV cache [L, 2, B, Hkv, ctx, hd] (bf16 bits)
    pub kv: Vec<u16>,
    /// pruned KV cache for sparse attention [L, 2, B, Hkv, keep_len, hd]
    pub kv_sparse: Option<SparseKv>,
    /// indicator caches by name ("h", "q", "k", "v"): [L, B, gen, d]
    pub ind: std::collections::BTreeMap<&'static str, Vec<u16>>,
    /// latest logits per gen position [B, gen, V]
    pub logits: Vec<f32>,
    /// latest confidence per gen position [B, gen]
    pub conf: Vec<f32>,
    /// host-vs-resident divergence, maintained by every mutating op
    pub dirty: DirtyState,
}

#[derive(Debug, Clone)]
pub struct SparseKv {
    /// [L, 2, B, Hkv, keep_len, hd] bf16 bits
    pub kv: Vec<u16>,
    /// retained prompt rows per batch element [B, keep_prompt] (sorted)
    pub keep_idx: Vec<Vec<usize>>,
    pub keep_prompt: usize,
}

pub const INDICATORS: [&str; 4] = ["h", "q", "k", "v"];

impl GroupCaches {
    pub fn new(dims: &Dims, batch: usize) -> GroupCaches {
        let d = dims;
        let kv_len = d.n_layers * 2 * batch * d.n_kv_heads * d.ctx * d.head_dim;
        let ind_len = d.n_layers * batch * d.gen_len * d.d_model;
        GroupCaches {
            dims: *d,
            batch,
            kv: vec![0; kv_len],
            kv_sparse: None,
            ind: INDICATORS.iter().map(|i| (*i, vec![0u16; ind_len])).collect(),
            logits: vec![0.0; batch * d.gen_len * d.vocab],
            conf: vec![0.0; batch * d.gen_len],
            dirty: DirtyState::new(d, batch),
        }
    }

    // -- transfer-size helpers ---------------------------------------------

    /// Bytes of one dense-KV row (one (slot, t) pair across all layers,
    /// both K and V, all heads).
    pub fn kv_row_bytes(&self) -> usize {
        self.dims.n_layers * 2 * self.dims.n_kv_heads * self.dims.head_dim * 2
    }

    /// Bytes of the whole dense KV tensor.
    pub fn kv_bytes(&self) -> usize {
        self.kv.len() * 2
    }

    /// Bytes of one pruned-KV row (same layout as the dense row).
    pub fn kv_sparse_row_bytes(&self) -> usize {
        self.kv_row_bytes()
    }

    pub fn kv_sparse_bytes(&self) -> usize {
        self.kv_sparse.as_ref().map(|sp| sp.kv.len() * 2).unwrap_or(0)
    }

    /// Bytes of one gathered-indicator row ((slot, gen-pos) across the
    /// `n_ind` gathered layers).
    pub fn ind_row_bytes(&self, n_ind: usize) -> usize {
        n_ind * self.dims.d_model * 2
    }

    // -- index helpers ----------------------------------------------------

    /// offset into the dense KV cache at (layer, k_or_v, b, h, t, 0)
    fn kv_off(&self, t_len: usize, l: usize, s: usize, b: usize, h: usize, t: usize) -> usize {
        let d = &self.dims;
        ((((l * 2 + s) * self.batch + b) * d.n_kv_heads + h) * t_len + t) * d.head_dim
    }

    fn all_slots(&self) -> Vec<usize> {
        (0..self.batch).collect()
    }

    // -- refresh from a prefill pass ---------------------------------------

    /// Overwrite all caches from prefill outputs
    /// (logits, kv, ind_h, ind_q, ind_k, ind_v, attn_mass).
    pub fn refresh_from_prefill(&mut self, outputs: &[HostTensor]) -> Result<()> {
        let slots = self.all_slots();
        self.refresh_slots_from_prefill(outputs, &slots)
    }

    /// Slot-lifecycle variant: merge prefill outputs into the given batch
    /// rows only. The continuous-batching scheduler uses this so that a
    /// grounding prefill for newly admitted sequences (or a per-slot
    /// prompt refresh) never perturbs the decode trajectory of the other
    /// occupants — batch rows are independent sequences, so a row-filtered
    /// merge is exact.
    pub fn refresh_slots_from_prefill(
        &mut self,
        outputs: &[HostTensor],
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims;
        // `prefill_b*` now emits the gen-region slice (`logits_gen`
        // [B, gen, V] — the host only ever read the gen rows, so the
        // prompt-region rows stay off the bus); older artifact sets
        // still ship the full [B, ctx, V] context. The logit output's
        // second dimension says which contract this artifact follows.
        let lg_shape = outputs[0].shape();
        if lg_shape.len() == 3 && lg_shape[1] == d.gen_len {
            self.merge_gen_logits_slots(&outputs[0], slots)?;
        } else {
            self.merge_full_logits_slots(&outputs[0], slots)?;
        }
        let kv_src = outputs[1].as_bf16()?;
        let row = d.n_kv_heads * d.ctx * d.head_dim;
        for l in 0..d.n_layers {
            for s in 0..2 {
                for &b in slots {
                    let off = ((l * 2 + s) * self.batch + b) * row;
                    self.kv[off..off + row].copy_from_slice(&kv_src[off..off + row]);
                }
            }
        }
        let ind_row = d.gen_len * d.d_model;
        for (i, name) in INDICATORS.iter().enumerate() {
            let src = outputs[2 + i].as_bf16()?;
            let dst = self.ind.get_mut(name).unwrap();
            for l in 0..d.n_layers {
                for &b in slots {
                    let off = (l * self.batch + b) * ind_row;
                    dst[off..off + ind_row].copy_from_slice(&src[off..off + ind_row]);
                }
            }
        }
        for &b in slots {
            self.dirty.kv.mark_slot(b);
            for bm in self.dirty.ind.values_mut() {
                bm.mark_slot(b);
            }
        }
        Ok(())
    }

    /// Merge full-context logits [B, ctx, V] into the gen-region
    /// latest-logits state for the given slots and refresh their
    /// confidences. The current compile pipeline slices every
    /// full-forward executable (`vanilla_b*`, `prefill_b*`, and the
    /// device-apply prefill) to the gen region in-graph and merges via
    /// [`GroupCaches::merge_gen_logits_slots`]; this full-context path
    /// remains for older artifact sets that predate the `logits_gen`
    /// signature and still pay the prompt-region offset here.
    pub fn merge_full_logits_slots(
        &mut self,
        logits_full: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims;
        let v = d.vocab;
        let src_all = logits_full.as_f32()?;
        for &b in slots {
            for g in 0..d.gen_len {
                let src = (b * d.ctx + d.prompt_len + g) * v;
                let dst = (b * d.gen_len + g) * v;
                self.logits[dst..dst + v].copy_from_slice(&src_all[src..src + v]);
            }
        }
        self.recompute_conf_slots(slots);
        Ok(())
    }

    /// Merge gen-region logits [B, gen, V] (the `logits_gen` output of
    /// the device-apply prefill — same positions, no prompt rows) into
    /// the latest-logits state for the given slots and refresh their
    /// confidences. Row-for-row with the host state, so no full-context
    /// offset arithmetic: the downlink shape IS the storage shape.
    pub fn merge_gen_logits_slots(
        &mut self,
        logits_gen: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims;
        let row = d.gen_len * d.vocab;
        let src_all = logits_gen.as_f32()?;
        if src_all.len() != self.batch * row {
            return Err(anyhow!(
                "gen-region logits have {} elements, want {} ([B, gen, V])",
                src_all.len(),
                self.batch * row
            ));
        }
        for &b in slots {
            self.logits[b * row..(b + 1) * row]
                .copy_from_slice(&src_all[b * row..(b + 1) * row]);
        }
        self.recompute_conf_slots(slots);
        Ok(())
    }

    /// Merge a tier-sliced gen-region logit downlink (`[B, g, V]` with
    /// `g <= gen_len` — the live gen rows of a narrowed context tier)
    /// into the FIRST `g` positions of the refreshed slots' logit state
    /// and refresh those rows' confidences. Positions past the live
    /// region keep their previous state: at that tier they are outside
    /// every scheduled block, so the sampler never reads them.
    pub fn merge_gen_logits_prefix_slots(
        &mut self,
        logits_gen: &HostTensor,
        g: usize,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims;
        let v = d.vocab;
        if g > d.gen_len {
            return Err(anyhow!("live gen rows {g} exceed gen_len {}", d.gen_len));
        }
        let src_all = logits_gen.as_f32()?;
        if src_all.len() != self.batch * g * v {
            return Err(anyhow!(
                "tier-sliced logits have {} elements, want {} ([B, {g}, V])",
                src_all.len(),
                self.batch * g * v
            ));
        }
        for &b in slots {
            for j in 0..g {
                let src = (b * g + j) * v;
                let dst = (b * d.gen_len + j) * v;
                self.logits[dst..dst + v].copy_from_slice(&src_all[src..src + v]);
                self.conf[b * d.gen_len + j] = softmax_max(&self.logits[dst..dst + v]);
            }
            self.dirty.conf.mark_slot(b);
        }
        Ok(())
    }

    /// Merge a **block-sliced** logit downlink (`logits_blk`
    /// [B, block, V] — each slot's current block window, gathered
    /// in-graph by the `prefill_apply_blk*` executables from its
    /// per-slot `blk_start`) into the latest-logits state and refresh
    /// only those rows' confidences. `starts[b]` is slot `b`'s
    /// gen-relative block start (don't-care for non-merged slots). The
    /// gen rows outside the window keep their previous logits/conf —
    /// exactly what the sampler reads, since it only ever decides within
    /// the current block.
    pub fn merge_gen_logits_block_slots(
        &mut self,
        logits_blk: &HostTensor,
        starts: &[usize],
        block: usize,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims;
        let v = d.vocab;
        let src_all = logits_blk.as_f32()?;
        if src_all.len() != self.batch * block * v {
            return Err(anyhow!(
                "block-sliced logits have {} elements, want {} ([B, block, V])",
                src_all.len(),
                self.batch * block * v
            ));
        }
        for &b in slots {
            let g0 = starts[b];
            if g0 + block > d.gen_len {
                return Err(anyhow!(
                    "slot {b}: block window [{g0}, {}) exceeds gen_len {}",
                    g0 + block,
                    d.gen_len
                ));
            }
            for j in 0..block {
                let src = (b * block + j) * v;
                let dst = (b * d.gen_len + g0 + j) * v;
                self.logits[dst..dst + v].copy_from_slice(&src_all[src..src + v]);
                self.conf[b * d.gen_len + g0 + j] =
                    softmax_max(&self.logits[dst..dst + v]);
            }
            self.dirty.conf.mark_slot(b);
        }
        Ok(())
    }

    /// Confidence = max softmax probability per gen position.
    pub fn recompute_conf(&mut self) {
        let slots = self.all_slots();
        self.recompute_conf_slots(&slots);
    }

    pub fn recompute_conf_slots(&mut self, slots: &[usize]) {
        let v = self.dims.vocab;
        let gen = self.dims.gen_len;
        for &b in slots {
            for g in 0..gen {
                let i = b * gen + g;
                let row = &self.logits[i * v..(i + 1) * v];
                self.conf[i] = softmax_max(row);
            }
            // confidence is host-computed from downloaded logits, so a
            // recompute always diverges the resident copy
            self.dirty.conf.mark_slot(b);
        }
    }

    // -- slot lifecycle ------------------------------------------------------

    /// Zero every cache row of one slot so a retiring sequence leaves no
    /// state behind for the next occupant. Host-originated: the slot's
    /// rows are marked dirty across every kind (slot-admission
    /// invalidation — a mid-flight admit dirties exactly the admitted
    /// slot, which the resident layer re-syncs or regenerates).
    pub fn reset_slot(&mut self, b: usize) {
        let d = self.dims;
        let kv_row = d.n_kv_heads * d.ctx * d.head_dim;
        for l in 0..d.n_layers {
            for s in 0..2 {
                let off = ((l * 2 + s) * self.batch + b) * kv_row;
                self.kv[off..off + kv_row].fill(0);
            }
        }
        let ind_row = d.gen_len * d.d_model;
        for cache in self.ind.values_mut() {
            for l in 0..d.n_layers {
                let off = (l * self.batch + b) * ind_row;
                cache[off..off + ind_row].fill(0);
            }
        }
        self.logits[b * d.gen_len * d.vocab..(b + 1) * d.gen_len * d.vocab].fill(0.0);
        self.conf[b * d.gen_len..(b + 1) * d.gen_len].fill(0.0);
        if let Some(sp) = self.kv_sparse.as_mut() {
            let keep_len = sp.keep_prompt + d.gen_len;
            let sp_row = d.n_kv_heads * keep_len * d.head_dim;
            for l in 0..d.n_layers {
                for s in 0..2 {
                    let off = ((l * 2 + s) * self.batch + b) * sp_row;
                    sp.kv[off..off + sp_row].fill(0);
                }
            }
            sp.keep_idx[b].clear();
        }
        self.dirty.kv.mark_slot(b);
        for bm in self.dirty.ind.values_mut() {
            bm.mark_slot(b);
        }
        self.dirty.conf.mark_slot(b);
        self.dirty.tok.mark_slot(b);
        if let Some(bm) = self.dirty.kv_sparse.as_mut() {
            bm.mark_slot(b);
        }
    }

    // -- cross-request prefix reuse -----------------------------------------

    /// Copy out the first `p` context rows of `slot`'s dense KV across
    /// every (layer, K/V, head): the relocatable prefix payload a
    /// retiring slot donates to the cross-request prefix cache. Layout is
    /// row-major over (layer, k_or_v, head, t) with `head_dim` elements
    /// per row — whatever `merge_prefix_rows` expects, and nothing else
    /// reads it. `p` must not exceed the prompt region (prefix KV is only
    /// a pure function of the prompt tokens there).
    pub fn extract_prefix_rows(&self, slot: usize, p: usize) -> Result<Vec<u16>> {
        let d = self.dims;
        if p > d.prompt_len {
            return Err(anyhow!(
                "prefix of {p} rows exceeds the {}-row prompt region",
                d.prompt_len
            ));
        }
        let hd = d.head_dim;
        let mut out = Vec::with_capacity(d.n_layers * 2 * d.n_kv_heads * p * hd);
        for l in 0..d.n_layers {
            for s in 0..2 {
                for h in 0..d.n_kv_heads {
                    let off = self.kv_off(d.ctx, l, s, slot, h, 0);
                    out.extend_from_slice(&self.kv[off..off + p * hd]);
                }
            }
        }
        Ok(out)
    }

    /// Seed `slot`'s first `p` dense-KV context rows from a cached prefix
    /// payload (the inverse of [`GroupCaches::extract_prefix_rows`]) and
    /// mark them dirty — the seed is host-originated state the resident
    /// device copy has not seen, so the bits are marked, never cleared;
    /// the grounding prefill's suffix pass then only regenerates the
    /// unshared tail.
    pub fn merge_prefix_rows(&mut self, slot: usize, p: usize, rows: &[u16]) -> Result<()> {
        let d = self.dims;
        let hd = d.head_dim;
        let want = d.n_layers * 2 * d.n_kv_heads * p * hd;
        if p > d.prompt_len || rows.len() != want {
            return Err(anyhow!(
                "prefix payload has {} elements, want {want} for {p} prompt rows",
                rows.len()
            ));
        }
        let mut src = 0usize;
        for l in 0..d.n_layers {
            for s in 0..2 {
                for h in 0..d.n_kv_heads {
                    let off = self.kv_off(d.ctx, l, s, slot, h, 0);
                    self.kv[off..off + p * hd].copy_from_slice(&rows[src..src + p * hd]);
                    src += p * hd;
                }
            }
        }
        self.dirty.kv.mark_range(slot, 0, p);
        Ok(())
    }

    // -- step-executable I/O ------------------------------------------------

    /// Gather the indicator-cache rows for `layers` into the step input
    /// tensor [n_ind, B, gen, d].
    pub fn gather_ind(&self, indicator: &str, layers: &[usize]) -> Result<HostTensor> {
        let mut out = HostTensor::Bf16 { shape: Vec::new(), data: Vec::new() };
        self.gather_ind_into(indicator, layers, &mut out)?;
        Ok(out)
    }

    /// Pooled variant: gather into a reusable bf16 scratch tensor so the
    /// step path doesn't allocate a fresh vector every iteration.
    pub fn gather_ind_into(
        &self,
        indicator: &str,
        layers: &[usize],
        out: &mut HostTensor,
    ) -> Result<()> {
        let d = &self.dims;
        let src = self
            .ind
            .get(indicator)
            .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?;
        let row = self.batch * d.gen_len * d.d_model;
        let n_ind = layers.len().max(1);
        match out {
            HostTensor::Bf16 { shape, data } => {
                shape.clear();
                shape.extend_from_slice(&[n_ind, self.batch, d.gen_len, d.d_model]);
                data.clear();
                data.reserve(n_ind * row);
                if layers.is_empty() {
                    data.resize(row, 0); // n_ind >= 1 dummy slot
                }
                for &l in layers {
                    data.extend_from_slice(&src[l * row..(l + 1) * row]);
                }
                Ok(())
            }
            _ => Err(anyhow!("gather_ind_into needs a bf16 scratch tensor")),
        }
    }

    /// Scatter a returned indicator block [n_ind, B, block, d] at
    /// `block_start` (absolute) back into the per-layer cache rows.
    pub fn scatter_ind_block(
        &mut self,
        indicator: &str,
        layers: &[usize],
        block_start: usize,
        block: usize,
        t: &HostTensor,
    ) -> Result<()> {
        let slots = self.all_slots();
        self.scatter_ind_block_slots(indicator, layers, block_start, block, t, &slots)
    }

    /// Row-filtered scatter: only the given slots' indicator rows are
    /// updated; spectator rows (slots working a different block, or
    /// vacant) keep their state.
    pub fn scatter_ind_block_slots(
        &mut self,
        indicator: &str,
        layers: &[usize],
        block_start: usize,
        block: usize,
        t: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d_model = self.dims.d_model;
        let gen_len = self.dims.gen_len;
        let batch = self.batch;
        let g0 = block_start - self.dims.prompt_len;
        let data = t.as_bf16()?;
        let dst = self
            .ind
            .get_mut(indicator)
            .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?;
        for (i, &l) in layers.iter().enumerate() {
            for &b in slots {
                for j in 0..block {
                    let src = (((i * batch) + b) * block + j) * d_model;
                    let dstoff = ((l * batch + b) * gen_len + g0 + j) * d_model;
                    dst[dstoff..dstoff + d_model]
                        .copy_from_slice(&data[src..src + d_model]);
                }
            }
        }
        if let Some(bm) = self.dirty.ind.get_mut(indicator) {
            for &b in slots {
                bm.mark_range(b, g0, g0 + block);
            }
        }
        Ok(())
    }

    /// Scatter a returned KV block [L, 2, B, Hkv, block, hd] into the dense
    /// cache at absolute position `block_start`.
    pub fn scatter_kv_block(
        &mut self,
        block_start: usize,
        block: usize,
        t: &HostTensor,
    ) -> Result<()> {
        let slots = self.all_slots();
        self.scatter_kv_block_slots(block_start, block, t, &slots)
    }

    /// Row-filtered variant of [`GroupCaches::scatter_kv_block`].
    pub fn scatter_kv_block_slots(
        &mut self,
        block_start: usize,
        block: usize,
        t: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims;
        let hd = d.head_dim;
        let data = t.as_bf16()?;
        for l in 0..d.n_layers {
            for s in 0..2 {
                for &b in slots {
                    for h in 0..d.n_kv_heads {
                        let src =
                            ((((l * 2 + s) * self.batch + b) * d.n_kv_heads + h) * block) * hd;
                        let dst = self.kv_off(d.ctx, l, s, b, h, block_start);
                        self.kv[dst..dst + block * hd]
                            .copy_from_slice(&data[src..src + block * hd]);
                    }
                }
            }
        }
        for &b in slots {
            self.dirty.kv.mark_range(b, block_start, block_start + block);
        }
        Ok(())
    }

    /// Same, into the pruned sparse cache (block rows live at
    /// `keep_prompt + (block_start - prompt_len)`).
    pub fn scatter_kv_block_sparse(
        &mut self,
        block_start: usize,
        block: usize,
        t: &HostTensor,
    ) -> Result<()> {
        let slots = self.all_slots();
        self.scatter_kv_block_sparse_slots(block_start, block, t, &slots)
    }

    /// Row-filtered variant of [`GroupCaches::scatter_kv_block_sparse`].
    pub fn scatter_kv_block_sparse_slots(
        &mut self,
        block_start: usize,
        block: usize,
        t: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims;
        let batch = self.batch;
        let hd = d.head_dim;
        let data = t.as_bf16()?;
        let sp = self.kv_sparse.as_mut().ok_or_else(|| anyhow!("no sparse cache"))?;
        let keep_len = sp.keep_prompt + d.gen_len;
        let row0 = sp.keep_prompt + (block_start - d.prompt_len);
        for l in 0..d.n_layers {
            for s in 0..2 {
                for &b in slots {
                    for h in 0..d.n_kv_heads {
                        let src =
                            ((((l * 2 + s) * batch + b) * d.n_kv_heads + h) * block) * hd;
                        let dst = ((((l * 2 + s) * batch + b) * d.n_kv_heads + h)
                            * keep_len
                            + row0)
                            * hd;
                        sp.kv[dst..dst + block * hd]
                            .copy_from_slice(&data[src..src + block * hd]);
                    }
                }
            }
        }
        if let Some(bm) = self.dirty.kv_sparse.as_mut() {
            for &b in slots {
                bm.mark_range(b, row0, row0 + block);
            }
        }
        Ok(())
    }

    /// Merge computed logits (`logits` [B, k, V] at absolute positions
    /// `pos` [B, k]) into the latest-logits state and refresh confidences
    /// for those positions. Skipped positions keep their stale
    /// logits/confidence — exactly the paper's reuse semantics.
    pub fn merge_step_logits(&mut self, logits: &HostTensor, pos: &HostTensor) -> Result<()> {
        let slots = self.all_slots();
        self.merge_step_logits_slots(logits, pos, &slots)
    }

    /// Row-filtered variant of [`GroupCaches::merge_step_logits`]: the
    /// scheduler applies a step's logits only to the slots that were
    /// actually working the stepped block.
    pub fn merge_step_logits_slots(
        &mut self,
        logits: &HostTensor,
        pos: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = &self.dims;
        let v = d.vocab;
        let lg = logits.as_f32()?;
        let ps = pos.as_i32()?;
        let k = logits.shape()[1];
        let gen_len = d.gen_len;
        let prompt_len = d.prompt_len;
        for &b in slots {
            for j in 0..k {
                let p = ps[b * k + j] as usize;
                let g = p - prompt_len;
                let dst = (b * gen_len + g) * v;
                let src = (b * k + j) * v;
                self.logits[dst..dst + v].copy_from_slice(&lg[src..src + v]);
                self.conf[b * gen_len + g] = softmax_max(&lg[src..src + v]);
                self.dirty.conf.mark_range(b, g, g + 1);
            }
        }
        Ok(())
    }

    pub fn kv_tensor(&self) -> HostTensor {
        let d = &self.dims;
        HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, self.batch, d.n_kv_heads, d.ctx, d.head_dim],
            data: self.kv.clone(),
        }
    }

    /// Zero-copy view of the dense KV cache for uploads (replaces the
    /// full-tensor clone [`GroupCaches::kv_tensor`] on the step path).
    pub fn kv_view(&self) -> TensorView<'_> {
        let d = &self.dims;
        TensorView::Bf16 {
            shape: ShapeVec::from_slice(&[
                d.n_layers, 2, self.batch, d.n_kv_heads, d.ctx, d.head_dim,
            ]),
            data: &self.kv,
        }
    }

    /// Zero-copy view of one full per-name indicator cache
    /// [L, B, gen, d] (the device-apply chain seed upload — the layer
    /// gather is a device-side op in that mode).
    pub fn ind_view(&self, indicator: &str) -> Result<TensorView<'_>> {
        let d = &self.dims;
        let src = self
            .ind
            .get(indicator)
            .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?;
        Ok(TensorView::Bf16 {
            shape: ShapeVec::from_slice(&[
                d.n_layers, self.batch, d.gen_len, d.d_model,
            ]),
            data: src,
        })
    }

    /// Zero-copy view of the raw confidence state [B, gen] (the
    /// device-apply chain seed upload — unmasked; the occupancy mask is
    /// a batch-bit executable input in that mode).
    pub fn conf_view(&self) -> TensorView<'_> {
        TensorView::F32 {
            shape: ShapeVec::from_slice(&[self.batch, self.dims.gen_len]),
            data: &self.conf,
        }
    }

    /// Zero-copy view of the pruned KV cache.
    pub fn kv_sparse_view(&self) -> Result<TensorView<'_>> {
        let d = &self.dims;
        let sp = self.kv_sparse.as_ref().ok_or_else(|| anyhow!("no sparse cache"))?;
        Ok(TensorView::Bf16 {
            shape: ShapeVec::from_slice(&[
                d.n_layers,
                2,
                self.batch,
                d.n_kv_heads,
                sp.keep_prompt + d.gen_len,
                d.head_dim,
            ]),
            data: &sp.kv,
        })
    }

    pub fn kv_sparse_tensor(&self) -> Result<HostTensor> {
        let d = &self.dims;
        let sp = self.kv_sparse.as_ref().ok_or_else(|| anyhow!("no sparse cache"))?;
        Ok(HostTensor::Bf16 {
            shape: vec![
                d.n_layers,
                2,
                self.batch,
                d.n_kv_heads,
                sp.keep_prompt + d.gen_len,
                d.head_dim,
            ],
            data: sp.kv.clone(),
        })
    }

    pub fn conf_tensor(&self) -> HostTensor {
        HostTensor::F32 {
            shape: vec![self.batch, self.dims.gen_len],
            data: self.conf.clone(),
        }
    }

    /// Confidence input with an occupancy mask applied: rows NOT in
    /// `slots` (vacant slots, or slots working a different block) are
    /// pinned to -1.0, below any real confidence in [0, 1], so they can
    /// never win the in-graph importance selection (I = α·conf +
    /// (1−α)·var, Eq. 1) and the executable's compute budget goes to the
    /// occupants. -1.0 rather than -inf keeps α·conf finite for α = 0.
    pub fn conf_tensor_masked(&self, slots: &[usize]) -> HostTensor {
        let mut out = HostTensor::F32 { shape: Vec::new(), data: Vec::new() };
        self.conf_masked_into(slots, &mut out).expect("f32 scratch");
        out
    }

    /// Pooled variant of [`GroupCaches::conf_tensor_masked`]: rebuild the
    /// occupancy-masked confidence input inside a reusable f32 scratch
    /// tensor.
    pub fn conf_masked_into(&self, slots: &[usize], out: &mut HostTensor) -> Result<()> {
        let gen = self.dims.gen_len;
        match out {
            HostTensor::F32 { shape, data } => {
                shape.clear();
                shape.extend_from_slice(&[self.batch, gen]);
                data.clear();
                data.resize(self.batch * gen, -1.0f32);
                for &b in slots {
                    data[b * gen..(b + 1) * gen]
                        .copy_from_slice(&self.conf[b * gen..(b + 1) * gen]);
                }
                Ok(())
            }
            _ => Err(anyhow!("conf_masked_into needs an f32 scratch tensor")),
        }
    }

    // -- sparse-attention selection (Sparse-dLLM analog) --------------------

    /// Rebuild the pruned KV cache from the dense one: per batch element,
    /// retain the `keep_prompt` prompt rows with the highest
    /// kernel-smoothed attention mass, then all gen rows.
    pub fn rebuild_sparse(
        &mut self,
        attn_mass: &HostTensor,
        keep_prompt: usize,
        smooth_kernel: usize,
    ) -> Result<()> {
        let slots = self.all_slots();
        self.rebuild_sparse_slots(attn_mass, keep_prompt, smooth_kernel, &slots)
    }

    /// Row-filtered sparse rebuild: refresh the pruned rows of the given
    /// slots only, leaving the other occupants' pruned cache untouched
    /// (slot admission under sparse attention).
    pub fn rebuild_sparse_slots(
        &mut self,
        attn_mass: &HostTensor,
        keep_prompt: usize,
        smooth_kernel: usize,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims;
        let mass = attn_mass.as_f32()?;
        let keep_len = keep_prompt + d.gen_len;
        let hd = d.head_dim;
        if self
            .kv_sparse
            .as_ref()
            .map(|sp| sp.keep_prompt != keep_prompt)
            .unwrap_or(true)
        {
            self.kv_sparse = Some(SparseKv {
                kv: vec![0u16; d.n_layers * 2 * self.batch * d.n_kv_heads * keep_len * hd],
                keep_idx: vec![Vec::new(); self.batch],
                keep_prompt,
            });
            // geometry changed: every slot's pruned rows must re-sync
            self.dirty.kv_sparse = Some(DirtyBitmap::new_marked(self.batch, keep_len));
        }
        // the rebuild is host-side compute (top-k over downloaded
        // attention mass against the host dense KV), so the rebuilt
        // slots' pruned rows always diverge from the resident copy
        if let Some(bm) = self.dirty.kv_sparse.as_mut() {
            for &b in slots {
                bm.mark_slot(b);
            }
        }
        let mut keep_by_slot: Vec<(usize, Vec<usize>)> = Vec::with_capacity(slots.len());
        for &b in slots {
            let row = &mass[b * d.ctx..b * d.ctx + d.prompt_len];
            let smoothed = smooth(row, smooth_kernel);
            let mut order: Vec<usize> = (0..d.prompt_len).collect();
            order.sort_by(|&i, &j| smoothed[j].total_cmp(&smoothed[i]));
            let mut keep: Vec<usize> = order[..keep_prompt].to_vec();
            keep.sort();
            keep_by_slot.push((b, keep));
        }
        // split borrow: the dense cache is read while the sparse one is
        // written
        let mut sp = self.kv_sparse.take().unwrap();
        for l in 0..d.n_layers {
            for s in 0..2 {
                for (b, keep) in &keep_by_slot {
                    let b = *b;
                    for h in 0..d.n_kv_heads {
                        let base_dst =
                            (((l * 2 + s) * self.batch + b) * d.n_kv_heads + h) * keep_len;
                        // retained prompt rows
                        for (r, &src_t) in keep.iter().enumerate() {
                            let srco = self.kv_off(d.ctx, l, s, b, h, src_t);
                            let dsto = (base_dst + r) * hd;
                            sp.kv[dsto..dsto + hd]
                                .copy_from_slice(&self.kv[srco..srco + hd]);
                        }
                        // full gen region
                        let srco = self.kv_off(d.ctx, l, s, b, h, d.prompt_len);
                        let dsto = (base_dst + keep_prompt) * hd;
                        sp.kv[dsto..dsto + d.gen_len * hd]
                            .copy_from_slice(&self.kv[srco..srco + d.gen_len * hd]);
                    }
                }
            }
        }
        for (b, keep) in keep_by_slot {
            sp.keep_idx[b] = keep;
        }
        self.kv_sparse = Some(sp);
        Ok(())
    }
}

fn smooth(xs: &[f32], kernel: usize) -> Vec<f32> {
    if kernel <= 1 {
        return xs.to_vec();
    }
    let half = kernel / 2;
    (0..xs.len())
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(xs.len());
            xs[lo..hi].iter().sum::<f32>() / (hi - lo) as f32
        })
        .collect()
}

pub fn softmax_max(row: &[f32]) -> f32 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = row.iter().map(|x| (x - m).exp()).sum();
    1.0 / denom // exp(m - m) / sum = 1/denom
}

// ---------------------------------------------------------------------------
// refresh scheduling (paper Table 5 / 6)
// ---------------------------------------------------------------------------

/// Per-benchmark refresh policy: prompt refresh every `prompt_period`
/// iterations (global), block refresh every `block_period` iterations
/// within a block. A prefill at every block start grounds the new block
/// (DualCache does this implicitly; the periods add the ES cadence).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RefreshPolicy {
    pub prompt_period: usize,
    pub block_period: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPlan {
    /// full forward (prompt refresh / vanilla / block-start grounding)
    Prefill,
    /// full-block step, no skipping (block refresh / DualCache step)
    DualStep,
    /// early-skip step
    EsStep,
}

impl RefreshPolicy {
    /// Decide the compute for (global iteration g, iteration-within-block
    /// i_b) of an ES-dLLM run.
    pub fn plan_es(&self, g: usize, i_b: usize) -> StepPlan {
        if i_b == 0 || (self.prompt_period > 0 && g % self.prompt_period == 0) {
            StepPlan::Prefill
        } else if self.block_period > 0 && i_b % self.block_period == 0 {
            StepPlan::DualStep
        } else {
            StepPlan::EsStep
        }
    }

    /// DualCache baseline: prefill at block start, dual step otherwise.
    pub fn plan_dual(i_b: usize) -> StepPlan {
        if i_b == 0 {
            StepPlan::Prefill
        } else {
            StepPlan::DualStep
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims {
            vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, n_kv_heads: 1,
            d_ff: 8, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
        }
    }

    #[test]
    fn softmax_max_uniform_row() {
        let c = softmax_max(&[0.0, 0.0, 0.0, 0.0]);
        assert!((c - 0.25).abs() < 1e-6);
        let c2 = softmax_max(&[10.0, 0.0, 0.0, 0.0]);
        assert!(c2 > 0.99);
    }

    #[test]
    fn merge_step_logits_updates_only_computed_positions() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 1);
        c.logits.fill(1.0);
        c.recompute_conf();
        let before = c.conf.clone();
        let logits = HostTensor::F32 {
            shape: vec![1, 1, 8],
            data: vec![9.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        };
        let pos = HostTensor::I32 { shape: vec![1, 1], data: vec![5] };
        c.merge_step_logits(&logits, &pos).unwrap();
        assert!(c.conf[1] > 0.9); // gen idx 1 (pos 5 - prompt 4) updated
        assert_eq!(c.conf[0], before[0]);
        assert_eq!(c.logits[(1 * 8) as usize], 9.0);
    }

    #[test]
    fn kv_scatter_block_roundtrip() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 1);
        // block = gen region rows 0..2 at absolute pos 4..6
        let block = 2;
        let n = d.n_layers * 2 * 1 * d.n_kv_heads * block * d.head_dim;
        let data: Vec<u16> = (0..n as u16).collect();
        let t = HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, 1, d.n_kv_heads, block, d.head_dim],
            data: data.clone(),
        };
        c.scatter_kv_block(4, block, &t).unwrap();
        // layer 0, k, b0, h0, t=4..6 should hold rows 0..block
        let off = c.kv_off(d.ctx, 0, 0, 0, 0, 4);
        assert_eq!(&c.kv[off..off + block * d.head_dim], &data[..block * d.head_dim]);
        // untouched region stays zero
        let off2 = c.kv_off(d.ctx, 0, 0, 0, 0, 0);
        assert!(c.kv[off2..off2 + 4 * d.head_dim].iter().all(|&x| x == 0));
    }

    #[test]
    fn sparse_rebuild_retains_top_mass_rows() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 1);
        for (i, v) in c.kv.iter_mut().enumerate() {
            *v = i as u16;
        }
        let mass = HostTensor::F32 {
            shape: vec![1, d.ctx],
            data: vec![0.1, 0.9, 0.8, 0.05, 0.0, 0.0, 0.0, 0.0],
        };
        c.rebuild_sparse(&mass, 2, 1).unwrap();
        let sp = c.kv_sparse.as_ref().unwrap();
        assert_eq!(sp.keep_idx[0], vec![1, 2]);
        let keep_len = 2 + d.gen_len;
        assert_eq!(
            sp.kv.len(),
            d.n_layers * 2 * d.n_kv_heads * keep_len * d.head_dim
        );
        // first retained row equals dense row t=1 of layer0/k/h0
        let src = c.kv_off(d.ctx, 0, 0, 0, 0, 1);
        assert_eq!(&sp.kv[..d.head_dim], &c.kv[src..src + d.head_dim]);
    }

    #[test]
    fn slot_filtered_kv_scatter_leaves_spectators_untouched() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let block = 2;
        let n = d.n_layers * 2 * 2 * d.n_kv_heads * block * d.head_dim;
        let data: Vec<u16> = (1..=n as u16).collect();
        let t = HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, 2, d.n_kv_heads, block, d.head_dim],
            data,
        };
        c.scatter_kv_block_slots(4, block, &t, &[1]).unwrap();
        // slot 0 untouched, slot 1 written
        let off0 = c.kv_off(d.ctx, 0, 0, 0, 0, 4);
        assert!(c.kv[off0..off0 + block * d.head_dim].iter().all(|&x| x == 0));
        let off1 = c.kv_off(d.ctx, 0, 0, 1, 0, 4);
        assert!(c.kv[off1..off1 + block * d.head_dim].iter().any(|&x| x != 0));
    }

    #[test]
    fn slot_filtered_logit_merge_and_reset() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let logits = HostTensor::F32 {
            shape: vec![2, 1, 8],
            data: vec![
                9.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // slot 0 row
                7.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // slot 1 row
            ],
        };
        let pos = HostTensor::I32 { shape: vec![2, 1], data: vec![4, 4] };
        c.merge_step_logits_slots(&logits, &pos, &[1]).unwrap();
        assert_eq!(c.logits[0], 0.0, "slot 0 must be untouched");
        assert_eq!(c.logits[d.gen_len * d.vocab], 7.0, "slot 1 gen row 0");
        c.reset_slot(1);
        assert_eq!(c.logits[d.gen_len * d.vocab], 0.0);
        assert_eq!(c.conf[d.gen_len], 0.0);
    }

    #[test]
    fn gen_logit_merge_matches_full_context_merge() {
        let d = dims();
        let v = d.vocab;
        // a full-context tensor and its gen-region slice with the same
        // peaked rows must produce identical state through either merge
        let mut full = vec![0.0f32; 2 * d.ctx * v];
        let mut gen = vec![0.0f32; 2 * d.gen_len * v];
        for b in 0..2usize {
            for g in 0..d.gen_len {
                let peak = ((b + g) % v) as usize;
                full[(b * d.ctx + d.prompt_len + g) * v + peak] = 6.0;
                gen[(b * d.gen_len + g) * v + peak] = 6.0;
            }
        }
        let full_t = HostTensor::F32 { shape: vec![2, d.ctx, v], data: full };
        let gen_t = HostTensor::F32 { shape: vec![2, d.gen_len, v], data: gen };
        let mut a = GroupCaches::new(&d, 2);
        let mut b_ = GroupCaches::new(&d, 2);
        a.merge_full_logits_slots(&full_t, &[0, 1]).unwrap();
        b_.merge_gen_logits_slots(&gen_t, &[0, 1]).unwrap();
        assert_eq!(a.logits, b_.logits);
        assert_eq!(a.conf, b_.conf);

        // slot filtering: spectator rows untouched
        let mut c = GroupCaches::new(&d, 2);
        c.merge_gen_logits_slots(&gen_t, &[1]).unwrap();
        assert!(c.logits[..d.gen_len * v].iter().all(|&x| x == 0.0));
        assert_eq!(c.logits[d.gen_len * v..], b_.logits[d.gen_len * v..]);

        // a full-context tensor fed to the gen merge is a shape error,
        // not a silent mis-slice
        let full_t2 = HostTensor::F32 {
            shape: vec![2, d.ctx, v],
            data: vec![0.0; 2 * d.ctx * v],
        };
        assert!(c.merge_gen_logits_slots(&full_t2, &[0]).is_err());
    }

    #[test]
    fn conf_tensor_masked_pins_vacant_rows() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        c.conf.fill(0.5);
        let t = c.conf_tensor_masked(&[0]);
        let data = t.as_f32().unwrap();
        assert!(data[..d.gen_len].iter().all(|&x| x == 0.5));
        assert!(data[d.gen_len..].iter().all(|&x| x == -1.0));
    }

    #[test]
    fn slot_filtered_prefill_refresh() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let v = d.vocab;
        let mut logits_full = vec![0.0f32; 2 * d.ctx * v];
        // peak token 3 for every gen position of both rows
        for b in 0..2 {
            for g in 0..d.gen_len {
                logits_full[(b * d.ctx + d.prompt_len + g) * v + 3] = 5.0;
            }
        }
        let kv_len = d.n_layers * 2 * 2 * d.n_kv_heads * d.ctx * d.head_dim;
        let ind_len = d.n_layers * 2 * d.gen_len * d.d_model;
        let outputs = vec![
            HostTensor::F32 { shape: vec![2, d.ctx, v], data: logits_full },
            HostTensor::Bf16 {
                shape: vec![d.n_layers, 2, 2, d.n_kv_heads, d.ctx, d.head_dim],
                data: vec![7u16; kv_len],
            },
            HostTensor::Bf16 { shape: vec![d.n_layers, 2, d.gen_len, d.d_model], data: vec![1u16; ind_len] },
            HostTensor::Bf16 { shape: vec![d.n_layers, 2, d.gen_len, d.d_model], data: vec![2u16; ind_len] },
            HostTensor::Bf16 { shape: vec![d.n_layers, 2, d.gen_len, d.d_model], data: vec![3u16; ind_len] },
            HostTensor::Bf16 { shape: vec![d.n_layers, 2, d.gen_len, d.d_model], data: vec![4u16; ind_len] },
            HostTensor::F32 { shape: vec![2, d.ctx], data: vec![0.0; 2 * d.ctx] },
        ];
        c.refresh_slots_from_prefill(&outputs, &[1]).unwrap();
        // slot 1 refreshed: confident logits + kv filled
        assert!(c.conf[d.gen_len] > 0.9);
        let off1 = c.kv_off(d.ctx, 0, 0, 1, 0, 0);
        assert_eq!(c.kv[off1], 7);
        // slot 0 untouched
        assert_eq!(c.conf[0], 0.0);
        let off0 = c.kv_off(d.ctx, 0, 0, 0, 0, 0);
        assert_eq!(c.kv[off0], 0);
    }

    #[test]
    fn refresh_plan_cadence() {
        let p = RefreshPolicy { prompt_period: 8, block_period: 2 };
        // block of 4: i_b 0 → prefill; odd iters es; even (non-0) dual
        assert_eq!(p.plan_es(0, 0), StepPlan::Prefill);
        assert_eq!(p.plan_es(1, 1), StepPlan::EsStep);
        assert_eq!(p.plan_es(2, 2), StepPlan::DualStep);
        assert_eq!(p.plan_es(3, 3), StepPlan::EsStep);
        assert_eq!(p.plan_es(8, 4), StepPlan::Prefill); // global prompt period
        assert_eq!(RefreshPolicy::plan_dual(0), StepPlan::Prefill);
        assert_eq!(RefreshPolicy::plan_dual(3), StepPlan::DualStep);
    }

    #[test]
    fn smooth_is_mean_filter() {
        let s = smooth(&[0.0, 3.0, 0.0], 3);
        assert!((s[1] - 1.0).abs() < 1e-6);
        assert_eq!(smooth(&[1.0, 2.0], 1), vec![1.0, 2.0]);
    }

    #[test]
    fn dirty_bitmap_mark_clear_count() {
        let mut bm = DirtyBitmap::new_clean(2, 70); // straddles a word
        assert!(!bm.any());
        bm.mark_range(1, 60, 66);
        assert_eq!(bm.count(), 6);
        assert_eq!(bm.count_slot(1), 6);
        assert_eq!(bm.count_slot(0), 0);
        assert!(bm.get(1, 60) && bm.get(1, 65) && !bm.get(1, 66));
        bm.clear_range(1, 60, 63);
        assert_eq!(bm.count_slot(1), 3);
        bm.mark_slot(0);
        assert_eq!(bm.count_slot(0), 70);
        bm.clear_all();
        assert!(!bm.any());
        // out-of-range marks are clamped, not UB
        bm.mark_range(0, 68, 999);
        assert_eq!(bm.count(), 2);
    }

    #[test]
    fn caches_start_fully_dirty_and_ops_mark_rows() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        assert_eq!(c.dirty.kv.count(), 2 * d.ctx, "fresh caches are unseeded");
        c.dirty.kv.clear_all();
        c.dirty.conf.clear_all();
        for bm in c.dirty.ind.values_mut() {
            bm.clear_all();
        }

        // a KV block scatter marks exactly the block rows of its slots
        let block = 2;
        let n = d.n_layers * 2 * 2 * d.n_kv_heads * block * d.head_dim;
        let t = HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, 2, d.n_kv_heads, block, d.head_dim],
            data: vec![1u16; n],
        };
        c.scatter_kv_block_slots(4, block, &t, &[1]).unwrap();
        assert_eq!(c.dirty.kv.count_slot(1), block);
        assert_eq!(c.dirty.kv.count_slot(0), 0);
        assert!(c.dirty.kv.get(1, 4) && c.dirty.kv.get(1, 5));

        // a step-logits merge marks the merged confidence rows
        let logits = HostTensor::F32 {
            shape: vec![2, 1, 8],
            data: vec![0.0; 16],
        };
        let pos = HostTensor::I32 { shape: vec![2, 1], data: vec![5, 5] };
        c.merge_step_logits_slots(&logits, &pos, &[0]).unwrap();
        assert_eq!(c.dirty.conf.count_slot(0), 1);
        assert!(c.dirty.conf.get(0, 1), "gen idx 1 = pos 5 - prompt 4");
        assert_eq!(c.dirty.conf.count_slot(1), 0);

        // reset (slot admission) marks every kind of exactly that slot
        c.dirty.tok.clear_all();
        c.reset_slot(0);
        assert_eq!(c.dirty.kv.count_slot(0), d.ctx);
        assert_eq!(c.dirty.conf.count_slot(0), d.gen_len);
        assert_eq!(c.dirty.tok.count_slot(0), d.ctx, "token row dirtied too");
        assert_eq!(c.dirty.tok.count_slot(1), 0);
        for bm in c.dirty.ind.values() {
            assert_eq!(bm.count_slot(0), d.gen_len);
            assert_eq!(bm.count_slot(1), 0);
        }
        assert_eq!(c.dirty.kv.count_slot(1), block, "spectator untouched");
    }

    #[test]
    fn prefix_rows_roundtrip_and_mark_not_clear() {
        let d = dims();
        let mut a = GroupCaches::new(&d, 2);
        for (i, v) in a.kv.iter_mut().enumerate() {
            *v = i as u16;
        }
        let p = 2;
        let rows = a.extract_prefix_rows(1, p).unwrap();
        assert_eq!(rows.len(), d.n_layers * 2 * d.n_kv_heads * p * d.head_dim);

        let mut b = GroupCaches::new(&d, 2);
        b.dirty.kv.clear_all();
        b.merge_prefix_rows(0, p, &rows).unwrap();
        // slot 0 of `b` now holds slot 1 of `a`'s prefix rows exactly
        for l in 0..d.n_layers {
            for s in 0..2 {
                for h in 0..d.n_kv_heads {
                    let src = a.kv_off(d.ctx, l, s, 1, h, 0);
                    let dst = b.kv_off(d.ctx, l, s, 0, h, 0);
                    assert_eq!(
                        &b.kv[dst..dst + p * d.head_dim],
                        &a.kv[src..src + p * d.head_dim]
                    );
                }
            }
        }
        // the seed is host-originated: bits marked, never cleared
        assert_eq!(b.dirty.kv.count_slot(0), p);
        assert_eq!(b.dirty.kv.count_slot(1), 0, "spectator untouched");
        // oversize prefixes and mismatched payloads fail loudly
        assert!(a.extract_prefix_rows(0, d.prompt_len + 1).is_err());
        assert!(b.merge_prefix_rows(0, p, &rows[1..]).is_err());
        assert!(b.merge_prefix_rows(0, d.prompt_len + 1, &rows).is_err());
    }

    #[test]
    fn pooled_builders_match_allocating_variants() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        for (i, v) in c.ind.get_mut("h").unwrap().iter_mut().enumerate() {
            *v = i as u16;
        }
        c.conf.fill(0.5);
        let layers = vec![0usize, 1];
        let fresh = c.gather_ind("h", &layers).unwrap();
        let mut pooled = HostTensor::Bf16 { shape: Vec::new(), data: Vec::new() };
        c.gather_ind_into("h", &layers, &mut pooled).unwrap();
        assert_eq!(fresh.shape(), pooled.shape());
        assert_eq!(fresh.as_bf16().unwrap(), pooled.as_bf16().unwrap());

        let fresh_conf = c.conf_tensor_masked(&[0]);
        let mut pooled_conf = HostTensor::F32 { shape: Vec::new(), data: Vec::new() };
        c.conf_masked_into(&[0], &mut pooled_conf).unwrap();
        assert_eq!(fresh_conf.as_f32().unwrap(), pooled_conf.as_f32().unwrap());

        // kv_view matches the cloning kv_tensor
        let t = c.kv_tensor();
        let v = c.kv_view();
        assert_eq!(t.shape(), v.shape());
        assert_eq!(t.elements(), v.elements());
    }
}
