//! Small shared utilities.

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Human-readable byte counts for logs and reports.
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.00 MiB");
    }
}
