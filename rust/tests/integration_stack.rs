//! Integration tests over the real artifacts: runtime → engine → router →
//! HTTP server. These need `make artifacts` to have run; they are skipped
//! (with a message) when artifacts are missing so `cargo test` stays green
//! on a fresh checkout.

use esdllm::batcher::BatcherCfg;
use esdllm::engine::{Engine, EngineCfg, Method};
use esdllm::httpd::Client;
use esdllm::json::{self, Json};
use esdllm::router::{Router, RouterCfg};
use esdllm::runtime::{default_artifacts_dir, Runtime};
use esdllm::server::{serve, ServeCfg};

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists()
        || !dir.join("weights-llada-nano-instruct.bin").exists()
    {
        eprintln!("skipping integration test: artifacts not built");
        return None;
    }
    Some(Runtime::load(&dir).expect("artifacts present but unloadable"))
}

#[test]
fn engine_generates_all_methods_deterministically() {
    let Some(rt) = runtime() else { return };
    let prompts = vec!["1+2=".to_string()];
    let mut texts = vec![];
    for method in [Method::Vanilla, Method::DualCache, Method::EsDllm] {
        let mut engine = Engine::new(&rt, EngineCfg::new("llada-nano", method));
        let r1 = engine.generate(&prompts).unwrap();
        let mut engine2 = Engine::new(&rt, EngineCfg::new("llada-nano", method));
        let r2 = engine2.generate(&prompts).unwrap();
        assert_eq!(r1.texts, r2.texts, "{method:?} must be deterministic");
        assert_eq!(r1.iterations, r2.iterations);
        // greedy decoding takes one iteration per emitted token; the EOS
        // guard may retire the sequence at a block boundary before the
        // full 32-position gen region is unmasked
        assert!(r1.iterations > 0 && r1.iterations <= 32, "{}", r1.iterations);
        texts.push(r1.texts[0].clone());
    }
    // all methods produce non-empty text
    for t in &texts {
        assert!(!t.is_empty());
    }
}

#[test]
fn es_step_counts_follow_refresh_policy() {
    let Some(rt) = runtime() else { return };
    let mut cfg = EngineCfg::new("llada-nano", Method::EsDllm);
    cfg.refresh = esdllm::cache::RefreshPolicy { prompt_period: 16, block_period: 4 };
    cfg.block = 8;
    let mut engine = Engine::new(&rt, cfg);
    let r = engine.generate(&["2*3=".to_string()]).unwrap();
    // every iteration runs exactly one executable for a single sequence
    assert_eq!(r.n_prefill + r.n_dual + r.n_es, r.iterations);
    // block 8 with block_period 4: per full block, i_b=0 prefills and
    // i_b=4 dual-refreshes; the EOS guard may retire before all 4 blocks
    let blocks = r.iterations.div_ceil(8);
    assert!(r.n_prefill >= blocks, "{} prefills over {blocks} blocks", r.n_prefill);
    assert!(r.n_es >= r.n_dual, "ES steps dominate the cadence");
}

#[test]
fn parallel_decoding_reduces_iterations() {
    let Some(rt) = runtime() else { return };
    let prompts = vec!["sort(3,1,2)=".to_string()];
    let mut base = Engine::new(&rt, EngineCfg::new("llada-nano", Method::EsDllm));
    let rb = base.generate(&prompts).unwrap();
    let mut cfg = EngineCfg::new("llada-nano", Method::EsDllm);
    cfg.sampler = cfg.sampler.with_parallel(0.9);
    let mut pd = Engine::new(&rt, cfg);
    let rp = pd.generate(&prompts).unwrap();
    assert!(
        rp.iterations < rb.iterations,
        "PD {} !< greedy {}",
        rp.iterations,
        rb.iterations
    );
}

#[test]
fn sparse_attention_runs_and_prunes() {
    let Some(rt) = runtime() else { return };
    let mut cfg = EngineCfg::new("llada-nano", Method::EsDllm);
    cfg.sparse = true;
    let mut engine = Engine::new(&rt, cfg);
    let r = engine.generate(&["max(4,9,2)=".to_string()]).unwrap();
    assert!(r.iterations > 0 && r.iterations <= 32);
    assert!(!r.texts[0].is_empty());
}

#[test]
fn dream_arch_and_base_checkpoint_load() {
    let Some(rt) = runtime() else { return };
    if !default_artifacts_dir()
        .join("weights-dream-nano-instruct.bin")
        .exists()
    {
        eprintln!("skipping: dream weights not built yet");
        return;
    }
    for (arch, ck) in [("dream-nano", "instruct"), ("llada-nano", "base")] {
        let mut cfg = EngineCfg::new(arch, Method::EsDllm);
        cfg.checkpoint = ck.into();
        let mut engine = Engine::new(&rt, cfg);
        let r = engine.generate(&["7-4=".to_string()]).unwrap();
        assert!(r.iterations > 0 && r.iterations <= 32, "{arch}/{ck}");
    }
}

#[test]
fn http_server_end_to_end() {
    let Some(_rt) = runtime() else { return };
    let mut router_cfg = RouterCfg::new(
        EngineCfg::new("llada-nano", Method::EsDllm),
        default_artifacts_dir(),
    );
    router_cfg.batcher = BatcherCfg { max_batch: 8, flush_ms: 10 };
    router_cfg.queue_cap = 16;
    let router = Router::start(router_cfg);
    let server = serve(&ServeCfg::default(), router.clone()).unwrap();
    let mut client = Client::new(server.addr);

    let (st, body) = client.get("/healthz").unwrap();
    assert_eq!((st, body.as_slice()), (200, b"ok".as_slice()));

    let (st, body) = client
        .post("/generate", br#"{"prompt": "1+1="}"#)
        .unwrap();
    assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(j.get("text").as_str().is_some());
    assert!(j.get("iterations").as_usize().unwrap() > 0);

    let (st, _) = client
        .post("/generate", br#"{"nope": 1}"#)
        .unwrap();
    assert_eq!(st, 400);

    let (st, m) = client.get("/metrics").unwrap();
    assert_eq!(st, 200);
    let m = String::from_utf8_lossy(&m);
    // the malformed request is rejected before reaching the router, so
    // only the successful generate counts
    assert!(m.contains("esdllm_requests_total 1"), "{m}");
    router.shutdown();
    let _ = json::num(0.0);
}

#[test]
fn vocab_json_matches_builtin_tokenizer_expectations() {
    let Some(rt) = runtime() else { return };
    let t = &rt.tokenizer;
    assert_eq!(t.pad, 0);
    assert_eq!(t.mask, 1);
    assert_eq!(t.eos, 2);
    let ids = t.encode("f(x)=x*3|f(2)=6").unwrap();
    assert_eq!(t.decode(&ids), "f(x)=x*3|f(2)=6");
}
