"""Synthetic benchmark task families + the character tokenizer.

These stand in for the paper's five evaluation suites (see DESIGN.md §1):

    arith    ~ GSM8K      few-shot multi-digit arithmetic
    chain    ~ MATH       nested bracketed expression evaluation
    logic    ~ BBH        boolean expression evaluation
    codegen  ~ HumanEval  apply a stated function rule to a new input
    listops  ~ MBPP       sort / reverse / max over digit lists

Every sample is (prompt, answer); quality is exact match on the answer
span, so generation degradation from over-aggressive skipping is directly
measurable.  The same generators are re-implemented in Rust
(`rust/src/workload/`) with the same PRNG so both sides agree; *this* file
is only used at build time (training corpus + vocab artifact).
"""

import json

# ---------------------------------------------------------------------------
# Tokenizer: fixed char-level vocabulary. Order is frozen — the Rust
# tokenizer loads vocab.json and must agree with training.
# ---------------------------------------------------------------------------

PAD, MASK, EOS, BOS = 0, 1, 2, 3
SPECIALS = ["<pad>", "<mask>", "<eos>", "<bos>"]
CHARS = (
    [str(i) for i in range(10)]
    + [chr(c) for c in range(ord("a"), ord("z") + 1)]
    + list("+-*/=()[],.:?><|&! ")
)
TOKENS = SPECIALS + CHARS
assert len(TOKENS) <= 64, len(TOKENS)
VOCAB = 64  # padded with unused slots to a power of two

_STOI = {s: i for i, s in enumerate(TOKENS)}


def encode(s: str):
    return [_STOI[c] for c in s]


def decode(ids):
    out = []
    for i in ids:
        if i == EOS:
            break
        if i < len(TOKENS) and i >= len(SPECIALS):
            out.append(TOKENS[i])
    return "".join(out)


def write_vocab_json(path):
    with open(path, "w") as f:
        json.dump(
            {
                "tokens": TOKENS,
                "vocab_size": VOCAB,
                "pad": PAD,
                "mask": MASK,
                "eos": EOS,
                "bos": BOS,
            },
            f,
            indent=1,
        )


# ---------------------------------------------------------------------------
# splitmix64 — identical generator on the Rust side, so the eval sets match.
# ---------------------------------------------------------------------------

MASK64 = (1 << 64) - 1


class SplitMix:
    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        return self.next64() % n

    def range(self, lo: int, hi: int) -> int:  # inclusive
        return lo + self.below(hi - lo + 1)


# ---------------------------------------------------------------------------
# Task families
# ---------------------------------------------------------------------------


def _arith_pair(rng):
    a, b = rng.range(1, 99), rng.range(1, 99)
    if rng.below(3) == 0 and a >= b:
        return f"{a}-{b}=", str(a - b)
    if rng.below(4) == 0:
        a, b = rng.range(2, 9), rng.range(2, 9)
        return f"{a}*{b}=", str(a * b)
    return f"{a}+{b}=", str(a + b)


def gen_arith(rng):
    """Few-shot arithmetic: two solved examples, one open."""
    shots = []
    for _ in range(2):
        q, a = _arith_pair(rng)
        shots.append(q + a)
    q, a = _arith_pair(rng)
    return "|".join(shots + [q]), a


def _expr(rng, depth):
    if depth == 0:
        v = rng.range(1, 9)
        return str(v), v
    ls, lv = _expr(rng, depth - 1)
    rv = rng.range(1, 9)
    op = "+-*"[rng.below(3)]
    if op == "+":
        val = lv + rv
    elif op == "-":
        val = lv - rv
    else:
        val = lv * rv
    if abs(val) > 99:  # keep answers short
        op, val = "+", lv + rv
    return f"({ls}{op}{rv})", val


def gen_chain(rng):
    s, v = _expr(rng, rng.range(2, 3))
    return f"{s}=", str(v)


def _bexpr(rng, depth):
    if depth == 0:
        v = rng.below(2) == 1
        return ("t" if v else "f"), v
    if rng.below(4) == 0:
        ls, lv = _bexpr(rng, depth - 1)
        return f"!{ls}", not lv
    ls, lv = _bexpr(rng, depth - 1)
    rs, rv = _bexpr(rng, 0)
    if rng.below(2) == 0:
        return f"({ls}&{rs})", lv and rv
    return f"({ls}|{rs})", lv or rv


def gen_logic(rng):
    s, v = _bexpr(rng, rng.range(2, 3))
    return f"{s}=", "t" if v else "f"


def gen_codegen(rng):
    k = rng.range(2, 9)
    op = "+-*"[rng.below(3)]
    x1, x2 = rng.range(1, 9), rng.range(1, 9)

    def apply(x):
        if op == "+":
            return x + k
        if op == "-":
            return x - k
        return x * k

    rule = f"f(x)=x{op}{k}"
    return f"{rule}|f({x1})={apply(x1)}|f({x2})=", str(apply(x2))


def gen_listops(rng):
    n = rng.range(3, 5)
    xs = [rng.below(10) for _ in range(n)]
    kind = rng.below(3)
    body = ",".join(map(str, xs))
    if kind == 0:
        return f"sort({body})=", ",".join(map(str, sorted(xs)))
    if kind == 1:
        return f"rev({body})=", ",".join(map(str, xs[::-1]))
    return f"max({body})=", str(max(xs))


BENCHMARKS = {
    "arith": gen_arith,
    "chain": gen_chain,
    "logic": gen_logic,
    "codegen": gen_codegen,
    "listops": gen_listops,
}

# Benchmark seeds: train / eval draws come from disjoint seed spaces.
TRAIN_SEED_BASE = 0x5EED_0000
EVAL_SEED_BASE = 0xE7A1_0000


def sample(bench: str, seed: int):
    """Deterministic (prompt, answer) for (bench, seed)."""
    rng = SplitMix((hash_bench(bench) << 32) ^ seed)
    return BENCHMARKS[bench](rng)


def hash_bench(bench: str) -> int:
    h = 2166136261
    for c in bench.encode():
        h = ((h ^ c) * 16777619) & 0xFFFFFFFF
    return h


def make_example(bench: str, seed: int, prompt_len: int, gen_len: int):
    """Tokenized training example: prompt right-padded with PAD to
    prompt_len; answer + EOS-fill to gen_len (LLaDA pads responses with
    EOS so the model learns to emit an EOS tail)."""
    prompt, answer = sample(bench, seed)
    p = encode(prompt)[:prompt_len]
    a = encode(answer)[: gen_len - 1]
    p = p + [PAD] * (prompt_len - len(p))
    a = a + [EOS] * (gen_len - len(a))
    return p, a, prompt, answer
