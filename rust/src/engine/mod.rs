//! The decode engine: per-iteration orchestration of the paper's methods.
//!
//! One [`Engine`] drives one batched sequence group through the masked-
//! diffusion denoising loop, choosing per iteration between:
//!
//!   * `Prefill`  — full forward (vanilla step / prompt refresh / block
//!                  grounding); refreshes every cache,
//!   * `DualStep` — full-block step against cached outside-KV (DualCache's
//!                  per-iteration op; ES-dLLM's block refresh),
//!   * `EsStep`   — the early-skip step (Algorithm 1): the executable
//!                  computes importance scores in-graph, returns logits
//!                  only for the surviving positions, and the engine
//!                  merges them into the latest-logits state (skipped
//!                  positions keep their previous logits/confidence).
//!
//! The engine owns sampling (low-confidence remask / maskgit-plus),
//! parallel decoding, the EOS guard, sparse-KV selection, and all cache
//! plumbing. Python is never on this path.

use anyhow::{anyhow, Result};

use crate::cache::{GroupCaches, RefreshPolicy, StepPlan};
use crate::manifest::{ArchSpec, ExeKind, ExeSpec};
use crate::rng::SplitMix;
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;
use crate::sampler::{decide_unmask, SamplerCfg, UnmaskInput};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// full recomputation every iteration (the LLaDA/Dream baseline)
    Vanilla,
    /// Fast-dLLM DualCache: cached outside-KV, full block per iteration
    DualCache,
    /// this paper: DualCache + early-skipping inside the block
    EsDllm,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Vanilla => "vanilla",
            Method::DualCache => "DualCache",
            Method::EsDllm => "ES-dLLM",
        }
    }
}

#[derive(Debug, Clone)]
pub struct EngineCfg {
    pub arch: String,
    pub checkpoint: String,
    pub method: Method,
    pub block: usize,
    pub refresh: RefreshPolicy,
    /// Eq. 1 mixing weight
    pub alpha: f32,
    pub sampler: SamplerCfg,
    /// prompt-KV pruning (Sparse-dLLM integration)
    pub sparse: bool,
    /// variation indicator: "h" | "q" | "k" | "v"
    pub indicator: String,
    /// override the ES step executable (ablation variants)
    pub es_exe_override: Option<String>,
    /// adaptive skip ratio (paper §7 future work): pick the skip-ratio
    /// variant each iteration from the observed confidence drift —
    /// aggressive skipping while the iterate is quiescent, conservative
    /// when it is moving. Requires the ratio-variant executables
    /// (compiled for llada-nano at block 32).
    pub adaptive: bool,
    pub seed: u64,
}

impl EngineCfg {
    pub fn new(arch: &str, method: Method) -> EngineCfg {
        EngineCfg {
            arch: arch.to_string(),
            checkpoint: "instruct".to_string(),
            method,
            block: 8,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 4 },
            alpha: 0.5,
            sampler: if arch.starts_with("dream") {
                SamplerCfg::dream()
            } else {
                SamplerCfg::llada()
            },
            sparse: false,
            indicator: "h".to_string(),
            es_exe_override: None,
            adaptive: false,
            seed: 0,
        }
    }
}

/// Adaptive-ratio policy (future-work extension): map the mean
/// |Δconfidence| observed at the last computed iteration to a compiled
/// skip-ratio variant. Quiescent iterate → skip harder.
pub fn adaptive_es_exe(block: usize, batch: usize, mean_conf_delta: f32) -> String {
    let variant = if mean_conf_delta < 0.01 {
        "es_r2_only_75" // aggressive: keep only 25% past layer 2
    } else if mean_conf_delta < 0.05 {
        return format!("es_blk{block}_b{batch}"); // default r1=r2=0.5
    } else {
        "es_r2_only_25" // conservative: keep 75%
    };
    format!("{variant}_blk{block}_b{batch}")
}

/// Outcome of one batched group generation.
#[derive(Debug, Clone)]
pub struct GroupResult {
    pub texts: Vec<String>,
    pub iterations: usize,
    pub tokens_generated: usize,
    pub wall_s: f64,
    /// iteration counts by plan, for FLOPs accounting
    pub n_prefill: usize,
    pub n_dual: usize,
    pub n_es: usize,
}

pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub cfg: EngineCfg,
    rng: SplitMix,
    /// mean |Δconfidence| at the last iteration (adaptive-ratio signal)
    conf_drift: f32,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: EngineCfg) -> Engine<'rt> {
        let seed = cfg.seed ^ 0xE5D1;
        Engine { rt, cfg, rng: SplitMix::new(seed), conf_drift: 1.0 }
    }

    fn arch(&self) -> Result<&ArchSpec> {
        self.rt.arch(&self.cfg.arch)
    }

    fn exe<'a>(&self, arch: &'a ArchSpec, name: &str) -> Result<&'a ExeSpec> {
        arch.exe(name)
    }

    /// Name of the step executable for the given plan at batch `b`.
    fn step_exe_name(&self, plan: StepPlan, batch: usize) -> String {
        let blk = self.cfg.block;
        let ind = self.cfg.indicator.as_str();
        match plan {
            StepPlan::Prefill => unreachable!(),
            StepPlan::DualStep => {
                if self.cfg.sparse {
                    format!("dual_sp_blk{blk}_b{batch}")
                } else if ind != "h" {
                    format!("dual_ind_{ind}_blk{blk}_b{batch}")
                } else {
                    format!("dual_blk{blk}_b{batch}")
                }
            }
            StepPlan::EsStep => {
                if let Some(name) = &self.cfg.es_exe_override {
                    name.clone()
                } else if self.cfg.adaptive {
                    adaptive_es_exe(blk, batch, self.conf_drift)
                } else if self.cfg.sparse {
                    format!("es_sp_blk{blk}_b{batch}")
                } else if ind != "h" {
                    format!("es_ind_{ind}_blk{blk}_b{batch}")
                } else {
                    format!("es_blk{blk}_b{batch}")
                }
            }
        }
    }

    /// Compile every executable this configuration can touch at batch
    /// size `batch`, so the first timed generation doesn't pay PJRT
    /// compilation (5–7 s per module) inside the measurement window.
    pub fn precompile(&mut self, batch: usize) -> Result<()> {
        let arch = self.arch()?.clone();
        let mut names = vec![format!("prefill_b{batch}")];
        if self.cfg.method == Method::Vanilla {
            names = vec![format!("vanilla_b{batch}")];
        } else {
            names.push(self.step_exe_name(StepPlan::DualStep, batch));
            if self.cfg.method == Method::EsDllm {
                if self.cfg.adaptive {
                    for drift in [0.001f32, 0.02, 0.2] {
                        names.push(adaptive_es_exe(self.cfg.block, batch, drift));
                    }
                } else {
                    names.push(self.step_exe_name(StepPlan::EsStep, batch));
                }
            }
        }
        for name in names {
            let exe = self.exe(&arch, &name)?;
            self.rt.executable(&arch, exe)?;
        }
        self.rt.checkpoint_params(&arch, &self.cfg.checkpoint)?;
        Ok(())
    }

    /// Generate completions for up to `batch` prompts (padded internally).
    pub fn generate(&mut self, prompts: &[String]) -> Result<GroupResult> {
        let arch = self.arch()?.clone();
        let d = &arch.dims;
        let gen = d.gen_len;
        let block = self.cfg.block;
        if gen % block != 0 {
            return Err(anyhow!("gen_len {gen} not divisible by block {block}"));
        }
        // batch-size class: the core executables exist for b in {1, 8};
        // sparse / indicator / ablation variants are compiled at b=8 only
        let b1_ok = !self.cfg.sparse
            && self.cfg.indicator == "h"
            && self.cfg.es_exe_override.is_none();
        let batch = if prompts.len() <= 1 && b1_ok { 1 } else { 8 };
        if prompts.len() > batch {
            return Err(anyhow!("group of {} exceeds max batch {batch}", prompts.len()));
        }
        let tok = &self.rt.tokenizer;
        let mask = tok.mask;

        // layout: [prompt (PAD-padded) | gen (MASK)]
        let mut tokens = vec![0i32; batch * d.ctx];
        for b in 0..batch {
            let prompt = prompts.get(b).unwrap_or(&prompts[prompts.len() - 1]);
            let ids = tok.encode_prompt(prompt, d.prompt_len)?;
            tokens[b * d.ctx..b * d.ctx + d.prompt_len].copy_from_slice(&ids);
            for g in 0..gen {
                tokens[b * d.ctx + d.prompt_len + g] = mask;
            }
        }

        let mut caches = GroupCaches::new(d, batch);
        let mut result = GroupResult {
            texts: vec![],
            iterations: 0,
            tokens_generated: prompts.len() * gen,
            wall_s: 0.0,
            n_prefill: 0,
            n_dual: 0,
            n_es: 0,
        };
        let t0 = std::time::Instant::now();

        let n_blocks = gen / block;
        let mut g_iter = 0usize; // global iteration counter
        for blk_i in 0..n_blocks {
            let block_lo = blk_i * block; // gen-region offset
            let block_start = d.prompt_len + block_lo; // absolute
            let mut i_b = 0usize;
            // iterate until every sequence's block region is unmasked
            while (0..batch).any(|b| {
                tokens[b * d.ctx + block_start..b * d.ctx + block_start + block]
                    .iter()
                    .any(|&t| t == mask)
            }) {
                let plan = match self.cfg.method {
                    Method::Vanilla => StepPlan::Prefill,
                    Method::DualCache => RefreshPolicy::plan_dual(i_b),
                    Method::EsDllm => self.cfg.refresh.plan_es(g_iter, i_b),
                };
                let conf_before = caches.conf.clone();
                match plan {
                    StepPlan::Prefill => {
                        self.run_prefill(&arch, batch, &tokens, &mut caches)?;
                        result.n_prefill += 1;
                    }
                    StepPlan::DualStep | StepPlan::EsStep => {
                        self.run_step(
                            &arch, plan, batch, &tokens, block_start, &mut caches,
                        )?;
                        if plan == StepPlan::DualStep {
                            result.n_dual += 1;
                        } else {
                            result.n_es += 1;
                        }
                    }
                }
                // adaptive-ratio signal: mean |Δconf| over the block
                if self.cfg.adaptive {
                    let mut sum = 0f32;
                    let mut cnt = 0usize;
                    for b in 0..batch {
                        for j in block_lo..block_lo + block {
                            let i = b * gen + j;
                            sum += (caches.conf[i] - conf_before[i]).abs();
                            cnt += 1;
                        }
                    }
                    self.conf_drift = sum / cnt.max(1) as f32;
                }

                // unmask decisions per sequence
                for b in 0..batch {
                    let gen_tokens =
                        &tokens[b * d.ctx + d.prompt_len..b * d.ctx + d.ctx];
                    let inp = UnmaskInput {
                        logits: &caches.logits
                            [b * gen * d.vocab..(b + 1) * gen * d.vocab],
                        conf: &caches.conf[b * gen..(b + 1) * gen],
                        gen_tokens,
                        block_lo,
                        block_hi: block_lo + block,
                        vocab: d.vocab,
                        mask_id: mask,
                        eos_id: tok.eos,
                    };
                    let decision = decide_unmask(&self.cfg.sampler, &inp, &mut self.rng);
                    for (p, t) in decision.positions.iter().zip(&decision.tokens) {
                        tokens[b * d.ctx + d.prompt_len + p] = *t;
                    }
                }
                g_iter += 1;
                i_b += 1;
                result.iterations += 1;
            }
        }

        result.wall_s = t0.elapsed().as_secs_f64();
        result.texts = (0..prompts.len())
            .map(|b| {
                tok.decode(&tokens[b * d.ctx + d.prompt_len..b * d.ctx + d.ctx])
            })
            .collect();
        Ok(result)
    }

    fn run_prefill(
        &mut self,
        arch: &ArchSpec,
        batch: usize,
        tokens: &[i32],
        caches: &mut GroupCaches,
    ) -> Result<()> {
        let d = &arch.dims;
        // the vanilla baseline never reads caches: use the logits-only
        // executable and skip all cache maintenance
        if self.cfg.method == Method::Vanilla {
            let exe = self.exe(arch, &format!("vanilla_b{batch}"))?;
            let toks = HostTensor::I32 {
                shape: vec![batch, d.ctx],
                data: tokens.to_vec(),
            };
            let out = self.rt.run(arch, exe, &self.cfg.checkpoint, &[toks])?;
            // slice gen-region logits into the state
            let logits_full = out[0].as_f32()?;
            for b in 0..batch {
                for g in 0..d.gen_len {
                    let src = (b * d.ctx + d.prompt_len + g) * d.vocab;
                    let dst = (b * d.gen_len + g) * d.vocab;
                    caches.logits[dst..dst + d.vocab]
                        .copy_from_slice(&logits_full[src..src + d.vocab]);
                }
            }
            caches.recompute_conf();
            return Ok(());
        }
        let exe = self.exe(arch, &format!("prefill_b{batch}"))?;
        let toks = HostTensor::I32 { shape: vec![batch, d.ctx], data: tokens.to_vec() };
        let out = self.rt.run(arch, exe, &self.cfg.checkpoint, &[toks])?;
        debug_assert_eq!(exe.kind, ExeKind::Prefill);
        caches.refresh_from_prefill(&out)?;
        if self.cfg.sparse {
            let keep = self.rt.manifest.generation.sparse_keep_prompt;
            caches.rebuild_sparse(&out[6], keep, 3)?;
        }
        Ok(())
    }

    fn run_step(
        &mut self,
        arch: &ArchSpec,
        plan: StepPlan,
        batch: usize,
        tokens: &[i32],
        block_start: usize,
        caches: &mut GroupCaches,
    ) -> Result<()> {
        let d = &arch.dims;
        let block = self.cfg.block;
        let exe_name = self.step_exe_name(plan, batch);
        let exe = self.exe(arch, &exe_name)?;

        // current block tokens
        let mut x_tok = Vec::with_capacity(batch * block);
        for b in 0..batch {
            x_tok.extend_from_slice(
                &tokens[b * d.ctx + block_start..b * d.ctx + block_start + block],
            );
        }

        let ind_layers: &[usize] = &exe.skip_layers;
        let all_layers: Vec<usize> = (0..d.n_layers).collect();
        let ind_for_exe: Vec<usize> = if exe.skip.is_empty() {
            all_layers
        } else {
            ind_layers.to_vec()
        };
        let indicator = exe.indicator.clone().unwrap_or_else(|| "h".into());

        let kv = if self.cfg.sparse {
            caches.kv_sparse_tensor()?
        } else {
            caches.kv_tensor()
        };
        let inputs = vec![
            HostTensor::I32 { shape: vec![batch, block], data: x_tok },
            HostTensor::scalar_i32(block_start as i32),
            kv,
            caches.gather_ind(&indicator, &ind_for_exe)?,
            caches.conf_tensor(),
            HostTensor::scalar_f32(self.cfg.alpha),
        ];
        let out = self.rt.run(arch, exe, &self.cfg.checkpoint, &inputs)?;
        // outputs: logits [B,k,V], pos [B,k], kv_block, ind_block
        caches.merge_step_logits(&out[0], &out[1])?;
        if self.cfg.sparse {
            caches.scatter_kv_block_sparse(block_start, block, &out[2])?;
        } else {
            caches.scatter_kv_block(block_start, block, &out[2])?;
        }
        caches.scatter_ind_block(&indicator, &ind_for_exe, block_start, block, &out[3])?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels() {
        assert_eq!(Method::Vanilla.label(), "vanilla");
        assert_eq!(Method::DualCache.label(), "DualCache");
        assert_eq!(Method::EsDllm.label(), "ES-dLLM");
    }

    #[test]
    fn default_cfg_matches_arch_family() {
        let l = EngineCfg::new("llada-nano", Method::EsDllm);
        assert!(matches!(
            l.sampler.strategy,
            crate::sampler::Strategy::LowConfidence
        ));
        let d = EngineCfg::new("dream-nano", Method::EsDllm);
        assert!(matches!(
            d.sampler.strategy,
            crate::sampler::Strategy::MaskgitPlus { .. }
        ));
        assert_eq!(l.alpha, 0.5);
        assert_eq!(l.block, 8);
    }

    #[test]
    fn adaptive_exe_thresholds() {
        // quiescent → aggressive variant
        assert_eq!(adaptive_es_exe(32, 8, 0.001), "es_r2_only_75_blk32_b8");
        // moderate drift → default
        assert_eq!(adaptive_es_exe(32, 8, 0.02), "es_blk32_b8");
        // large drift → conservative
        assert_eq!(adaptive_es_exe(32, 8, 0.2), "es_r2_only_25_blk32_b8");
    }
}
