//! Serving metrics: counters, latency histograms, throughput accounting.
//! Exposed via the HTTP `/metrics` endpoint in a Prometheus-like text
//! format and consumed by the benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram over fixed log-spaced buckets (microseconds to
/// minutes), plus exact quantiles from a bounded reservoir.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    bounds_us: Vec<u64>,
    reservoir: Mutex<Vec<f64>>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

const RESERVOIR_CAP: usize = 4096;

impl Default for Histogram {
    fn default() -> Self {
        // 100us .. ~100s, ~x2.15 steps
        let bounds_us: Vec<u64> = (0..20)
            .map(|i| (100.0 * 2.15f64.powi(i)) as u64)
            .collect();
        Histogram {
            buckets: (0..bounds_us.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            bounds_us,
            reservoir: Mutex::new(Vec::with_capacity(RESERVOIR_CAP)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe_secs(&self, s: f64) {
        let us = (s * 1e6) as u64;
        let idx = self.bounds_us.partition_point(|b| *b < us);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        let n = self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        let mut r = self.reservoir.lock().unwrap();
        if r.len() < RESERVOIR_CAP {
            r.push(s);
        } else {
            // simple reservoir sampling keeps quantiles representative
            let j = (n as usize) % (RESERVOIR_CAP * 4);
            if j < RESERVOIR_CAP {
                r[j] = s;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
    }

    pub fn quantile(&self, q: f64) -> f64 {
        let mut r = self.reservoir.lock().unwrap().clone();
        if r.is_empty() {
            return 0.0;
        }
        r.sort_by(|a, b| a.partial_cmp(b).unwrap());
        r[((r.len() as f64 - 1.0) * q).round() as usize]
    }
}

/// Server-wide metrics registry.
#[derive(Default)]
pub struct Metrics {
    pub requests_total: Counter,
    pub requests_rejected: Counter,
    pub tokens_generated: Counter,
    pub iterations_total: Counter,
    pub prefill_steps: Counter,
    pub dual_steps: Counter,
    pub es_steps: Counter,
    pub batches_total: Counter,
    pub batch_occupancy_sum: Counter,
    pub request_latency: Histogram,
    pub queue_latency: Histogram,
    started: Mutex<Option<std::time::Instant>>,
}

impl Metrics {
    pub fn start_clock(&self) {
        *self.started.lock().unwrap() = Some(std::time::Instant::now());
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn tps(&self) -> f64 {
        let up = self.uptime_secs();
        if up <= 0.0 {
            return 0.0;
        }
        self.tokens_generated.get() as f64 / up
    }

    /// Prometheus-style exposition.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let kv = [
            ("esdllm_requests_total", self.requests_total.get()),
            ("esdllm_requests_rejected", self.requests_rejected.get()),
            ("esdllm_tokens_generated", self.tokens_generated.get()),
            ("esdllm_iterations_total", self.iterations_total.get()),
            ("esdllm_prefill_steps", self.prefill_steps.get()),
            ("esdllm_dual_steps", self.dual_steps.get()),
            ("esdllm_es_steps", self.es_steps.get()),
            ("esdllm_batches_total", self.batches_total.get()),
        ];
        for (k, v) in kv {
            out.push_str(&format!("{k} {v}\n"));
        }
        out.push_str(&format!("esdllm_throughput_tps {:.3}\n", self.tps()));
        out.push_str(&format!(
            "esdllm_request_latency_seconds_mean {:.6}\n",
            self.request_latency.mean_secs()
        ));
        for q in [0.5, 0.9, 0.99] {
            out.push_str(&format!(
                "esdllm_request_latency_seconds_p{} {:.6}\n",
                (q * 100.0) as u32,
                self.request_latency.quantile(q)
            ));
        }
        let batches = self.batches_total.get().max(1);
        out.push_str(&format!(
            "esdllm_batch_occupancy_mean {:.3}\n",
            self.batch_occupancy_sum.get() as f64 / batches as f64
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.observe_secs(i as f64 / 100.0);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        assert!(p50 <= p90);
        assert!((h.mean_secs() - 0.505).abs() < 0.02);
    }

    #[test]
    fn render_contains_counters() {
        let m = Metrics::default();
        m.start_clock();
        m.requests_total.inc();
        m.tokens_generated.add(32);
        let text = m.render();
        assert!(text.contains("esdllm_requests_total 1"));
        assert!(text.contains("esdllm_tokens_generated 32"));
    }
}
