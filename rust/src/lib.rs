//! ES-dLLM: Efficient Inference for Diffusion Large Language Models by
//! Early-Skipping — a production-style reproduction.
//!
//! Three-layer architecture:
//!   * Layer 1 (build time): Pallas kernels under `python/compile/kernels/`.
//!   * Layer 2 (build time): JAX diffusion-transformer step functions under
//!     `python/compile/model.py`, AOT-lowered to HLO text in `artifacts/`.
//!   * Layer 3 (this crate): the serving coordinator — request routing,
//!     continuous batching, KV/hidden/confidence cache management, the
//!     early-skip decode engine, refresh policies, sampling, metrics and an
//!     HTTP front end. Python never runs on the request path.
//!
//! Serving data path (one worker thread per PJRT runtime):
//!
//! ```text
//! httpd → server (/generate: prompt + per-request params)
//!       → router (bounded queue; backpressure → 503)
//!       → scheduler::GroupScheduler  ← the continuous-batching core
//!           fixed batch slots; per-sequence SeqState machines;
//!           retire/admit at block boundaries; row-filtered cache merges
//!       → scheduler::StepBackend (PjrtBackend over compiled
//!         executables, or scheduler::sim::SimBackend for tests/benches)
//! ```
//!
//! [`engine::Engine`] remains the run-to-completion façade for the eval
//! and bench paths: it admits a whole prompt group into a scheduler and
//! ticks it until every sequence retires.

pub mod analysis;
pub mod batcher;
pub mod bench;
pub mod cache;
pub mod cli;
pub mod engine;
pub mod eval;
pub mod fault;
pub mod flops;
pub mod manifest;
pub mod metrics;
pub mod router;
pub mod runtime;
pub mod sampler;
pub mod scheduler;
pub mod server;
pub mod weights;
pub mod httpd;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod threadpool;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
