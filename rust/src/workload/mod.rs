//! Benchmark workload generators — an exact Rust port of
//! `python/compile/tasks.py` (same SplitMix64 stream, same FNV bench
//! hash), so evaluation sets are identical across the build and request
//! paths. Also provides request traces (Poisson arrivals) for the serving
//! benchmarks.

use crate::rng::SplitMix;

pub const BENCHMARKS: [&str; 5] = ["arith", "chain", "logic", "codegen", "listops"];

/// Paper-benchmark analog names (DESIGN.md §1) for table rendering.
pub fn paper_name(bench: &str) -> &'static str {
    match bench {
        "arith" => "GSM8K~arith",
        "chain" => "MATH~chain",
        "logic" => "BBH~logic",
        "codegen" => "HumanEval~codegen",
        "listops" => "MBPP~listops",
        _ => "?",
    }
}

pub const TRAIN_SEED_BASE: u64 = 0x5EED_0000;
pub const EVAL_SEED_BASE: u64 = 0xE7A1_0000;

fn hash_bench(bench: &str) -> u64 {
    let mut h: u32 = 2166136261;
    for c in bench.bytes() {
        h = (h ^ c as u32).wrapping_mul(16777619);
    }
    h as u64
}

/// Deterministic (prompt, answer) for (bench, seed) — matches
/// `tasks.sample` in python exactly.
pub fn sample(bench: &str, seed: u64) -> (String, String) {
    let mut rng = SplitMix::new((hash_bench(bench) << 32) ^ seed);
    match bench {
        "arith" => gen_arith(&mut rng),
        "chain" => gen_chain(&mut rng),
        "logic" => gen_logic(&mut rng),
        "codegen" => gen_codegen(&mut rng),
        "listops" => gen_listops(&mut rng),
        other => panic!("unknown benchmark {other}"),
    }
}

fn arith_pair(rng: &mut SplitMix) -> (String, String) {
    let a = rng.range(1, 99);
    let b = rng.range(1, 99);
    if rng.below(3) == 0 && a >= b {
        return (format!("{a}-{b}="), format!("{}", a - b));
    }
    if rng.below(4) == 0 {
        let a = rng.range(2, 9);
        let b = rng.range(2, 9);
        return (format!("{a}*{b}="), format!("{}", a * b));
    }
    (format!("{a}+{b}="), format!("{}", a + b))
}

fn gen_arith(rng: &mut SplitMix) -> (String, String) {
    let mut shots = Vec::new();
    for _ in 0..2 {
        let (q, a) = arith_pair(rng);
        shots.push(format!("{q}{a}"));
    }
    let (q, a) = arith_pair(rng);
    shots.push(q);
    (shots.join("|"), a)
}

fn expr(rng: &mut SplitMix, depth: u32) -> (String, i64) {
    if depth == 0 {
        let v = rng.range(1, 9);
        return (v.to_string(), v);
    }
    let (ls, lv) = expr(rng, depth - 1);
    let rv = rng.range(1, 9);
    let op = [b'+', b'-', b'*'][rng.below(3) as usize];
    let val = match op {
        b'+' => lv + rv,
        b'-' => lv - rv,
        _ => lv * rv,
    };
    if val.abs() > 99 {
        return (format!("({ls}+{rv})"), lv + rv);
    }
    (format!("({ls}{}{rv})", op as char), val)
}

fn gen_chain(rng: &mut SplitMix) -> (String, String) {
    let depth = rng.range(2, 3) as u32;
    let (s, v) = expr(rng, depth);
    (format!("{s}="), v.to_string())
}

fn bexpr(rng: &mut SplitMix, depth: u32) -> (String, bool) {
    if depth == 0 {
        let v = rng.below(2) == 1;
        return ((if v { "t" } else { "f" }).to_string(), v);
    }
    if rng.below(4) == 0 {
        let (ls, lv) = bexpr(rng, depth - 1);
        return (format!("!{ls}"), !lv);
    }
    let (ls, lv) = bexpr(rng, depth - 1);
    let (rs, rv) = bexpr(rng, 0);
    if rng.below(2) == 0 {
        (format!("({ls}&{rs})"), lv && rv)
    } else {
        (format!("({ls}|{rs})"), lv || rv)
    }
}

fn gen_logic(rng: &mut SplitMix) -> (String, String) {
    let depth = rng.range(2, 3) as u32;
    let (s, v) = bexpr(rng, depth);
    (format!("{s}="), (if v { "t" } else { "f" }).to_string())
}

fn gen_codegen(rng: &mut SplitMix) -> (String, String) {
    let k = rng.range(2, 9);
    let op = [b'+', b'-', b'*'][rng.below(3) as usize];
    let x1 = rng.range(1, 9);
    let x2 = rng.range(1, 9);
    let apply = |x: i64| match op {
        b'+' => x + k,
        b'-' => x - k,
        _ => x * k,
    };
    (
        format!("f(x)=x{}{k}|f({x1})={}|f({x2})=", op as char, apply(x1)),
        apply(x2).to_string(),
    )
}

fn gen_listops(rng: &mut SplitMix) -> (String, String) {
    let n = rng.range(3, 5);
    let xs: Vec<i64> = (0..n).map(|_| rng.below(10) as i64).collect();
    let kind = rng.below(3);
    let body = xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
    match kind {
        0 => {
            let mut s = xs.clone();
            s.sort();
            (
                format!("sort({body})="),
                s.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(","),
            )
        }
        1 => {
            let r: Vec<String> = xs.iter().rev().map(|x| x.to_string()).collect();
            (format!("rev({body})="), r.join(","))
        }
        _ => (format!("max({body})="), xs.iter().max().unwrap().to_string()),
    }
}

/// A scored evaluation item.
#[derive(Debug, Clone)]
pub struct EvalItem {
    pub bench: &'static str,
    pub seed: u64,
    pub prompt: String,
    pub answer: String,
}

/// Deterministic eval set for a benchmark (disjoint from training seeds).
pub fn eval_set(bench: &'static str, n: usize) -> Vec<EvalItem> {
    (0..n)
        .map(|i| {
            let seed = EVAL_SEED_BASE + i as u64;
            let (prompt, answer) = sample(bench, seed);
            EvalItem { bench, seed, prompt, answer }
        })
        .collect()
}

/// Exact-match scoring on the decoded answer span (the paper's
/// exact_match / pass@1 analog).
pub fn score(expected: &str, generated: &str) -> bool {
    expected.trim() == generated.trim()
}

// ---------------------------------------------------------------------------
// request traces for the serving benchmarks
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// arrival offset from trace start, seconds
    pub at_s: f64,
    pub item: EvalItem,
}

/// Poisson arrival trace mixing all benchmarks (serving-style load).
pub fn poisson_trace(rate_per_s: f64, n: usize, seed: u64) -> Vec<TraceRequest> {
    let mut rng = SplitMix::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exp(rate_per_s);
            let bench = BENCHMARKS[rng.below(BENCHMARKS.len() as u64) as usize];
            let seed = EVAL_SEED_BASE + 50_000 + i as u64;
            let (prompt, answer) = sample(bench, seed);
            TraceRequest { at_s: t, item: EvalItem { bench, seed, prompt, answer } }
        })
        .collect()
}

/// Characters chat messages draw from (all encodable by the builtin
/// tokenizer).
const CHAT_CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";

fn chat_word(rng: &mut SplitMix, n: usize) -> String {
    (0..n)
        .map(|_| CHAT_CHARS[rng.below(CHAT_CHARS.len() as u64) as usize] as char)
        .collect()
}

/// Deterministic multi-turn chat trace: every conversation opens with
/// the SAME seeded system prompt, and each turn re-submits the full
/// prior context plus a fresh 7-char user message (`sys|m1|m2|…|mi`) —
/// so turn i's prompt is a strict string prefix of turn i+1's, the
/// serving pattern the cross-request prefix cache exists for. Turns
/// whose context would exceed `prompt_len` are dropped (the
/// conversation ends early), arrivals are Poisson at `rate_per_s`, and
/// the whole trace is a pure function of `seed`. Conversations are
/// emitted sequentially, so replaying turn-by-turn (each turn retired
/// before the next is admitted) warms the prefix cache exactly once
/// per turn.
pub fn chat_trace(
    conversations: usize,
    turns: usize,
    rate_per_s: f64,
    prompt_len: usize,
    seed: u64,
) -> Vec<TraceRequest> {
    let mut rng = SplitMix::new(seed);
    // 15-char system prompt: with 8 chars per turn ("|" + message) a
    // 4-turn conversation tops out at 47 chars — inside the sim's
    // 48-token prompt region
    let sys = chat_word(&mut rng, 15);
    let mut t = 0.0;
    let mut out = Vec::new();
    for c in 0..conversations {
        let mut ctx = sys.clone();
        for turn in 0..turns {
            let msg = chat_word(&mut rng, 7);
            ctx = format!("{ctx}|{msg}");
            if ctx.len() > prompt_len {
                break;
            }
            t += rng.exp(rate_per_s);
            out.push(TraceRequest {
                at_s: t,
                item: EvalItem {
                    bench: "chat",
                    seed: seed ^ ((c as u64) << 16) ^ turn as u64,
                    prompt: ctx.clone(),
                    answer: String::new(),
                },
            });
        }
    }
    out
}

/// Replay a trace open-loop against `submit`: each request is issued at
/// its Poisson arrival offset (relative to the first call), regardless
/// of how fast earlier requests complete — the serving-benchmark load
/// model. `submit` should enqueue without blocking on completion (e.g.
/// `Router::submit` returning a oneshot to wait on later).
pub fn replay_trace<F: FnMut(&TraceRequest)>(trace: &[TraceRequest], mut submit: F) {
    let t0 = std::time::Instant::now();
    for req in trace {
        let now = t0.elapsed().as_secs_f64();
        if req.at_s > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(req.at_s - now));
        }
        submit(req);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sampling() {
        let (p1, a1) = sample("arith", 123);
        let (p2, a2) = sample("arith", 123);
        assert_eq!((p1, a1), (p2, a2));
        let (p3, _) = sample("arith", 124);
        assert_ne!(sample("arith", 123).0, p3);
    }

    #[test]
    fn all_benchmarks_generate() {
        for b in BENCHMARKS {
            for s in 0..50 {
                let (p, a) = sample(b, s);
                assert!(!p.is_empty() && !a.is_empty(), "{b}/{s}");
                assert!(p.len() <= 48, "prompt too long: {b}/{s}: {p}");
                assert!(a.len() <= 31, "answer too long: {b}/{s}: {a}");
            }
        }
    }

    #[test]
    fn listops_answers_are_correct() {
        for s in 0..200 {
            let (p, a) = sample("listops", s);
            if let Some(body) = p.strip_prefix("sort(").and_then(|r| r.strip_suffix(")=")) {
                let mut xs: Vec<i64> =
                    body.split(',').map(|x| x.parse().unwrap()).collect();
                xs.sort();
                let want =
                    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
                assert_eq!(a, want);
            }
        }
    }

    #[test]
    fn trace_is_sorted_in_time() {
        let t = poisson_trace(100.0, 50, 7);
        assert_eq!(t.len(), 50);
        for w in t.windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
    }

    #[test]
    fn score_trims() {
        assert!(score("42", " 42 "));
        assert!(!score("42", "43"));
    }

    #[test]
    fn chat_trace_turns_grow_by_prefix() {
        let trace = chat_trace(3, 4, 100.0, 48, 11);
        assert_eq!(trace.len(), 12);
        // deterministic
        let again = chat_trace(3, 4, 100.0, 48, 11);
        let p: Vec<&str> = trace.iter().map(|r| r.item.prompt.as_str()).collect();
        let q: Vec<&str> = again.iter().map(|r| r.item.prompt.as_str()).collect();
        assert_eq!(p, q);
        for (i, r) in trace.iter().enumerate() {
            assert!(r.item.prompt.len() <= 48);
            // every conversation opens with the shared system prompt
            assert_eq!(r.item.prompt.as_bytes()[..15], trace[0].item.prompt.as_bytes()[..15]);
            // within a conversation each turn extends the previous one
            if i % 4 != 0 {
                assert!(
                    r.item.prompt.starts_with(&trace[i - 1].item.prompt),
                    "turn {i} does not extend its predecessor"
                );
            }
        }
        // distinct conversations diverge after the system prompt
        assert_ne!(trace[0].item.prompt, trace[4].item.prompt);
        // arrivals are nondecreasing
        for w in trace.windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
    }

    #[test]
    fn replay_paces_arrivals() {
        // high-rate trace: replay must deliver every request, in order,
        // and take at least the last arrival offset
        let trace = poisson_trace(500.0, 20, 3);
        let mut seen = Vec::new();
        let t0 = std::time::Instant::now();
        replay_trace(&trace, |r| seen.push(r.item.seed));
        let elapsed = t0.elapsed().as_secs_f64();
        assert_eq!(seen.len(), 20);
        let expect: Vec<u64> = trace.iter().map(|r| r.item.seed).collect();
        assert_eq!(seen, expect);
        assert!(elapsed + 0.005 >= trace.last().unwrap().at_s);
    }
}
