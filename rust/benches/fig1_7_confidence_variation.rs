//! Figures 1 (llada) & 7 (dream): confidence-variation statistics during
//! generation — |Δconfidence| distribution (1b/7b) and the per-iteration
//! fraction of positions with |Δconf| > 0.05 (1c/7c). Series are printed
//! and written as CSVs under artifacts/figures/.

use esdllm::analysis::{frac_above, histogram, observe_generation};
use esdllm::bench::{bench_archs, bench_n, Table};
use esdllm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    // paper uses 100 samples; default here is bench_n(24)/8 groups ×8 seqs
    let groups = (bench_n(24) / 8).max(1);

    for arch in bench_archs() {
        let fig = if arch.starts_with("llada") { "fig1" } else { "fig7" };
        let stats = observe_generation(&rt, &arch, groups)?;

        // (b) distribution of |Δconfidence|
        let bins = [0.001f32, 0.005, 0.01, 0.05, 0.1, 0.3, 0.6];
        let all: Vec<f32> = stats
            .records
            .iter()
            .flat_map(|r| r.conf_delta.iter().cloned())
            .collect();
        let h = histogram(all.iter().cloned(), &bins);
        let total: usize = h.iter().sum();
        let mut dist = Table::new(
            &format!("{fig}b analog: |Δconfidence| distribution ({arch}, {} positions)", total),
            &["bin_lo", "bin_hi", "count", "fraction"],
        );
        let mut lo = 0.0f32;
        for (i, c) in h.iter().enumerate() {
            let hi = bins.get(i).copied().unwrap_or(f32::INFINITY);
            dist.row(&[
                format!("{lo:.3}"),
                format!("{hi:.3}"),
                format!("{c}"),
                format!("{:.4}", *c as f64 / total as f64),
            ]);
            lo = hi;
        }
        dist.print();
        dist.write_csv(&format!("artifacts/figures/{fig}b_conf_dist_{arch}.csv"))?;

        // (c) fraction > 0.05 per iteration
        let frac = frac_above(&stats, 0.05);
        let mut fr = Table::new(
            &format!("{fig}c analog: fraction of |Δconf| > 0.05 by iteration ({arch})"),
            &["iteration", "fraction"],
        );
        for (i, f) in frac.iter().enumerate() {
            fr.row(&[format!("{i}"), format!("{:.4}", f)]);
        }
        // print a summary instead of 31 rows
        let early: f64 = frac.iter().take(4).sum::<f64>() / 4.0;
        let late: f64 =
            frac.iter().skip(frac.len().saturating_sub(8)).sum::<f64>() / 8.0_f64.min(frac.len() as f64);
        println!(
            "\n{fig}c ({arch}): mean fraction |Δconf|>0.05 — first 4 iters {:.1}%, last 8 iters {:.1}% \
             (paper: <10% except initial iterations)",
            early * 100.0,
            late * 100.0
        );
        fr.write_csv(&format!("artifacts/figures/{fig}c_conf_frac_{arch}.csv"))?;
    }
    Ok(())
}
