"""Task generators + tokenizer: determinism, correctness of reference
answers, and layout constraints (prompt/answer fit the fixed regions)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tasks


def test_tokenizer_roundtrip():
    s = "sort(5,2,9)=2,5,9"
    assert tasks.decode(tasks.encode(s)) == s


def test_decode_stops_at_eos():
    ids = tasks.encode("42") + [tasks.EOS] + tasks.encode("junk")
    assert tasks.decode(ids) == "42"


def test_vocab_is_frozen():
    # the Rust tokenizer and the training data depend on this exact table
    assert tasks.TOKENS[:4] == ["<pad>", "<mask>", "<eos>", "<bos>"]
    assert tasks.TOKENS[4] == "0"
    assert len(tasks.TOKENS) <= tasks.VOCAB == 64


@settings(max_examples=50, deadline=None)
@given(bench=st.sampled_from(sorted(tasks.BENCHMARKS)),
       seed=st.integers(0, 2**30))
def test_samples_fit_fixed_regions(bench, seed):
    prompt, answer = tasks.sample(bench, seed)
    assert 0 < len(prompt) <= 48
    assert 0 < len(answer) <= 31
    # round-trip through the tokenizer must be lossless
    assert tasks.decode(tasks.encode(prompt)) == prompt
    assert tasks.decode(tasks.encode(answer)) == answer


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_sampling_is_deterministic(seed):
    for bench in tasks.BENCHMARKS:
        assert tasks.sample(bench, seed) == tasks.sample(bench, seed)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_listops_reference_answers(seed):
    prompt, answer = tasks.sample("listops", seed)
    if prompt.startswith("sort("):
        xs = sorted(int(x) for x in prompt[5:-2].split(","))
        assert answer == ",".join(map(str, xs))
    elif prompt.startswith("rev("):
        xs = [x for x in prompt[4:-2].split(",")][::-1]
        assert answer == ",".join(xs)
    elif prompt.startswith("max("):
        xs = [int(x) for x in prompt[4:-2].split(",")]
        assert answer == str(max(xs))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_arith_reference_answers(seed):
    prompt, answer = tasks.sample("arith", seed)
    q = prompt.rsplit("|", 1)[-1].rstrip("=")
    for op in "+-*":
        if op in q[1:]:
            i = q.rindex(op)
            a, b = int(q[:i]), int(q[i + 1:])
            val = {"+": a + b, "-": a - b, "*": a * b}[op]
            assert answer == str(val)
            return
    pytest.fail(f"unparsable arith prompt {prompt!r}")


def test_make_example_layout():
    p, a, prompt, answer = tasks.make_example("logic", 7, 48, 32)
    assert len(p) == 48 and len(a) == 32
    # prompt right-padded with PAD; answer EOS-filled
    assert p[-1] == tasks.PAD or len(prompt) == 48
    assert a[-1] == tasks.EOS
    assert tasks.decode(a) == answer


def test_splitmix_reference_values():
    # frozen reference shared with rust/src/rng (tests there use the same)
    r = tasks.SplitMix(42)
    assert [r.next64() for _ in range(2)] == [
        13679457532755275413, 2949826092126892291]
