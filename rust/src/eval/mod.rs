//! Benchmark evaluation: per-benchmark generation settings (the paper's
//! Tables 4–6 analogs) and the runner that produces (TPS, score) rows for
//! every method — the machinery behind all table benches.

use anyhow::Result;

use crate::cache::RefreshPolicy;
use crate::engine::{Engine, EngineCfg, Method};
use crate::runtime::Runtime;
use crate::sampler::SamplerCfg;
use crate::workload::{self, EvalItem};

/// Per-benchmark generation configuration (Table 4 analog: gen/block
/// lengths scaled 256→32; the chain/MATH benchmark decodes its whole
/// output as a single block).
#[derive(Debug, Clone, Copy)]
pub struct BenchCfg {
    pub bench: &'static str,
    pub block: usize,
    /// ES-dLLM refresh periods (Table 5 analog)
    pub refresh: RefreshPolicy,
    /// ES-dLLM* refresh periods (Table 6 analog)
    pub refresh_star: RefreshPolicy,
}

pub const BENCH_CFGS: [BenchCfg; 5] = [
    BenchCfg {
        bench: "arith",
        block: 8,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 4 },
        refresh_star: RefreshPolicy { prompt_period: 8, block_period: 2 },
    },
    BenchCfg {
        bench: "chain",
        block: 32,
        refresh: RefreshPolicy { prompt_period: 33, block_period: 8 },
        refresh_star: RefreshPolicy { prompt_period: 16, block_period: 4 },
    },
    BenchCfg {
        bench: "logic",
        block: 8,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 4 },
        refresh_star: RefreshPolicy { prompt_period: 8, block_period: 2 },
    },
    BenchCfg {
        bench: "codegen",
        block: 8,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
        refresh_star: RefreshPolicy { prompt_period: 8, block_period: 2 },
    },
    BenchCfg {
        bench: "listops",
        block: 8,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
        refresh_star: RefreshPolicy { prompt_period: 8, block_period: 2 },
    },
];

pub fn bench_cfg(bench: &str) -> BenchCfg {
    BENCH_CFGS
        .iter()
        .find(|c| c.bench == bench)
        .copied()
        .unwrap_or(BENCH_CFGS[0])
}

/// Result of evaluating one (benchmark, method) cell.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub bench: &'static str,
    pub method: String,
    pub tps: f64,
    pub score: f64,
    pub n_samples: usize,
    pub iterations: usize,
    pub n_prefill: usize,
    pub n_dual: usize,
    pub n_es: usize,
    pub wall_s: f64,
}

impl EvalResult {
    pub fn speedup_vs(&self, baseline: &EvalResult) -> f64 {
        self.tps / baseline.tps
    }
}

/// Options modifying a base engine config for a table variant.
#[derive(Debug, Clone, Default)]
pub struct EvalOpts {
    pub checkpoint: Option<String>,
    pub parallel_threshold: Option<f32>,
    pub sparse: bool,
    pub alpha: Option<f32>,
    pub indicator: Option<String>,
    pub es_exe_override: Option<String>,
    pub refresh_star: bool,
    pub sampler: Option<SamplerCfg>,
}

/// Build the engine config for (arch, method, benchmark, opts).
pub fn engine_cfg(arch: &str, method: Method, bc: &BenchCfg, opts: &EvalOpts) -> EngineCfg {
    let mut cfg = EngineCfg::new(arch, method);
    cfg.block = bc.block;
    cfg.refresh = if opts.refresh_star { bc.refresh_star } else { bc.refresh };
    if let Some(ck) = &opts.checkpoint {
        cfg.checkpoint = ck.clone();
    }
    if let Some(t) = opts.parallel_threshold {
        cfg.sampler = cfg.sampler.with_parallel(t);
    }
    if let Some(s) = opts.sampler {
        cfg.sampler = s;
    }
    cfg.sparse = opts.sparse;
    if let Some(a) = opts.alpha {
        cfg.alpha = a;
    }
    if let Some(i) = &opts.indicator {
        cfg.indicator = i.clone();
    }
    cfg.es_exe_override = opts.es_exe_override.clone();
    cfg
}

/// Evaluate one (arch, method, benchmark) cell over `n` samples in batched
/// groups of 8 (the paper's batch size).
pub fn evaluate(
    rt: &Runtime,
    arch: &str,
    method: Method,
    bench: &'static str,
    n: usize,
    opts: &EvalOpts,
) -> Result<EvalResult> {
    let bc = bench_cfg(bench);
    let items: Vec<EvalItem> = workload::eval_set(bench, n);
    let cfg = engine_cfg(arch, method, &bc, opts);
    let mut engine = Engine::new(rt, cfg);
    // compile outside the measurement window (PJRT compiles cost seconds;
    // leaving them inside would understate the first cells' TPS)
    engine.precompile(if n <= 1 { 1 } else { 8 })?;

    let mut correct = 0usize;
    let mut res = EvalResult {
        bench,
        method: method_label(method, opts),
        tps: 0.0,
        score: 0.0,
        n_samples: n,
        iterations: 0,
        n_prefill: 0,
        n_dual: 0,
        n_es: 0,
        wall_s: 0.0,
    };
    let mut tokens = 0usize;
    for group in items.chunks(8) {
        let prompts: Vec<String> = group.iter().map(|i| i.prompt.clone()).collect();
        let g = engine.generate(&prompts)?;
        for (item, text) in group.iter().zip(&g.texts) {
            if workload::score(&item.answer, text) {
                correct += 1;
            }
        }
        res.iterations += g.iterations;
        res.n_prefill += g.n_prefill;
        res.n_dual += g.n_dual;
        res.n_es += g.n_es;
        res.wall_s += g.wall_s;
        tokens += g.tokens_generated;
    }
    // TPS over tokens actually emitted: the EOS guard retires sequences
    // at block boundaries before the full gen region is decoded, so
    // crediting n * gen_len would inflate throughput purely by accounting
    res.tps = tokens as f64 / res.wall_s;
    res.score = 100.0 * correct as f64 / n as f64;
    Ok(res)
}

pub fn method_label(method: Method, opts: &EvalOpts) -> String {
    let mut label = method.label().to_string();
    if opts.refresh_star {
        label.push('*');
    }
    if opts.parallel_threshold.is_some() {
        label.push_str("+PD");
    }
    if opts.sparse {
        label.push_str("+Sparse");
    }
    if let Some(ck) = &opts.checkpoint {
        if ck == "base" {
            label.push_str(" (base)");
        }
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_cfg_lookup() {
        assert_eq!(bench_cfg("chain").block, 32);
        assert_eq!(bench_cfg("arith").block, 8);
        // unknown falls back to the first config
        assert_eq!(bench_cfg("nope").bench, "arith");
    }

    #[test]
    fn labels_compose() {
        let mut o = EvalOpts::default();
        o.parallel_threshold = Some(0.9);
        o.sparse = true;
        assert_eq!(method_label(Method::EsDllm, &o), "ES-dLLM+PD+Sparse");
        o = EvalOpts { refresh_star: true, ..Default::default() };
        assert_eq!(method_label(Method::EsDllm, &o), "ES-dLLM*");
    }

    #[test]
    fn engine_cfg_applies_opts() {
        let bc = bench_cfg("arith");
        let opts = EvalOpts {
            alpha: Some(0.25),
            indicator: Some("q".into()),
            checkpoint: Some("base".into()),
            ..Default::default()
        };
        let cfg = engine_cfg("llada-nano", Method::EsDllm, &bc, &opts);
        assert_eq!(cfg.alpha, 0.25);
        assert_eq!(cfg.indicator, "q");
        assert_eq!(cfg.checkpoint, "base");
        assert_eq!(cfg.block, 8);
    }
}
