//! Observation experiments (paper §4, Figures 1, 2, 5–8, Table 3):
//! per-iteration confidence variation and intermediate-tensor variation
//! statistics, collected by replaying vanilla generation through the
//! `observe` executable (full forward + probe tensors at layers 2/5/7).

use anyhow::Result;

use crate::cache::softmax_max;
use crate::rng::SplitMix;
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;
use crate::sampler::{decide_unmask_with, SamplerCfg, SamplerScratch, UnmaskInput};

pub const PROBE_TENSORS: [&str; 4] = ["hidden", "query", "key", "value"];

/// Per-iteration record for one batch of sequences.
#[derive(Debug, Clone)]
pub struct IterRecord {
    /// |Δconfidence| per (seq, gen position)
    pub conf_delta: Vec<f32>,
    /// normalized L1 variation per probe layer × tensor × (seq, pos)
    pub var: Vec<Vec<Vec<f32>>>, // [probe][tensor][seq*pos]
}

#[derive(Debug, Clone)]
pub struct ObservationStats {
    pub probe_layers: Vec<usize>,
    pub records: Vec<IterRecord>,
    pub gen_len: usize,
    pub batch: usize,
}

/// Replay vanilla generation for `groups` batches of 8 sequences drawn
/// from all benchmarks, recording confidence deltas and tensor variation
/// between successive iterations (the paper's 100-sample methodology).
pub fn observe_generation(rt: &Runtime, arch_name: &str, groups: usize) -> Result<ObservationStats> {
    let arch = rt.arch(arch_name)?.clone();
    let d = &arch.dims;
    let probe_layers = rt.manifest.generation.observe_probe_layers.clone();
    let exe = arch.exe("observe_b8")?;
    let tok = &rt.tokenizer;
    let gen = d.gen_len;
    let sampler = SamplerCfg::llada();
    let mut rng = SplitMix::new(0x0B5E);
    let mut scratch = SamplerScratch::default();

    let mut stats = ObservationStats {
        probe_layers: probe_layers.clone(),
        records: vec![],
        gen_len: gen,
        batch: 8,
    };

    for g in 0..groups {
        // mixed-benchmark batch (the paper samples across datasets)
        let mut tokens = vec![0i32; 8 * d.ctx];
        for b in 0..8 {
            let bench = crate::workload::BENCHMARKS[(g * 8 + b) % 5];
            let item = &crate::workload::eval_set(bench, g * 8 + b + 1)[g * 8 + b];
            let ids = tok.encode_prompt(&item.prompt, d.prompt_len)?;
            tokens[b * d.ctx..b * d.ctx + d.prompt_len].copy_from_slice(&ids);
            for i in 0..gen {
                tokens[b * d.ctx + d.prompt_len + i] = tok.mask;
            }
        }

        let mut prev_conf: Option<Vec<f32>> = None;
        let mut prev_probes: Option<Vec<f32>> = None;
        for _iter in 0..gen {
            let toks_t = HostTensor::I32 { shape: vec![8, d.ctx], data: tokens.clone() };
            let out = rt.run(&arch, exe, "instruct", &[toks_t])?;
            let logits = out[0].as_f32()?;
            let probes = out[1].as_f32()?; // [n_probe, 4, 8, gen, d]

            // confidence per gen position
            let mut conf = vec![0f32; 8 * gen];
            for b in 0..8 {
                for i in 0..gen {
                    let off = (b * d.ctx + d.prompt_len + i) * d.vocab;
                    conf[b * gen + i] = softmax_max(&logits[off..off + d.vocab]);
                }
            }

            if let (Some(pc), Some(pp)) = (&prev_conf, &prev_probes) {
                let conf_delta: Vec<f32> =
                    conf.iter().zip(pc.iter()).map(|(a, b)| (a - b).abs()).collect();
                let mut var = vec![vec![vec![]; 4]; probe_layers.len()];
                let row = d.d_model;
                let per_tensor = 8 * gen * row;
                for (pi, v_p) in var.iter_mut().enumerate() {
                    for (ti, v_t) in v_p.iter_mut().enumerate() {
                        let base = (pi * 4 + ti) * per_tensor;
                        for r in 0..8 * gen {
                            let cur = &probes[base + r * row..base + (r + 1) * row];
                            let prev = &pp[base + r * row..base + (r + 1) * row];
                            v_t.push(varnorm_row(cur, prev));
                        }
                    }
                }
                stats.records.push(IterRecord { conf_delta, var });
            }
            prev_conf = Some(conf.clone());
            prev_probes = Some(probes.to_vec());

            // unmask one token per sequence (vanilla low-confidence order,
            // whole gen region — matches the paper's observation setup)
            for b in 0..8 {
                let gen_tokens = &tokens[b * d.ctx + d.prompt_len..b * d.ctx + d.ctx];
                let inp = UnmaskInput {
                    logits: &logits_rows(logits, b, d.ctx, d.prompt_len, gen, d.vocab),
                    conf: &conf[b * gen..(b + 1) * gen],
                    gen_tokens,
                    block_lo: 0,
                    block_hi: gen,
                    vocab: d.vocab,
                    mask_id: tok.mask,
                    eos_id: tok.eos,
                };
                let dec = decide_unmask_with(&sampler, &inp, &mut rng, &mut scratch);
                for (p, t) in dec.positions.iter().zip(&dec.tokens) {
                    tokens[b * d.ctx + d.prompt_len + p] = *t;
                }
            }
        }
    }
    Ok(stats)
}

fn logits_rows(
    logits: &[f32],
    b: usize,
    ctx: usize,
    prompt_len: usize,
    gen: usize,
    vocab: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; gen * vocab];
    for i in 0..gen {
        let src = (b * ctx + prompt_len + i) * vocab;
        out[i * vocab..(i + 1) * vocab].copy_from_slice(&logits[src..src + vocab]);
    }
    out
}

fn varnorm_row(cur: &[f32], prev: &[f32]) -> f32 {
    let d = cur.len() as f32;
    let l1: f32 = cur.iter().zip(prev).map(|(a, b)| (a - b).abs()).sum();
    let l2: f32 = prev.iter().map(|x| x * x).sum::<f32>().sqrt();
    l1 / (d.sqrt() * l2 + 1e-6)
}

// ---------------------------------------------------------------------------
// summaries for the figure benches
// ---------------------------------------------------------------------------

/// Histogram of values over log-spaced bins (figures 1b, 2b, 5, 6, 8).
pub fn histogram(values: impl Iterator<Item = f32>, bins: &[f32]) -> Vec<usize> {
    let mut counts = vec![0usize; bins.len() + 1];
    for v in values {
        let idx = bins.partition_point(|b| *b < v);
        counts[idx] += 1;
    }
    counts
}

/// Fraction of positions with confidence variation > threshold, per
/// iteration (figure 1c).
pub fn frac_above(stats: &ObservationStats, threshold: f32) -> Vec<f64> {
    stats
        .records
        .iter()
        .map(|r| {
            let n = r.conf_delta.len().max(1);
            r.conf_delta.iter().filter(|v| **v > threshold).count() as f64 / n as f64
        })
        .collect()
}

/// Pearson correlation between tensor variation and |Δconfidence|
/// (Table 3 analog).
pub fn pearson(xs: &[f32], ys: &[f32]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().map(|v| *v as f64).sum::<f64>() / n;
    let my = ys.iter().map(|v| *v as f64).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = *x as f64 - mx;
        let dy = *y as f64 - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        let ys = [2.0f32, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        let neg = [4.0f32, 3.0, 2.0, 1.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_bins() {
        let bins = [0.1f32, 1.0];
        let h = histogram([0.05f32, 0.5, 5.0, 0.09].into_iter(), &bins);
        assert_eq!(h, vec![2, 1, 1]);
    }

    #[test]
    fn varnorm_zero_for_identical() {
        let a = [1.0f32, -2.0, 3.0];
        assert_eq!(varnorm_row(&a, &a), 0.0);
        assert!(varnorm_row(&[2.0, -2.0, 3.0], &a) > 0.0);
    }
}
