//! Checkpoint loader: flat little-endian f32 records in the canonical
//! parameter order (`ESDW` format written by `python/compile/train.py`).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::manifest::ArchSpec;

#[derive(Debug)]
pub struct Checkpoint {
    /// tensors in canonical parameter order
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn load(path: &Path, arch: &ArchSpec) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        if bytes.len() < 12 || &bytes[0..4] != b"ESDW" {
            return Err(anyhow!("{}: bad magic", path.display()));
        }
        let ver = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        if ver != 1 {
            return Err(anyhow!("unsupported checkpoint version {ver}"));
        }
        if count != arch.params.len() {
            return Err(anyhow!(
                "checkpoint has {count} tensors, manifest expects {}",
                arch.params.len()
            ));
        }
        let mut off = 12usize;
        let mut tensors = Vec::with_capacity(count);
        for (name, shape) in &arch.params {
            let n: usize = shape.iter().product();
            let end = off + 4 * n;
            if end > bytes.len() {
                return Err(anyhow!("checkpoint truncated at {name}"));
            }
            let data: Vec<f32> = bytes[off..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push((name.clone(), shape.clone(), data));
            off = end;
        }
        if off != bytes.len() {
            return Err(anyhow!("checkpoint has {} trailing bytes", bytes.len() - off));
        }
        Ok(Checkpoint { tensors })
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|(_, _, d)| d.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Dims;
    use std::collections::BTreeMap;

    fn tiny_arch() -> ArchSpec {
        ArchSpec {
            name: "t".into(),
            dims: Dims {
                vocab: 4, d_model: 2, n_layers: 1, n_heads: 1, n_kv_heads: 1,
                d_ff: 4, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
            },
            checkpoints: BTreeMap::new(),
            params: vec![("a".into(), vec![2, 2]), ("b".into(), vec![3])],
            executables: BTreeMap::new(),
        }
    }

    fn write_ckpt(path: &Path, tensors: &[Vec<f32>]) {
        let mut bytes = b"ESDW".to_vec();
        bytes.extend(1u32.to_le_bytes());
        bytes.extend((tensors.len() as u32).to_le_bytes());
        for t in tensors {
            for v in t {
                bytes.extend(v.to_le_bytes());
            }
        }
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("esdllm-weights-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_ckpt(&p, &[vec![1.0, 2.0, 3.0, 4.0], vec![5.0, 6.0, 7.0]]);
        let ck = Checkpoint::load(&p, &tiny_arch()).unwrap();
        assert_eq!(ck.tensors[0].2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ck.tensors[1].2, vec![5.0, 6.0, 7.0]);
        assert_eq!(ck.total_params(), 7);
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("esdllm-weights-test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_ckpt(&p, &[vec![1.0, 2.0, 3.0, 4.0]]); // only one tensor
        assert!(Checkpoint::load(&p, &tiny_arch()).is_err());
    }
}
