"""Pure-jnp oracles for the Pallas kernels (the correctness signal used by
pytest and by training, which needs differentiable ops)."""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, scale=None):
    """Bidirectional cached-KV attention.

    q: [B, Hq, S, hd]  (S = active query set, e.g. a block or subset)
    k: [B, Hkv, T, hd] (T = full cached context)
    v: [B, Hkv, T, hd]
    returns [B, Hq, S, hd]

    GQA: query head h attends to kv head h // (Hq // Hkv).
    """
    b, hq, s, hd = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s_qk = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    p = jax.nn.softmax(s_qk, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v)


def varnorm_ref(h, h_prev, eps=1e-6):
    """Normalized L1 variation (Eq. 1, second term).

    h, h_prev: [..., d] -> [...]:
        ||h - h_prev||_1 / (sqrt(d) * ||h_prev||_2)
    """
    d = h.shape[-1]
    l1 = jnp.sum(jnp.abs(h - h_prev), axis=-1)
    l2 = jnp.sqrt(jnp.sum(h_prev * h_prev, axis=-1))
    return l1 / (jnp.sqrt(jnp.asarray(d, h.dtype)) * l2 + eps)
