//! Transfer-accounting acceptance tests for the resident-cache layer
//! and the device-apply decode path: a steady-state ES/dual tick ships
//! zero KV, indicator, and confidence bytes in either direction (only
//! block tokens + batch-bit masks go up, and the downlink is exactly
//! the gen-region logit rows — `B × block × V` logit bytes per step,
//! `B × gen × V` per grounding prefill, never `B × ctx × V`), the PJRT
//! device planner and the sim planner produce identical `TransferStats`
//! (including the D2H ledger) for the same workload, a mid-flight
//! admission dirties exactly the admitted slot, eviction invalidates
//! the resident chain, Host-apply ledger deltas match the dirty
//! bitmaps, and a donated (input-output-aliased) execution chain never
//! holds two live copies of a chained tensor — pinned against the stub
//! runtime's live-buffer ledger. The cross-request prefix cache gets
//! the same treatment: prefix-seeded admission decodes token-identical
//! to a cacheless run, the `PrefixStats` ledger is byte-exact between
//! the sim identity and a PJRT-style `(arch, owner)` identity across
//! hit / miss / evict, and prefix entries (host payloads) survive a
//! full device eviction. Everything runs over the sim backend / the
//! planner / the xla stub directly — no PJRT artifacts required.

use std::time::Instant;

use esdllm::cache::{GroupCaches, RefreshPolicy, StepPlan};
use esdllm::engine::Method;
use esdllm::manifest::Dims;
use esdllm::runtime::resident::{
    chain_seed_bytes, ApplyMode, DeviceGroupCaches, PrefixCache, PrefixStats, ResidencyPool,
    TransferKind, TransferStats,
};
use esdllm::runtime::tensor::HostTensor;
use esdllm::sampler::SamplerCfg;
use esdllm::scheduler::sim::{SimBackend, SimCfg};
use esdllm::scheduler::{GroupScheduler, SchedCfg, SeqInput, SeqParams};

fn sched_cfg(block: usize) -> SchedCfg {
    SchedCfg {
        method: Method::EsDllm,
        block,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
        sampler: SamplerCfg::llada(),
        seed: 0,
        k: 1,
        hysteresis: None,
    }
}

fn sched_with(n_slots: usize, block: usize, sim: SimCfg) -> GroupScheduler<'static> {
    let backend = SimBackend::new(sim);
    GroupScheduler::new(Box::new(backend), n_slots, sched_cfg(block)).unwrap()
}

fn sched_classes(classes: &[usize], block: usize) -> GroupScheduler<'static> {
    let backend = SimBackend::new(SimCfg::default());
    GroupScheduler::with_classes(Box::new(backend), classes, sched_cfg(block)).unwrap()
}

fn sched(n_slots: usize, block: usize) -> GroupScheduler<'static> {
    sched_with(n_slots, block, SimCfg::default())
}

fn input(id: u64, prompt: &str) -> SeqInput {
    SeqInput {
        id,
        prompt: prompt.to_string(),
        params: SeqParams::default(),
        submitted: Instant::now(),
    }
}

fn drain(s: &mut GroupScheduler<'_>) {
    let mut guard = 0;
    while s.active() > 0 {
        s.tick().unwrap();
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
    }
}

#[test]
fn steady_state_es_steps_upload_no_full_kv_bytes() {
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefgh")).unwrap();
    drain(&mut s);
    let stats = s.transfer_stats();
    let kv_full = s.group_caches().kv_bytes() as u64;

    assert_eq!(
        stats.full_kv_uploads, 1,
        "exactly one full-KV upload: the residency seed"
    );
    assert_eq!(
        stats.kv_upload_bytes, kv_full,
        "steady-state steps shipped zero KV bytes past the seed"
    );
    assert!(
        stats.upload_bytes_saved > stats.upload_bytes,
        "residency saved {} B vs {} B shipped — must dominate",
        stats.upload_bytes_saved,
        stats.upload_bytes
    );
    assert!(stats.resident_reuses > 0, "KV input reused across steps");
    assert!(stats.retained_out_reuses > 0, "outputs chained across calls");
    assert!(stats.ingraph_conf_steps > 0, "steps computed conf in-graph");
    assert!(stats.d2h_bytes_avoided > 0, "cache downloads avoided");

    // a whole second generation moves no further KV, indicator, or
    // confidence bytes — the chain persists across retirements
    s.admit(input(2, "xyab")).unwrap();
    drain(&mut s);
    let stats2 = s.transfer_stats();
    assert_eq!(stats2.full_kv_uploads, 1);
    assert_eq!(stats2.kv_upload_bytes, kv_full);
    assert_eq!(stats2.ind_upload_bytes, stats.ind_upload_bytes);
    assert_eq!(stats2.conf_upload_bytes, stats.conf_upload_bytes);
}

/// The PR's acceptance criterion: with `ApplyMode::Device`, once the
/// chain is seeded every ES/dual tick ships ONLY step tokens (plus the
/// batch-bit occupancy mask) host→device, zero KV / indicator /
/// confidence bytes in either direction, and downloads exactly the
/// block's logit rows — `B × block × V` logit bytes (+ `B × block` i32
/// positions), NOT the `B × ctx × V` full context; grounding-prefill
/// ticks download exactly the gen-region slice `B × gen × V`.
#[test]
fn device_steady_state_ships_only_tokens_and_masks() {
    let d = SimCfg::default().dims;
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefgh")).unwrap();
    s.tick().unwrap(); // grounding prefill: seeds the chain
    let batch = 2u64;
    let block = 4u64;
    let vocab = d.vocab as u64;
    // the one sequence occupies one slot, so each tick runs exactly one
    // plan: a grounding/refresh prefill, a dual step (downloads the
    // whole block's rows), or an ES step (downloads the final_keep
    // survivors — 1 of 4 under the default skip chain)
    let prefill_d2h = batch * d.gen_len as u64 * vocab * 4;
    let ctx_logit_d2h = batch * d.ctx as u64 * vocab * 4;
    let es_sel = SimCfg::n_sel(StepPlan::EsStep, block as usize) as u64;
    assert_eq!(es_sel, 1, "default skip chain at block 4 keeps one row");
    let step_d2h = |n_sel: u64| {
        // n_sel logit rows (f32) + their i32 positions
        (batch * n_sel * vocab * 4, batch * n_sel * 4)
    };

    let mut steady_ticks = 0;
    let mut guard = 0;
    while s.active() > 0 {
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
        let (pf_before, es_before) = (s.n_prefill, s.n_es);
        let before = s.transfer_stats();
        s.tick().unwrap();
        let delta = s.transfer_stats().since(&before);
        assert_eq!(delta.donated_execs, 1, "every device run donates its chain");
        if s.n_prefill > pf_before {
            // refresh-cadence prefill ticks chain too (zero cache bytes)
            // and download only the gen-region logit slice
            assert_eq!(delta.kv_upload_bytes, 0);
            assert_eq!(delta.d2h_bytes_shipped, prefill_d2h);
            assert_eq!(delta.d2h_bytes_saved, ctx_logit_d2h - prefill_d2h);
            continue;
        }
        steady_ticks += 1;
        assert_eq!(delta.kv_upload_bytes, 0, "no KV bytes up");
        assert_eq!(delta.kv_sparse_upload_bytes, 0);
        assert_eq!(delta.ind_upload_bytes, 0, "no indicator bytes up");
        assert_eq!(delta.conf_upload_bytes, 0, "no confidence bytes up");
        assert_eq!(delta.full_kv_uploads, 0);
        // exactly one step ran this tick: block tokens for the stepped
        // slot + the [B] occupancy mask, nothing else
        let expected = 4 * 4 + batch * 4;
        assert_eq!(delta.token_upload_bytes, expected);
        assert_eq!(delta.upload_bytes, expected, "tokens+mask are ALL traffic");
        assert_eq!(delta.ingraph_conf_steps, 1);
        assert_eq!(delta.retained_out_reuses, 3, "kv+ind+conf all chained");
        assert!(delta.d2h_bytes_avoided > 0, "block downloads avoided");
        // the steady-state downlink: at most B × block × V logit bytes —
        // exactly that for a dual step, the final_keep survivors for an
        // ES step — never B × ctx × V
        let n_sel = if s.n_es > es_before { es_sel } else { block };
        let (logit_b, pos_b) = step_d2h(n_sel);
        assert_eq!(delta.d2h_bytes_shipped, logit_b + pos_b);
        assert!(logit_b <= batch * block * vocab * 4);
        assert_eq!(delta.d2h_bytes_saved, ctx_logit_d2h - logit_b);
    }
    assert!(steady_ticks >= 2, "workload exercised steady-state steps");
    // sanity: geometry used above matches the sim dims
    assert_eq!(d.gen_len % 4, 0);
}

/// Byte-exact parity: the call sequence `PjrtBackend` makes on the
/// device-apply path (sync_prefill_device / sync_step_device +
/// note_*_applied, per its plan schedule) must produce the identical
/// `TransferStats` ledger as the sim backend run through the scheduler
/// on the same workload — both backends route through the same
/// composite planner, and this pins that contract. The equality is
/// over the WHOLE ledger struct, so the D2H counters
/// (`d2h_bytes_shipped` / `d2h_bytes_saved` / `donated_execs`) are
/// byte-exact between the sim and PJRT planners by the same assertion.
#[test]
fn pjrt_device_planner_matches_sim_planner() {
    // sim side: one 3-char prompt at block 4 retires after exactly
    // 4 iterations of block 0 (EOS-guard) with plans [Prefill, Es,
    // Dual, Es]
    let mut s = sched(2, 4);
    s.admit(input(1, "abc")).unwrap();
    drain(&mut s);
    assert_eq!((s.n_prefill, s.n_dual, s.n_es), (1, 1, 2), "plan schedule");
    assert_eq!(s.ticks, 4);
    let sim_stats = s.transfer_stats();

    // PJRT planner side: replicate that schedule through the planner
    // calls prefill_device_impl / step_device_impl make — n_sel per plan
    // is the executable's final_keep (block for dual, the default-skip
    // survivors for ES), exactly what step_device_impl reads from the
    // manifest and what the sim models via SimCfg::n_sel
    let d = SimCfg::default().dims;
    let mut c = GroupCaches::new(&d, 2);
    let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
    let tokens = vec![0i32; 2 * d.ctx];
    let slots = [0usize];
    c.reset_slot(0); // admission
    r.sync_prefill_device(&mut c, "h", &tokens, &slots).unwrap();
    r.note_prefill_applied(&mut c, &slots);
    for plan in [StepPlan::EsStep, StepPlan::DualStep, StepPlan::EsStep] {
        let n_sel = SimCfg::n_sel(plan, 4);
        r.sync_step_device(&mut c, "h", d.n_layers, n_sel, &tokens, d.prompt_len, 4, &slots)
            .unwrap();
        r.note_step_applied(&mut c, "h", false, d.prompt_len, 4, &slots);
    }
    assert_eq!(
        r.stats, sim_stats,
        "PJRT device planner and sim planner ledgers must be byte-exact"
    );
}

/// Fused-path parity: a scheduler run whose consecutive ES iterations
/// fuse into k-step dispatches must produce the identical
/// `TransferStats` ledger as a manual replay through the planner calls
/// the PJRT fused path makes (`sync_step_device_k` per fused run) —
/// extending the byte-exact sim-vs-PJRT contract to the fused path,
/// including the new `fused_execs` / `inner_iters_fused` /
/// `dispatches_avoided` counters.
#[test]
fn fused_planner_parity_sim_vs_pjrt_replay() {
    // block 8 with a block-period-4 refresh gives per-block plans
    // [P, E, E, E, D, E, E, E]; at k = 8 each ES run fuses to depth 3
    // (run-length capped), so "abc" decodes its 8-position block in 4
    // dispatches: Prefill, fused-ES(3), Dual, fused-ES(3)
    let cfg = SchedCfg {
        method: Method::EsDllm,
        block: 8,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 4 },
        sampler: SamplerCfg::llada(),
        seed: 0,
        k: 8,
        hysteresis: None,
    };
    let backend = SimBackend::new(SimCfg::default());
    let mut s = GroupScheduler::new(Box::new(backend), 2, cfg).unwrap();
    s.admit(input(1, "abc")).unwrap();
    drain(&mut s);
    assert_eq!(
        (s.n_prefill, s.n_dual, s.n_es, s.n_fused),
        (1, 1, 2, 2),
        "dispatch schedule"
    );
    assert_eq!(s.ticks, 4, "8 iterations in 4 dispatches");
    let sim_stats = s.transfer_stats();
    assert_eq!(sim_stats.fused_execs, 2);
    assert_eq!(sim_stats.inner_iters_fused, 6);
    assert_eq!(sim_stats.dispatches_avoided, 4);

    // PJRT planner side: replicate that schedule through the calls
    // step_device_k_impl / step_device_impl make — one
    // sync_step_device_k per fused run at its actual fused depth
    let d = SimCfg::default().dims;
    let mut c = GroupCaches::new(&d, 2);
    let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
    let tokens = vec![0i32; 2 * d.ctx];
    let slots = [0usize];
    c.reset_slot(0); // admission
    r.sync_prefill_device(&mut c, "h", &tokens, &slots).unwrap();
    r.note_prefill_applied(&mut c, &slots);
    let es_sel = SimCfg::n_sel(StepPlan::EsStep, 8);
    let dual_sel = SimCfg::n_sel(StepPlan::DualStep, 8);
    r.sync_step_device_k(&mut c, "h", d.n_layers, es_sel, 3, &tokens, d.prompt_len, 8, &slots)
        .unwrap();
    r.note_step_applied(&mut c, "h", false, d.prompt_len, 8, &slots);
    r.sync_step_device(&mut c, "h", d.n_layers, dual_sel, &tokens, d.prompt_len, 8, &slots)
        .unwrap();
    r.note_step_applied(&mut c, "h", false, d.prompt_len, 8, &slots);
    r.sync_step_device_k(&mut c, "h", d.n_layers, es_sel, 3, &tokens, d.prompt_len, 8, &slots)
        .unwrap();
    r.note_step_applied(&mut c, "h", false, d.prompt_len, 8, &slots);
    assert_eq!(
        r.stats, sim_stats,
        "fused-path planner ledgers must be byte-exact sim vs PJRT"
    );
}

/// Tiered sim scheduler at `block`, live-context decoding enabled.
fn sched_tiered(n_slots: usize, block: usize) -> GroupScheduler<'static> {
    let base = SimCfg::default();
    let tiers = SimCfg::default_ctx_tiers(&base.dims);
    let mut s = sched_with(n_slots, block, base.with_ctx_tiers(&tiers));
    s.enable_live_ctx(true);
    s
}

/// Tiered-planner parity: a live-context scheduler run (block-sliced
/// grounding prefill + steps dispatched at the live tier + early block
/// retirement) must produce the identical `TransferStats` ledger as a
/// manual replay through the planner calls the PJRT tiered path makes —
/// `set_live_ctx` before each dispatch, `sync_prefill_device_blk` for
/// the grounding, `sync_step_device` per step, `note_early_retired` at
/// the EOS-guard retirement. The whole-struct equality extends the
/// byte-exact sim-vs-PJRT contract to every pruned-tick counter
/// (`live_row_ticks` / `full_row_ticks` / `flops_units` /
/// `suffix_blocks_pruned` / `early_retired_blocks`).
#[test]
fn tiered_planner_parity_sim_vs_pjrt_replay() {
    // "abc" at block 4 decodes block 0 in plans [P, E, D, E] and
    // retires on the EOS guard; the live frontier never leaves the
    // smallest tier (prompt + 8) and the remaining 7 gen blocks retire
    // early
    let mut s = sched_tiered(2, 4);
    s.admit(input(1, "abc")).unwrap();
    drain(&mut s);
    assert_eq!((s.n_prefill, s.n_dual, s.n_es), (1, 1, 2), "plan schedule");
    assert_eq!(s.tier_switches, 0, "one block of work: no tier motion");
    let sim_stats = s.transfer_stats();
    let d = SimCfg::default().dims;
    let tier = d.prompt_len + 8;
    let batch = 2u64;
    assert_eq!(
        sim_stats.live_row_ticks,
        4 * batch * tier as u64,
        "4 dispatches at the smallest tier"
    );
    assert_eq!(sim_stats.full_row_ticks, 4 * batch * d.ctx as u64);
    assert_eq!(
        sim_stats.suffix_blocks_pruned,
        3 * ((d.ctx - tier) / 4) as u64,
        "each of the 3 steps skipped the converged suffix blocks"
    );
    assert_eq!(sim_stats.early_retired_blocks, (d.gen_len / 4 - 1) as u64);

    // PJRT planner side: the identical call sequence the tiered
    // prefill_device_blk_impl / step_device_impl path makes
    let mut c = GroupCaches::new(&d, 2);
    let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Device);
    let tokens = vec![0i32; 2 * d.ctx];
    let slots = [0usize];
    c.reset_slot(0); // admission
    r.set_live_ctx(tier);
    r.sync_prefill_device_blk(&mut c, "h", &tokens, &slots, 4).unwrap();
    r.note_prefill_applied(&mut c, &slots);
    for plan in [StepPlan::EsStep, StepPlan::DualStep, StepPlan::EsStep] {
        let n_sel = SimCfg::n_sel(plan, 4);
        r.sync_step_device(&mut c, "h", d.n_layers, n_sel, &tokens, d.prompt_len, 4, &slots)
            .unwrap();
        r.note_step_applied(&mut c, "h", false, d.prompt_len, 4, &slots);
    }
    r.note_early_retired((d.gen_len / 4 - 1) as u64);
    assert_eq!(
        r.stats, sim_stats,
        "tiered planner ledgers must be byte-exact sim vs PJRT"
    );

    // and the untier run prices strictly more modeled FLOPs for the
    // same trajectory
    let mut full = sched(2, 4);
    full.admit(input(1, "abc")).unwrap();
    drain(&mut full);
    let fs = full.transfer_stats();
    assert!(sim_stats.flops_units < fs.flops_units);
    assert_eq!(fs.suffix_blocks_pruned, 0);
    assert_eq!(fs.early_retired_blocks, 0, "ledger-silent with tiering off");
}

/// Block-sliced grounding prefill downlink: under live-context decoding
/// every prefill tick downloads exactly each refreshed slot's current
/// `[B, block, V]` logit window — never the gen-region slice — and the
/// `blk_start` vector rides up as `B × 4` extra token bytes.
#[test]
fn block_sliced_prefill_downloads_one_block_window() {
    let d = SimCfg::default().dims;
    let batch = 2u64;
    let vocab = d.vocab as u64;
    let window = batch * 4 * vocab * 4;
    let gen_slice = batch * 8 * vocab * 4; // smallest tier's gen-live slice
    assert!(window < gen_slice);
    let mut s = sched_tiered(2, 4);
    s.admit(input(1, "abcdefgh")).unwrap();
    let mut prefill_ticks = 0;
    let mut guard = 0;
    while s.active() > 0 {
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
        let pf_before = s.n_prefill;
        let before = s.transfer_stats();
        s.tick().unwrap();
        let delta = s.transfer_stats().since(&before);
        if s.n_prefill > pf_before {
            prefill_ticks += 1;
            assert_eq!(
                delta.d2h_bytes_shipped, window,
                "prefill downlink is the block window, not the gen slice"
            );
            // uplink: the refreshed slot's live token rows, the [B]
            // occupancy mask, and the [B] blk_start vector
            assert_eq!(
                delta.token_upload_bytes,
                s.live_tier().unwrap() as u64 * 4 + batch * 4 + batch * 4
            );
        }
    }
    assert!(prefill_ticks >= 2, "both blocks grounded through the blk path");
    // the second block's grounding rode a tier switch
    assert!(s.tier_switches >= 1);
}

#[test]
fn admission_dirties_exactly_one_slot() {
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefg")).unwrap();
    s.tick().unwrap(); // grounding prefill seeds the chain, clears bitmaps
    s.tick().unwrap(); // first step chains retained outputs
    let ctx = s.group_caches().dims.ctx;
    assert_eq!(s.group_caches().dirty.kv.count(), 0, "group fully in sync");

    let slot_b = s.admit(input(2, "xy")).unwrap();
    let dirty = &s.group_caches().dirty;
    assert_eq!(dirty.kv.count_slot(slot_b), ctx, "admitted slot invalidated");
    assert_eq!(dirty.kv.count(), ctx, "and nothing else");
    let gen = s.group_caches().dims.gen_len;
    assert_eq!(dirty.conf.count_slot(slot_b), gen);
    for bm in dirty.ind.values() {
        assert_eq!(bm.count_slot(slot_b), gen);
    }

    // the grounding prefill regenerates the slot's rows device-side:
    // the dirty rows drain with zero KV upload
    let before = s.transfer_stats();
    s.tick().unwrap();
    assert_eq!(s.group_caches().dirty.kv.count_slot(slot_b), 0);
    let delta = s.transfer_stats().since(&before);
    assert_eq!(delta.kv_upload_bytes, 0);
    assert_eq!(delta.full_kv_uploads, 0);
    drain(&mut s);
}

/// Regression (device-apply eviction): `evict_all` must invalidate the
/// resident chain — drop retained handles, reset seeded state, mark the
/// host mirrors dirty — so a sequence admitted after an eviction
/// re-grounds from a fresh seed instead of stepping against the evicted
/// group's stale device copy.
#[test]
fn evict_all_invalidates_resident_chain() {
    let mut s = sched(2, 4);
    s.admit(input(1, "abcdefgh")).unwrap();
    s.tick().unwrap(); // seed
    s.tick().unwrap(); // steady-state step
    assert_eq!(s.group_caches().dirty.kv.count(), 0);

    s.evict_all();
    assert_eq!(s.active(), 0);
    let d = s.group_caches().dims;
    assert_eq!(
        s.group_caches().dirty.kv.count(),
        2 * d.ctx,
        "eviction takes back the whole device-residency promise"
    );
    for bm in s.group_caches().dirty.ind.values() {
        assert_eq!(bm.count(), 2 * d.gen_len);
    }

    // a re-admitted sequence must run exactly (a second seed, then the
    // usual zero-byte steady state) and still decode correctly
    s.admit(input(7, "xy")).unwrap();
    let mut out = Vec::new();
    let mut guard = 0;
    while s.active() > 0 {
        out.extend(s.tick().unwrap());
        guard += 1;
        assert!(guard < 1000);
    }
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].text, "xy", "post-eviction decode is exact");
    let stats = s.transfer_stats();
    assert_eq!(stats.full_kv_uploads, 2, "the re-ground re-seeded the chain");
}

#[test]
fn ledger_delta_matches_dirty_bitmap_in_host_apply_mode() {
    // Host-apply (the stateless-executable fallback): a step's own
    // output scatter leaves its rows dirty, and the next sync re-ships
    // exactly those rows — the ledger delta must equal
    // bitmap-rows × row-bytes.
    let d = Dims {
        vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, n_kv_heads: 1,
        d_ff: 8, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
    };
    let mut c = GroupCaches::new(&d, 2);
    let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Host);
    let slots = [0usize, 1];
    r.sync_kv(&mut c, &slots); // seed

    let block = 2;
    let n = d.n_layers * 2 * 2 * d.n_kv_heads * block * d.head_dim;
    let t = HostTensor::Bf16 {
        shape: vec![d.n_layers, 2, 2, d.n_kv_heads, block, d.head_dim],
        data: vec![3u16; n],
    };
    c.scatter_kv_block_slots(d.prompt_len, block, &t, &slots).unwrap();
    let dirty_rows: usize = slots.iter().map(|&b| c.dirty.kv.count_slot(b)).sum();
    assert_eq!(dirty_rows, 2 * block);

    let snap = r.stats;
    let out = r.sync_kv(&mut c, &slots);
    assert_eq!(out.shipped, (dirty_rows * c.kv_row_bytes()) as u64);
    assert!(out.shipped < out.full, "a delta, not a full re-upload");
    let delta = r.stats.since(&snap);
    assert_eq!(delta.kv_upload_bytes, out.shipped);
    assert_eq!(delta.full_kv_uploads, 0);
    assert_eq!(c.dirty.kv.count(), 0, "sync clears what it ships");
}

/// The Host-apply sim models the stateless fallback end to end: its
/// steps re-ship their own scattered rows as deltas, so it uploads
/// strictly more than the device-apply chain on the same workload —
/// and still decodes identically.
#[test]
fn host_apply_sim_reships_deltas_and_decodes_identically() {
    let mut dev = sched(2, 4);
    dev.admit(input(1, "abcdef")).unwrap();
    let mut dev_out = Vec::new();
    let mut guard = 0;
    while dev.active() > 0 {
        dev_out.extend(dev.tick().unwrap());
        guard += 1;
        assert!(guard < 1000);
    }

    let mut host = sched_with(2, 4, SimCfg::default().with_apply(ApplyMode::Host));
    host.admit(input(1, "abcdef")).unwrap();
    let mut host_out = Vec::new();
    guard = 0;
    while host.active() > 0 {
        host_out.extend(host.tick().unwrap());
        guard += 1;
        assert!(guard < 1000);
    }

    assert_eq!(dev_out[0].text, host_out[0].text, "apply mode is transparent");
    assert_eq!(dev_out[0].iterations, host_out[0].iterations);

    let ds = dev.transfer_stats();
    let hs = host.transfer_stats();
    assert!(
        hs.kv_upload_bytes > ds.kv_upload_bytes,
        "host-apply re-ships KV deltas ({} B) that device-apply chains ({} B)",
        hs.kv_upload_bytes,
        ds.kv_upload_bytes
    );
    assert!(hs.conf_upload_bytes > ds.conf_upload_bytes);
    assert!(ds.d2h_bytes_avoided > 0);
    assert_eq!(hs.retained_out_reuses, 0, "no chaining in host mode");
}

#[test]
fn per_kind_counters_split_the_total() {
    let mut s = sched(1, 4);
    s.admit(input(1, "abcd")).unwrap();
    drain(&mut s);
    let st: TransferStats = s.transfer_stats();
    assert_eq!(
        st.upload_bytes,
        st.kv_upload_bytes
            + st.kv_sparse_upload_bytes
            + st.ind_upload_bytes
            + st.conf_upload_bytes
            + st.token_upload_bytes,
        "per-kind counters must partition the total"
    );
    // tokens (and the batch-bit masks) ship every run; kv/ind/conf ship
    // exactly once — the chain seed
    assert!(st.token_upload_bytes > 0);
    let conf_seed = (s.group_caches().dims.gen_len * 4) as u64; // batch 1
    assert_eq!(st.conf_upload_bytes, conf_seed);
}

#[test]
fn record_classifies_kinds() {
    let mut st = TransferStats::default();
    st.record(TransferKind::Kv, 10, 10);
    st.record(TransferKind::Ind, 0, 8);
    st.record(TransferKind::Conf, 2, 4);
    assert_eq!(st.full_kv_uploads, 1);
    assert_eq!(st.resident_reuses, 1);
    assert_eq!(st.upload_bytes, 12);
    assert_eq!(st.upload_bytes_saved, 10);
    assert_eq!(st.kv_upload_bytes, 10);
    assert_eq!(st.ind_upload_bytes, 0);
    assert_eq!(st.conf_upload_bytes, 2);
}

/// The pooled-residency acceptance criterion: a b1 ↔ b8 batch-class
/// switch mid-trace reuses the parked chain with ZERO full-KV reseed —
/// each class seeds exactly once for the scheduler's whole lifetime,
/// re-activations are checkout hits (`chain_rebuilds_avoided > 0`,
/// `reseed_bytes_saved` = the seed bytes a cold rebuild would have
/// shipped), and slots dirtied by admissions after the checkout
/// re-ground on device without uploading KV.
#[test]
fn batch_class_switch_reuses_parked_chain_without_full_reseed() {
    let d = SimCfg::default().dims;
    let mut s = sched_classes(&[1, 8], 4);
    assert_eq!(s.batch_class(), 8, "starts at full capacity");

    // a lone request sizes the class down to b=1 before admission
    assert!(s.maybe_switch_class(1).unwrap());
    assert_eq!(s.batch_class(), 1);
    s.admit(input(1, "abc")).unwrap();
    drain(&mut s);
    assert_eq!(s.transfer_stats().full_kv_uploads, 1, "b1 chain seeds once");

    // a burst upshifts to b=8: the b1 chain parks, b8 seeds cold
    assert!(s.maybe_switch_class(8).unwrap());
    assert_eq!(s.batch_class(), 8);
    s.admit(input(2, "abc")).unwrap();
    drain(&mut s);
    assert_eq!(
        s.transfer_stats().full_kv_uploads,
        2,
        "each class pays exactly one seed, ever"
    );

    // back to b=1 mid-trace: the parked chain is checked out — NO third
    // seed, and the admission-dirtied slot re-grounds on device
    assert!(s.maybe_switch_class(1).unwrap());
    let slot = s.admit(input(3, "xy")).unwrap();
    assert!(
        s.group_caches().dirty.kv.count_slot(slot) > 0,
        "admission dirtied the slot while the chain sat parked"
    );
    let before = s.transfer_stats();
    drain(&mut s);
    let delta = s.transfer_stats().since(&before);
    assert_eq!(delta.full_kv_uploads, 0, "zero full-KV reseed on checkout");
    assert_eq!(delta.kv_upload_bytes, 0, "the dirty slot re-grounds on device");

    let ps = s.pool_stats();
    assert_eq!(ps.chain_switches, 3, "initial sizing + up + down");
    assert_eq!(ps.chain_rebuilds_avoided, 1, "the b1 re-activation was a hit");
    assert_eq!(ps.reseed_bytes_saved, chain_seed_bytes(&d, 1));
    assert_eq!(ps.resident_chains, 2, "both class chains stay resident");

    // and the b8 chain resumes the same way
    assert!(s.maybe_switch_class(8).unwrap());
    s.admit(input(4, "pq")).unwrap();
    drain(&mut s);
    let ps = s.pool_stats();
    assert_eq!(ps.chain_rebuilds_avoided, 2);
    assert_eq!(ps.reseed_bytes_saved, chain_seed_bytes(&d, 1) + chain_seed_bytes(&d, 8));
    assert_eq!(s.transfer_stats().full_kv_uploads, 2, "still two seeds total");
}

/// Byte-exact parity across a batch-class switch: replaying the exact
/// planner + pool call sequence `PjrtBackend` makes (activate / park /
/// checkout per class, composite syncs per plan) must produce BOTH the
/// identical `TransferStats` ledger and the identical `PoolStats`
/// ledger as the sim backend run through the scheduler on the same
/// b1 → b8 → b1 workload.
#[test]
fn pool_ledger_parity_sim_vs_pjrt_planner_across_class_switch() {
    // sim side: three 3-char-or-shorter prompts, one per phase; each
    // retires after exactly 4 iterations of block 0 (EOS guard) with
    // plans [Prefill, Es, Dual, Es] under block_period 2
    let mut s = sched_classes(&[1, 8], 4);
    s.maybe_switch_class(1).unwrap();
    s.admit(input(1, "abc")).unwrap();
    drain(&mut s);
    s.maybe_switch_class(8).unwrap();
    s.admit(input(2, "abc")).unwrap();
    drain(&mut s);
    s.maybe_switch_class(0).unwrap();
    s.admit(input(3, "xy")).unwrap();
    drain(&mut s);
    assert_eq!(s.ticks, 12, "three 4-tick generations");
    let sim_stats = s.transfer_stats();
    let sim_pool = s.pool_stats();

    // PJRT planner side: the same schedule through the planner calls
    // prefill_device_impl / step_device_impl make, against the same
    // pool API under a PJRT-style owner id
    let d = SimCfg::default().dims;
    let pool = ResidencyPool::new();
    let owner = Some(7u64);
    let plans = [StepPlan::EsStep, StepPlan::DualStep, StepPlan::EsStep];
    let run_gen = |r: &mut DeviceGroupCaches, c: &mut GroupCaches, tokens: &[i32]| {
        c.reset_slot(0); // admission
        r.sync_prefill_device(c, "h", tokens, &[0]).unwrap();
        r.note_prefill_applied(c, &[0]);
        for plan in plans {
            let n_sel = SimCfg::n_sel(plan, 4);
            r.sync_step_device(c, "h", d.n_layers, n_sel, tokens, d.prompt_len, 4, &[0])
                .unwrap();
            r.note_step_applied(c, "h", false, d.prompt_len, 4, &[0]);
        }
    };

    // switch #1: cold b1 activation
    assert!(pool.checkout("llada-nano", 1, owner, chain_seed_bytes(&d, 1)).is_none());
    pool.register_fresh();
    pool.record_switch();
    let mut c1 = GroupCaches::new(&d, 1);
    let mut r1 = DeviceGroupCaches::new(&d, 1, ApplyMode::Device);
    let t1 = vec![0i32; d.ctx];
    run_gen(&mut r1, &mut c1, &t1);

    // switch #2: park b1, cold b8 activation
    pool.park("llada-nano", 1, owner, r1.park_plan(), true);
    assert!(pool.checkout("llada-nano", 8, owner, chain_seed_bytes(&d, 8)).is_none());
    pool.register_fresh();
    pool.record_switch();
    let mut c8 = GroupCaches::new(&d, 8);
    let mut r8 = DeviceGroupCaches::new(&d, 8, ApplyMode::Device);
    let t8 = vec![0i32; 8 * d.ctx];
    run_gen(&mut r8, &mut c8, &t8);

    // switch #3: park b8, checkout HIT on the parked b1 chain
    pool.park("llada-nano", 8, owner, r8.park_plan(), true);
    let plan = pool
        .checkout("llada-nano", 1, owner, chain_seed_bytes(&d, 1))
        .expect("parked b1 chain resumes");
    pool.record_switch();
    r1.restore_plan(plan);
    run_gen(&mut r1, &mut c1, &t1);

    let mut pjrt = TransferStats::default();
    pjrt.merge(&r1.stats);
    pjrt.merge(&r8.stats);
    assert_eq!(pjrt, sim_stats, "transfer ledgers byte-exact across the switch");
    assert_eq!(pool.stats(), sim_pool, "pool ledgers byte-exact too");
}

/// Pool lifecycle: `evict_all` (and `invalidate_resident` behind it)
/// must evict the POOLED entries as well as the live chain — a
/// post-eviction class switch finds nothing to resume and re-seeds.
#[test]
fn evict_all_evicts_pooled_entries_not_just_the_live_chain() {
    let mut s = sched_classes(&[1, 8], 4);
    s.maybe_switch_class(1).unwrap();
    s.admit(input(1, "abc")).unwrap();
    drain(&mut s); // b1 chain seeded
    s.maybe_switch_class(8).unwrap(); // b1 parks in the pool, b8 live
    assert_eq!(s.pool_stats().resident_chains, 2);
    assert_eq!(s.transfer_stats().full_kv_uploads, 1);

    s.evict_all();
    assert_eq!(
        s.pool_stats().resident_chains,
        0,
        "eviction removes live AND pooled chains"
    );

    // switching back must NOT find the evicted b1 chain
    assert!(s.maybe_switch_class(1).unwrap());
    s.admit(input(9, "xy")).unwrap();
    drain(&mut s);
    assert_eq!(
        s.transfer_stats().full_kv_uploads,
        2,
        "post-eviction re-admission re-seeds"
    );
    assert_eq!(
        s.pool_stats().chain_rebuilds_avoided,
        0,
        "no chain reuse across an eviction"
    );
}

/// Park → dirty → checkout, at the planner level (Host-apply mode, so
/// the re-upload is visible as bytes): only the slots dirtied while the
/// chain sat parked re-ship on resume — a delta, never a full reseed.
#[test]
fn checkout_reships_only_slots_dirtied_while_parked() {
    let d = Dims {
        vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, n_kv_heads: 1,
        d_ff: 8, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
    };
    let pool = ResidencyPool::new();
    let mut c = GroupCaches::new(&d, 2);
    let mut r = DeviceGroupCaches::new(&d, 2, ApplyMode::Host);
    pool.register_fresh();
    r.sync_kv(&mut c, &[0, 1]); // seed
    assert_eq!(r.stats.full_kv_uploads, 1);

    pool.park("a", 2, None, r.park_plan(), true);
    // while parked: an admission resets slot 1, dirtying its rows
    c.reset_slot(1);

    let plan = pool.checkout("a", 2, None, chain_seed_bytes(&d, 2)).unwrap();
    r.restore_plan(plan);
    let out = r.sync_kv(&mut c, &[0, 1]);
    assert_eq!(
        out.shipped,
        (d.ctx * c.kv_row_bytes()) as u64,
        "exactly the parked-dirty slot's rows re-ship"
    );
    assert!(out.shipped < out.full, "a delta, not a full reseed");
    assert_eq!(r.stats.full_kv_uploads, 1, "no second seed");
    assert_eq!(c.dirty.kv.count(), 0, "resume clears what it ships");
    let ps = pool.stats();
    assert_eq!(ps.chain_rebuilds_avoided, 1);
    assert_eq!(ps.reseed_bytes_saved, chain_seed_bytes(&d, 2));
}

/// The prefix-cache acceptance criterion: admitting a prompt whose
/// block-aligned prefix sits in the cache must decode TOKEN-IDENTICAL
/// to a cacheless full-prefill admission — prefix KV is a pure function
/// of the prompt tokens under the deterministic grounding prefill, so
/// seeding from the cache changes which bytes move, never which tokens
/// come out. The savings are credited on the prefix ledger while the
/// transfer ledger itself stays byte-identical (suffix-only prefill is
/// realized as accounting over the device-resident grounding prefill).
#[test]
fn prefix_seeded_admission_is_trajectory_exact() {
    // a two-turn chat pair: turn 2 re-submits turn 1's whole prompt
    // plus a 4-char tail, the pattern the cache exists for
    let turns = ["abcdefgh", "abcdefghijkl"];
    let run = |cached: bool| {
        let mut backend = SimBackend::new(SimCfg::default());
        if cached {
            backend.set_prefix_cache(PrefixCache::new(1 << 20));
        }
        let mut s = GroupScheduler::new(Box::new(backend), 2, sched_cfg(4)).unwrap();
        let mut texts = Vec::new();
        for (i, p) in turns.iter().enumerate() {
            s.admit(input(i as u64 + 1, p)).unwrap();
            let mut guard = 0;
            while s.active() > 0 {
                texts.extend(s.tick().unwrap().into_iter().map(|f| f.text));
                guard += 1;
                assert!(guard < 1000);
            }
        }
        (texts, s.prefix_stats(), s.transfer_stats())
    };
    let (cached_texts, xs, cached_stats) = run(true);
    let (plain_texts, plain_xs, plain_stats) = run(false);

    assert_eq!(cached_texts, plain_texts, "prefix seeding must not move a token");
    assert_eq!(plain_xs, PrefixStats::default(), "no cache, no ledger");
    // turn 1 probes cold (miss); its retirement inserts the 8-char
    // aligned prefix; turn 2 probes 12 → miss, 8 → hit
    assert_eq!((xs.prefix_hits, xs.prefix_misses), (1, 1));
    let d = SimCfg::default().dims;
    let row_bytes = GroupCaches::new(&d, 2).kv_row_bytes() as u64;
    assert_eq!(xs.prefill_bytes_saved, 8 * row_bytes);
    assert_eq!(xs.prefix_cache_bytes, (8 + 12) * row_bytes);
    assert_eq!(xs.prefix_evictions, 0);
    assert_eq!(
        cached_stats, plain_stats,
        "savings are credited on the prefix ledger; the transfer ledger is untouched"
    );
}

/// Byte-exact parity of the `PrefixStats` ledger between the two
/// planner identities: the sim backend drives the cache through the
/// scheduler's probe/offer hooks (arch "sim", shared owner `None`),
/// and the identical call sequence replayed under a PJRT-style
/// `(arch, owner)` identity — the calls `PjrtBackend::prefix_probe` /
/// `prefix_offer` make — must land on the identical ledger across a
/// miss, a hit, and two budget evictions. All credit accounting lives
/// inside the shared `PrefixCache`, so equal call sequences MUST mean
/// equal ledgers; this pins that contract.
#[test]
fn prefix_ledger_parity_sim_vs_pjrt_identity_across_hit_miss_evict() {
    let d = SimCfg::default().dims;
    let row_bytes = GroupCaches::new(&d, 2).kv_row_bytes() as u64;
    // budget fits turn 1's 8-row payload OR turn 2's 12-row payload,
    // not both — every insert past the first evicts the LRU entry
    let budget = 16 * row_bytes;

    // sim side: three admissions through the scheduler — turn 1 (cold
    // miss, insert 8 rows), turn 2 (hit at 8, insert 12 rows → evicts
    // the just-hit turn-1 entry: its MRU stamp still predates the
    // insert), turn 1 again (miss — its entry was evicted — re-insert
    // → evicts turn 2's entry)
    let mut backend = SimBackend::new(SimCfg::default());
    backend.set_prefix_cache(PrefixCache::new(budget));
    let mut s = GroupScheduler::new(Box::new(backend), 2, sched_cfg(4)).unwrap();
    for (i, p) in ["abcdefgh", "abcdefghijkl", "abcdefgh"].iter().enumerate() {
        s.admit(input(i as u64 + 1, p)).unwrap();
        drain(&mut s);
    }
    let sim_xs = s.prefix_stats();

    // PJRT-identity side: the same probe/insert sequence, verbatim,
    // under a worker-owned identity. Ledger parity is a function of the
    // call sequence alone, so representative token ids suffice.
    let cache = PrefixCache::new(budget);
    let (arch, owner) = ("llada-nano", Some(7u64));
    let rows_per = |p: usize| d.n_layers * 2 * d.n_kv_heads * p * d.head_dim;
    let t1: Vec<i32> = (0..8).collect();
    let t2: Vec<i32> = (0..12).collect();
    assert!(cache.probe(arch, owner, &t1, 4, row_bytes).is_none());
    cache.insert(arch, owner, &t1, vec![0u16; rows_per(8)]);
    let (p, rows) = cache.probe(arch, owner, &t2, 4, row_bytes).expect("warm hit");
    assert_eq!((p, rows.len()), (8, rows_per(8)));
    cache.insert(arch, owner, &t2, vec![0u16; rows_per(12)]);
    assert!(cache.probe(arch, owner, &t1, 4, row_bytes).is_none());
    cache.insert(arch, owner, &t1, vec![0u16; rows_per(8)]);
    let pjrt_xs = cache.stats();

    assert_eq!(sim_xs, pjrt_xs, "prefix ledgers byte-exact across identities");
    assert_eq!((sim_xs.prefix_hits, sim_xs.prefix_misses), (1, 2));
    assert_eq!(sim_xs.prefill_bytes_saved, 8 * row_bytes);
    assert_eq!(sim_xs.prefix_evictions, 2);
    assert_eq!(sim_xs.prefix_cache_bytes, 8 * row_bytes, "only turn 1 resident");
}

/// Prefix entries are HOST payloads — pure functions of the prompt
/// tokens — so the fault ladder's `evict_all` (which drops every
/// device-resident chain and takes back the residency promise) must
/// NOT touch them: the next admission still hits the cache, decodes
/// exactly, and re-seeds its device chain from scratch. Prefix reuse
/// never substitutes for the device re-ground.
#[test]
fn prefix_entries_survive_evict_all_and_reground() {
    let mut backend = SimBackend::new(SimCfg::default());
    backend.set_prefix_cache(PrefixCache::new(1 << 20));
    let mut s = GroupScheduler::new(Box::new(backend), 2, sched_cfg(4)).unwrap();
    s.admit(input(1, "abcdefgh")).unwrap();
    drain(&mut s);
    let warm = s.prefix_stats();
    assert_eq!((warm.prefix_hits, warm.prefix_misses), (0, 1));
    assert!(warm.prefix_cache_bytes > 0, "retirement inserted the prefix");
    assert_eq!(s.transfer_stats().full_kv_uploads, 1);

    s.evict_all();
    assert_eq!(
        s.prefix_stats().prefix_cache_bytes,
        warm.prefix_cache_bytes,
        "device eviction leaves host prefix entries resident"
    );

    s.admit(input(2, "abcdefghijkl")).unwrap();
    let mut out = Vec::new();
    let mut guard = 0;
    while s.active() > 0 {
        out.extend(s.tick().unwrap());
        guard += 1;
        assert!(guard < 1000);
    }
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].text, "abcdefghijkl", "post-eviction decode is exact");
    let xs = s.prefix_stats();
    assert_eq!(xs.prefix_hits, 1, "the cache still hits across the eviction");
    assert_eq!(
        s.transfer_stats().full_kv_uploads,
        2,
        "the evicted chain re-seeded — prefix reuse is not a re-ground"
    );
}

/// The donation acceptance criterion: with the input-output alias
/// config enabled, a multi-tick device-apply chain holds AT MOST ONE
/// live device copy of each chained KV/indicator/confidence tensor —
/// even transiently during execution — asserted against the stub
/// runtime's live-buffer ledger. The un-aliased build (replace-and-drop
/// chaining) transiently holds two copies per chained tensor, which is
/// exactly the ROADMAP gap this closes.
#[test]
fn donated_chain_holds_at_most_one_live_copy_per_tensor() {
    let dev = xla::StubDevice::new();
    // three chained tensors (kv / ind / conf) seeded once, plus a
    // logits output that is downloaded and dropped every tick
    let (kv_b, ind_b, conf_b, logits_b) = (4096usize, 2048, 256, 512);
    let mut kv = dev.alloc(kv_b);
    let mut ind = dev.alloc(ind_b);
    let mut conf = dev.alloc(conf_b);
    assert_eq!(dev.live_buffers(), 3, "the chain seeds");
    dev.reset_peak();

    // alias pairs in the `ExeSpec::alias_pairs` format over args
    // [kv, ind, conf]: outputs 1/2/3 donate params 0/1/2 in place
    // (output 0 = logits, freshly materialized)
    let exe = dev.executable(&[logits_b, kv_b, ind_b, conf_b], &[(1, 0), (2, 1), (3, 2)]);
    for tick in 0..5 {
        let mut out = exe.execute(&[&kv, &ind, &conf]).unwrap();
        let logits = out.remove(0);
        assert_eq!(dev.live_buffers(), 4, "tick {tick}: 3 chains + logits only");
        // the chained outputs ARE the donated inputs, updated in place
        assert!(out[0].shares_allocation(&kv));
        assert!(out[1].shares_allocation(&ind));
        assert!(out[2].shares_allocation(&conf));
        // the host downloads the logit rows and drops the buffer; the
        // backend replaces its handles (the donated inputs are invalid)
        drop(logits);
        conf = out.pop().unwrap();
        ind = out.pop().unwrap();
        kv = out.pop().unwrap();
        assert_eq!(dev.live_buffers(), 3);
    }
    assert_eq!(
        dev.peak_live_buffers(),
        4,
        "at most one live copy per chained tensor across the whole chain \
         (3 chained allocations + the transient logits download)"
    );

    // the un-donated build on the same schedule: execution materializes
    // fresh outputs while the inputs are still live — two copies of
    // every chained tensor at once
    let dev2 = xla::StubDevice::new();
    let kv2 = dev2.alloc(kv_b);
    let ind2 = dev2.alloc(ind_b);
    let conf2 = dev2.alloc(conf_b);
    dev2.reset_peak();
    let exe2 = dev2.executable(&[logits_b, kv_b, ind_b, conf_b], &[]);
    let out = exe2.execute(&[&kv2, &ind2, &conf2]).unwrap();
    assert_eq!(dev2.live_buffers(), 7, "3 old + 3 new + logits");
    assert!(!out[1].shares_allocation(&kv2));
    drop(out);
    assert_eq!(dev2.live_buffers(), 3);
}
