//! Table 10: number-of-skips ablation at roughly iso-FLOPs (~40% in the
//! paper; the nano FLOPs proportions are printed alongside) across all
//! five benchmarks using llada-nano: one aggressive early skip (r1=0.7),
//! the default two skips (r1=r2=0.5), and three skips (r=0.405 ×3).

use esdllm::bench::{bench_n, Table};
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::flops;
use esdllm::runtime::Runtime;
use esdllm::workload::{paper_name, BENCHMARKS};

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let n = bench_n(16);
    let arch = "llada-nano";
    let dims = rt.arch(arch)?.dims;

    let variants: Vec<(&str, &str, Vec<(usize, f64)>)> = vec![
        ("r1=0.7", "es_r1_only_70", vec![(1, 0.7)]),
        ("r1=r2=0.5", "es", vec![(1, 0.5), (2, 0.5)]),
        ("r1=r2=r3=0.405", "es_triple_405", vec![(1, 0.405), (2, 0.405), (3, 0.405)]),
    ];

    let mut headers: Vec<&str> = vec!["Skip Ratio & Position", "FLOPs Prop."];
    let names: Vec<String> =
        BENCHMARKS.iter().map(|b| paper_name(b).to_string()).collect();
    headers.extend(names.iter().map(|s| s.as_str()));
    let mut table = Table::new(
        &format!("Table 10 analog: skipping times at iso-FLOPs, {n} samples"),
        &headers,
    );

    for (label, exe_base, skip) in variants {
        let prop = flops::flops_proportion(&dims, 8, &skip);
        let mut row = vec![label.to_string(), format!("{:.0}%", prop * 100.0)];
        for bench in BENCHMARKS {
            let block = esdllm::eval::bench_cfg(bench).block;
            let exe = if exe_base == "es" {
                format!("es_blk{block}_b8")
            } else {
                format!("{exe_base}_blk{block}_b8")
            };
            // triple/r1-70 variants exist only for blk8 and blk32
            let opts = EvalOpts {
                es_exe_override: Some(exe),
                ..Default::default()
            };
            let r = evaluate(&rt, arch, Method::EsDllm, bench, n, &opts)?;
            row.push(format!("{:.2}", r.score));
        }
        table.row(&row);
    }
    table.print();
    table.write_csv("artifacts/results/table10.csv")?;
    Ok(())
}
