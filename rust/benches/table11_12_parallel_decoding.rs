//! Tables 11 & 12: integration with confidence-aware parallel decoding
//! (threshold 0.9) — DualCache+PD vs ES-dLLM+PD on both architectures.
//! Speedups are reported against DualCache *without* PD, as in the paper.

use esdllm::bench::{bench_archs, bench_n, Table};
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::runtime::Runtime;
use esdllm::workload::{paper_name, BENCHMARKS};

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let n = bench_n(16);

    for arch in bench_archs() {
        let table_no = if arch.starts_with("llada") { 11 } else { 12 };
        let mut table = Table::new(
            &format!("Table {table_no} analog: parallel decoding on {arch}, {n} samples"),
            &["Benchmark", "Method", "TPS", "Speedup vs DualCache", "Score"],
        );
        for bench in BENCHMARKS {
            let base =
                evaluate(&rt, &arch, Method::DualCache, bench, n, &EvalOpts::default())?;
            for method in [Method::DualCache, Method::EsDllm] {
                let opts = EvalOpts {
                    parallel_threshold: Some(0.9),
                    ..Default::default()
                };
                let r = evaluate(&rt, &arch, method, bench, n, &opts)?;
                table.row(&[
                    paper_name(bench).to_string(),
                    r.method.clone(),
                    format!("{:.2}", r.tps),
                    format!("{:.2}x", r.speedup_vs(&base)),
                    format!("{:.2}", r.score),
                ]);
            }
        }
        table.print();
        table.write_csv(&format!("artifacts/results/table{table_no}.csv"))?;
    }
    Ok(())
}
