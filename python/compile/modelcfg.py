"""Model / generation configuration shared by training, AOT lowering and
(via the manifest) the Rust coordinator.

Two nano-scale diffusion-LM architectures mirror the paper's two subjects:

* ``llada-nano`` — MHA (like LLaDA-8B's 32-head attention), 8 layers so the
  paper's skip positions r4/r8 (depth 1/8 and 1/4 of 32 layers) map to
  r1/r2 here.
* ``dream-nano``  — GQA with 2 KV heads (like Dream-7B), otherwise equal.

Both are masked-diffusion transformers: RMSNorm, SwiGLU FFN, RoPE,
bidirectional attention, trained with the LLaDA SFT objective (mask the
answer region with a uniformly sampled ratio, CE on masked positions).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelCfg:
    name: str
    vocab: int = 64
    d_model: int = 128
    n_layers: int = 8
    n_heads: int = 8
    n_kv_heads: int = 8          # GQA when < n_heads
    d_ff: int = 384              # SwiGLU hidden width
    rope_base: float = 10000.0
    prompt_len: int = 48
    gen_len: int = 32

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def ctx(self) -> int:
        return self.prompt_len + self.gen_len

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.head_dim


LLADA_NANO = ModelCfg(name="llada-nano", n_kv_heads=8)
DREAM_NANO = ModelCfg(name="dream-nano", n_kv_heads=2)

ARCHS = {c.name: c for c in (LLADA_NANO, DREAM_NANO)}

# ---------------------------------------------------------------------------
# Parameter inventory.  The order returned here is THE canonical order: the
# flat argument order of every lowered executable, the record order in
# weights-*.bin, and the order the Rust runtime feeds parameter buffers.
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelCfg):
    """[(name, shape)] in canonical order."""
    d, dkv, f, v = cfg.d_model, cfg.d_kv, cfg.d_ff, cfg.vocab
    specs = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        specs += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, dkv)),
            (p + "wv", (d, dkv)),
            (p + "wo", (d, d)),
            (p + "ffn_norm", (d,)),
            (p + "w_gate", (d, f)),
            (p + "w_up", (d, f)),
            (p + "w_down", (f, d)),
        ]
    specs += [("out_norm", (d,)), ("head", (d, v))]
    return specs


def cfg_to_json(cfg: ModelCfg) -> dict:
    j = asdict(cfg)
    j["head_dim"] = cfg.head_dim
    j["ctx"] = cfg.ctx
    j["d_kv"] = cfg.d_kv
    return j


# ---------------------------------------------------------------------------
# Skip configurations (paper §6.1, Appendix C.2).  Depth mapping 32→8 layers:
# paper r0/r4/r8/r16 correspond to nano layers 0/1/2/4.
# ---------------------------------------------------------------------------

# name -> list of (layer_index, skip_ratio)
SKIP_CONFIGS = {
    "default": [(1, 0.5), (2, 0.5)],          # paper r4 = r8 = 0.5
    "r2_only_75": [(2, 0.75)],
    "r2_only_50": [(2, 0.5)],
    "r2_only_25": [(2, 0.25)],
    "r0_only_50": [(0, 0.5)],
    "r1_only_50": [(1, 0.5)],
    "r4_only_50": [(4, 0.5)],
    "r1_only_70": [(1, 0.7)],                 # table 10: single skip, iso-FLOPs
    "triple_405": [(1, 0.405), (2, 0.405), (3, 0.405)],
}


def keep_sizes(block: int, skips):
    """Active-set size entering each layer given a skip spec."""
    sizes = []
    s = block
    spec = dict(skips)
    for layer in range(64):
        sizes.append(s)
        if layer in spec:
            s = max(1, int(round(s * (1.0 - spec[layer]))))
    return sizes


def final_keep(block: int, skips) -> int:
    s = block
    for _, r in sorted(skips):
        s = max(1, int(round(s * (1.0 - r))))
    return s
