//! `esdllm` CLI — leader entrypoint for the ES-dLLM serving stack.
//!
//! Subcommands:
//!   serve     start the HTTP serving front end
//!   generate  one-shot generation from a prompt
//!   eval      run a benchmark cell (method × benchmark) and print TPS/score
//!   info      print manifest / artifact summary

use anyhow::{anyhow, Result};

use esdllm::batcher::BatcherCfg;
use esdllm::cli::Args;
use esdllm::engine::{Engine, EngineCfg, Method};
use esdllm::eval::{self, EvalOpts};
use esdllm::router::{Router, RouterCfg, SchedMode, SloPolicy, WorkerBackend};
use esdllm::runtime::{default_artifacts_dir, Runtime};
use esdllm::server::{serve, ServeCfg};

fn method_from_str(s: &str) -> Result<Method> {
    Ok(match s {
        "vanilla" => Method::Vanilla,
        "dual" | "dualcache" => Method::DualCache,
        "es" | "es-dllm" => Method::EsDllm,
        other => return Err(anyhow!("unknown method {other} (vanilla|dual|es)")),
    })
}

fn usage() -> String {
    "usage: esdllm <serve|generate|eval|info> [options]\n\
     \n\
     common options:\n\
       --arch <llada-nano|dream-nano>   model architecture (default llada-nano)\n\
       --checkpoint <instruct|base>     weights (default instruct)\n\
       --method <vanilla|dual|es>       decode method (default es)\n\
       --artifacts <dir>                artifacts dir (default ./artifacts)\n\
     serve:\n\
       --bind <addr:port>               listen address (default 127.0.0.1:8311)\n\
       --flush-ms <n>                   batcher flush window (default 20)\n\
       --sched <continuous|rtc>         scheduling mode (default continuous)\n\
       --fused-k <n>                    fused k-step dispatch depth (default 1;\n\
                                        runs of ES iterations execute as one\n\
                                        device dispatch, floored to a compiled\n\
                                        depth in {2,4,8})\n\
       --fault-plan <spec>              deterministic fault injection, e.g.\n\
                                        exec@3,alloc@1,rate=0.02,seed=7\n\
                                        (kinds: exec|transfer|alloc|diverge;\n\
                                        default: no faults)\n\
     generate:\n\
       --prompt <text>                  prompt to complete\n\
     eval:\n\
       --bench <arith|chain|logic|codegen|listops>\n\
       --n <samples>                    eval set size (default 32)\n\
       --parallel <threshold>           enable parallel decoding\n\
       --sparse                         enable sparse attention\n"
        .to_string()
}

fn main() -> Result<()> {
    esdllm::logging::init();
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let arch = args.str("arch", "llada-nano");
    let artifacts = std::path::PathBuf::from(
        args.str("artifacts", &default_artifacts_dir().display().to_string()),
    );
    let method = method_from_str(&args.str("method", "es"))?;

    let mut engine_cfg = EngineCfg::new(&arch, method);
    engine_cfg.checkpoint = args.str("checkpoint", "instruct");
    if let Some(t) = args.opt("parallel") {
        engine_cfg.sampler = engine_cfg
            .sampler
            .with_parallel(t.parse().map_err(|_| anyhow!("bad --parallel"))?);
    }
    engine_cfg.sparse = args.bool("sparse");
    engine_cfg.fused_k = args.usize("fused-k", 1);
    if let Some(plan) = args.opt("fault-plan") {
        engine_cfg.fault_plan = esdllm::fault::FaultPlan::parse(plan)
            .map_err(|e| anyhow!("bad --fault-plan: {e}"))?;
    }

    match cmd.as_str() {
        "serve" => {
            let mode = match args.str("sched", "continuous").as_str() {
                "rtc" | "run-to-completion" => SchedMode::RunToCompletion,
                "continuous" => SchedMode::Continuous,
                other => {
                    return Err(anyhow!("unknown --sched {other} (continuous|rtc)"))
                }
            };
            let policy = match args.str("slo-policy", "slo").as_str() {
                "fifo" => SloPolicy::Fifo,
                "slo" | "slo-aware" => SloPolicy::SloAware,
                other => return Err(anyhow!("unknown --slo-policy {other} (slo|fifo)")),
            };
            let router = Router::start(RouterCfg {
                engine: engine_cfg,
                batcher: BatcherCfg {
                    max_batch: 8,
                    flush_ms: args.u64("flush-ms", 20),
                },
                queue_cap: args.usize("queue-cap", 256),
                workers: args.usize("workers", 1),
                artifacts_dir: artifacts,
                mode,
                backend: WorkerBackend::Pjrt,
                policy,
                live_ctx: args.bool("live-ctx"),
                park_promote_ms: None,
            });
            let cfg = ServeCfg {
                bind: args.str("bind", "127.0.0.1:8311"),
                http_threads: args.usize("http-threads", 4),
                reply_timeout_ms: args.u64("reply-timeout-ms", 600_000),
            };
            let server = serve(&cfg, router.clone())?;
            println!("esdllm serving on http://{} (arch={arch})", server.addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "generate" => {
            let prompt = args.str("prompt", "sort(5,2,9)=");
            let rt = Runtime::load(&artifacts)?;
            let mut engine = Engine::new(&rt, engine_cfg);
            let res = engine.generate(&[prompt.clone()])?;
            println!("{prompt} -> {}", res.texts[0]);
            println!(
                "{} iterations ({}p/{}d/{}e) in {:.3}s",
                res.iterations, res.n_prefill, res.n_dual, res.n_es, res.wall_s
            );
        }
        "eval" => {
            let bench: &'static str = match args.str("bench", "arith").as_str() {
                "arith" => "arith",
                "chain" => "chain",
                "logic" => "logic",
                "codegen" => "codegen",
                "listops" => "listops",
                other => return Err(anyhow!("unknown bench {other}")),
            };
            let rt = Runtime::load(&artifacts)?;
            let opts = EvalOpts {
                checkpoint: Some(args.str("checkpoint", "instruct")),
                parallel_threshold: args
                    .opt("parallel")
                    .and_then(|t| t.parse().ok()),
                sparse: args.bool("sparse"),
                ..Default::default()
            };
            let n = args.usize("n", 32);
            let res = eval::evaluate(&rt, &arch, method, bench, n, &opts)?;
            println!(
                "{} / {} / {}: TPS {:.2}  score {:.2}%  ({} iters: {}p/{}d/{}e)",
                arch, res.method, bench, res.tps, res.score, res.iterations,
                res.n_prefill, res.n_dual, res.n_es
            );
        }
        "info" => {
            let rt = Runtime::load(&artifacts)?;
            let g = &rt.manifest.generation;
            println!(
                "artifacts: {} (ctx {} = prompt {} + gen {}, vocab {})",
                artifacts.display(), g.ctx, g.prompt_len, g.gen_len, g.vocab
            );
            for (name, a) in &rt.manifest.archs {
                println!(
                    "  {name}: {} layers, d={}, heads {}/{}kv, {} executables, checkpoints {:?}",
                    a.dims.n_layers, a.dims.d_model, a.dims.n_heads,
                    a.dims.n_kv_heads, a.executables.len(),
                    a.checkpoints.keys().collect::<Vec<_>>()
                );
            }
        }
        _ => {
            print!("{}", usage());
        }
    }
    Ok(())
}
