//! Decode-state caches for one batched sequence group, plus the refresh
//! scheduler (paper §5.2, Table 5).
//!
//! Host-owned state (bf16 raw bits for KV/indicator, f32 for
//! logits/confidence) that streams through the stateless step executables:
//!
//!   * KV cache            [L, 2, B, Hkv, T, hd]  (T = ctx, or pruned)
//!   * indicator caches    per indicator: [L, B, gen, d] — all layers so
//!                         any skip config can be served from one prefill
//!   * latest logits       [B, gen, V] and confidence [B, gen]
//!
//! The step executable returns only the *block slice* of updated KV and
//! indicator rows; [`GroupCaches::scatter_kv_block`] folds those back in.

use anyhow::{anyhow, Result};

use crate::manifest::Dims;
use crate::runtime::tensor::HostTensor;

#[derive(Debug, Clone)]
pub struct GroupCaches {
    pub dims: Dims,
    pub batch: usize,
    /// dense KV cache [L, 2, B, Hkv, ctx, hd] (bf16 bits)
    pub kv: Vec<u16>,
    /// pruned KV cache for sparse attention [L, 2, B, Hkv, keep_len, hd]
    pub kv_sparse: Option<SparseKv>,
    /// indicator caches by name ("h", "q", "k", "v"): [L, B, gen, d]
    pub ind: std::collections::BTreeMap<&'static str, Vec<u16>>,
    /// latest logits per gen position [B, gen, V]
    pub logits: Vec<f32>,
    /// latest confidence per gen position [B, gen]
    pub conf: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct SparseKv {
    /// [L, 2, B, Hkv, keep_len, hd] bf16 bits
    pub kv: Vec<u16>,
    /// retained prompt rows per batch element [B, keep_prompt] (sorted)
    pub keep_idx: Vec<Vec<usize>>,
    pub keep_prompt: usize,
}

pub const INDICATORS: [&str; 4] = ["h", "q", "k", "v"];

impl GroupCaches {
    pub fn new(dims: &Dims, batch: usize) -> GroupCaches {
        let d = dims;
        let kv_len = d.n_layers * 2 * batch * d.n_kv_heads * d.ctx * d.head_dim;
        let ind_len = d.n_layers * batch * d.gen_len * d.d_model;
        GroupCaches {
            dims: d.clone(),
            batch,
            kv: vec![0; kv_len],
            kv_sparse: None,
            ind: INDICATORS.iter().map(|i| (*i, vec![0u16; ind_len])).collect(),
            logits: vec![0.0; batch * d.gen_len * d.vocab],
            conf: vec![0.0; batch * d.gen_len],
        }
    }

    // -- index helpers ----------------------------------------------------

    /// offset into the dense KV cache at (layer, k_or_v, b, h, t, 0)
    fn kv_off(&self, t_len: usize, l: usize, s: usize, b: usize, h: usize, t: usize) -> usize {
        let d = &self.dims;
        ((((l * 2 + s) * self.batch + b) * d.n_kv_heads + h) * t_len + t) * d.head_dim
    }

    fn all_slots(&self) -> Vec<usize> {
        (0..self.batch).collect()
    }

    // -- refresh from a prefill pass ---------------------------------------

    /// Overwrite all caches from prefill outputs
    /// (logits, kv, ind_h, ind_q, ind_k, ind_v, attn_mass).
    pub fn refresh_from_prefill(&mut self, outputs: &[HostTensor]) -> Result<()> {
        let slots = self.all_slots();
        self.refresh_slots_from_prefill(outputs, &slots)
    }

    /// Slot-lifecycle variant: merge prefill outputs into the given batch
    /// rows only. The continuous-batching scheduler uses this so that a
    /// grounding prefill for newly admitted sequences (or a per-slot
    /// prompt refresh) never perturbs the decode trajectory of the other
    /// occupants — batch rows are independent sequences, so a row-filtered
    /// merge is exact.
    pub fn refresh_slots_from_prefill(
        &mut self,
        outputs: &[HostTensor],
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims.clone();
        self.merge_full_logits_slots(&outputs[0], slots)?;
        let kv_src = outputs[1].as_bf16()?;
        let row = d.n_kv_heads * d.ctx * d.head_dim;
        for l in 0..d.n_layers {
            for s in 0..2 {
                for &b in slots {
                    let off = ((l * 2 + s) * self.batch + b) * row;
                    self.kv[off..off + row].copy_from_slice(&kv_src[off..off + row]);
                }
            }
        }
        let ind_row = d.gen_len * d.d_model;
        for (i, name) in INDICATORS.iter().enumerate() {
            let src = outputs[2 + i].as_bf16()?;
            let dst = self.ind.get_mut(name).unwrap();
            for l in 0..d.n_layers {
                for &b in slots {
                    let off = (l * self.batch + b) * ind_row;
                    dst[off..off + ind_row].copy_from_slice(&src[off..off + ind_row]);
                }
            }
        }
        Ok(())
    }

    /// Merge full-context logits [B, ctx, V] into the gen-region
    /// latest-logits state for the given slots and refresh their
    /// confidences (the vanilla method's whole cache interaction).
    pub fn merge_full_logits_slots(
        &mut self,
        logits_full: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims.clone();
        let v = d.vocab;
        let src_all = logits_full.as_f32()?;
        for &b in slots {
            for g in 0..d.gen_len {
                let src = (b * d.ctx + d.prompt_len + g) * v;
                let dst = (b * d.gen_len + g) * v;
                self.logits[dst..dst + v].copy_from_slice(&src_all[src..src + v]);
            }
        }
        self.recompute_conf_slots(slots);
        Ok(())
    }

    /// Confidence = max softmax probability per gen position.
    pub fn recompute_conf(&mut self) {
        let slots = self.all_slots();
        self.recompute_conf_slots(&slots);
    }

    pub fn recompute_conf_slots(&mut self, slots: &[usize]) {
        let v = self.dims.vocab;
        let gen = self.dims.gen_len;
        for &b in slots {
            for g in 0..gen {
                let i = b * gen + g;
                let row = &self.logits[i * v..(i + 1) * v];
                self.conf[i] = softmax_max(row);
            }
        }
    }

    // -- slot lifecycle ------------------------------------------------------

    /// Zero every cache row of one slot so a retiring sequence leaves no
    /// state behind for the next occupant.
    pub fn reset_slot(&mut self, b: usize) {
        let d = self.dims.clone();
        let kv_row = d.n_kv_heads * d.ctx * d.head_dim;
        for l in 0..d.n_layers {
            for s in 0..2 {
                let off = ((l * 2 + s) * self.batch + b) * kv_row;
                self.kv[off..off + kv_row].fill(0);
            }
        }
        let ind_row = d.gen_len * d.d_model;
        for cache in self.ind.values_mut() {
            for l in 0..d.n_layers {
                let off = (l * self.batch + b) * ind_row;
                cache[off..off + ind_row].fill(0);
            }
        }
        self.logits[b * d.gen_len * d.vocab..(b + 1) * d.gen_len * d.vocab].fill(0.0);
        self.conf[b * d.gen_len..(b + 1) * d.gen_len].fill(0.0);
        if let Some(sp) = self.kv_sparse.as_mut() {
            let keep_len = sp.keep_prompt + d.gen_len;
            let sp_row = d.n_kv_heads * keep_len * d.head_dim;
            for l in 0..d.n_layers {
                for s in 0..2 {
                    let off = ((l * 2 + s) * self.batch + b) * sp_row;
                    sp.kv[off..off + sp_row].fill(0);
                }
            }
            sp.keep_idx[b].clear();
        }
    }

    // -- step-executable I/O ------------------------------------------------

    /// Gather the indicator-cache rows for `layers` into the step input
    /// tensor [n_ind, B, gen, d].
    pub fn gather_ind(&self, indicator: &str, layers: &[usize]) -> Result<HostTensor> {
        let d = &self.dims;
        let src = self
            .ind
            .get(indicator)
            .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?;
        let row = self.batch * d.gen_len * d.d_model;
        let mut data = Vec::with_capacity(layers.len().max(1) * row);
        if layers.is_empty() {
            data.resize(row, 0); // n_ind >= 1 dummy slot
        }
        for &l in layers {
            data.extend_from_slice(&src[l * row..(l + 1) * row]);
        }
        Ok(HostTensor::Bf16 {
            shape: vec![layers.len().max(1), self.batch, d.gen_len, d.d_model],
            data,
        })
    }

    /// Scatter a returned indicator block [n_ind, B, block, d] at
    /// `block_start` (absolute) back into the per-layer cache rows.
    pub fn scatter_ind_block(
        &mut self,
        indicator: &str,
        layers: &[usize],
        block_start: usize,
        block: usize,
        t: &HostTensor,
    ) -> Result<()> {
        let slots = self.all_slots();
        self.scatter_ind_block_slots(indicator, layers, block_start, block, t, &slots)
    }

    /// Row-filtered scatter: only the given slots' indicator rows are
    /// updated; spectator rows (slots working a different block, or
    /// vacant) keep their state.
    pub fn scatter_ind_block_slots(
        &mut self,
        indicator: &str,
        layers: &[usize],
        block_start: usize,
        block: usize,
        t: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d_model = self.dims.d_model;
        let gen_len = self.dims.gen_len;
        let batch = self.batch;
        let g0 = block_start - self.dims.prompt_len;
        let data = t.as_bf16()?;
        let dst = self
            .ind
            .get_mut(indicator)
            .ok_or_else(|| anyhow!("unknown indicator {indicator}"))?;
        for (i, &l) in layers.iter().enumerate() {
            for &b in slots {
                for j in 0..block {
                    let src = (((i * batch) + b) * block + j) * d_model;
                    let dstoff = ((l * batch + b) * gen_len + g0 + j) * d_model;
                    dst[dstoff..dstoff + d_model]
                        .copy_from_slice(&data[src..src + d_model]);
                }
            }
        }
        Ok(())
    }

    /// Scatter a returned KV block [L, 2, B, Hkv, block, hd] into the dense
    /// cache at absolute position `block_start`.
    pub fn scatter_kv_block(
        &mut self,
        block_start: usize,
        block: usize,
        t: &HostTensor,
    ) -> Result<()> {
        let slots = self.all_slots();
        self.scatter_kv_block_slots(block_start, block, t, &slots)
    }

    /// Row-filtered variant of [`GroupCaches::scatter_kv_block`].
    pub fn scatter_kv_block_slots(
        &mut self,
        block_start: usize,
        block: usize,
        t: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims.clone();
        let hd = d.head_dim;
        let data = t.as_bf16()?;
        for l in 0..d.n_layers {
            for s in 0..2 {
                for &b in slots {
                    for h in 0..d.n_kv_heads {
                        let src =
                            ((((l * 2 + s) * self.batch + b) * d.n_kv_heads + h) * block) * hd;
                        let dst = self.kv_off(d.ctx, l, s, b, h, block_start);
                        self.kv[dst..dst + block * hd]
                            .copy_from_slice(&data[src..src + block * hd]);
                    }
                }
            }
        }
        Ok(())
    }

    /// Same, into the pruned sparse cache (block rows live at
    /// `keep_prompt + (block_start - prompt_len)`).
    pub fn scatter_kv_block_sparse(
        &mut self,
        block_start: usize,
        block: usize,
        t: &HostTensor,
    ) -> Result<()> {
        let slots = self.all_slots();
        self.scatter_kv_block_sparse_slots(block_start, block, t, &slots)
    }

    /// Row-filtered variant of [`GroupCaches::scatter_kv_block_sparse`].
    pub fn scatter_kv_block_sparse_slots(
        &mut self,
        block_start: usize,
        block: usize,
        t: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims.clone();
        let batch = self.batch;
        let hd = d.head_dim;
        let data = t.as_bf16()?;
        let sp = self.kv_sparse.as_mut().ok_or_else(|| anyhow!("no sparse cache"))?;
        let keep_len = sp.keep_prompt + d.gen_len;
        let row0 = sp.keep_prompt + (block_start - d.prompt_len);
        for l in 0..d.n_layers {
            for s in 0..2 {
                for &b in slots {
                    for h in 0..d.n_kv_heads {
                        let src =
                            ((((l * 2 + s) * batch + b) * d.n_kv_heads + h) * block) * hd;
                        let dst = ((((l * 2 + s) * batch + b) * d.n_kv_heads + h)
                            * keep_len
                            + row0)
                            * hd;
                        sp.kv[dst..dst + block * hd]
                            .copy_from_slice(&data[src..src + block * hd]);
                    }
                }
            }
        }
        Ok(())
    }

    /// Merge computed logits (`logits` [B, k, V] at absolute positions
    /// `pos` [B, k]) into the latest-logits state and refresh confidences
    /// for those positions. Skipped positions keep their stale
    /// logits/confidence — exactly the paper's reuse semantics.
    pub fn merge_step_logits(&mut self, logits: &HostTensor, pos: &HostTensor) -> Result<()> {
        let slots = self.all_slots();
        self.merge_step_logits_slots(logits, pos, &slots)
    }

    /// Row-filtered variant of [`GroupCaches::merge_step_logits`]: the
    /// scheduler applies a step's logits only to the slots that were
    /// actually working the stepped block.
    pub fn merge_step_logits_slots(
        &mut self,
        logits: &HostTensor,
        pos: &HostTensor,
        slots: &[usize],
    ) -> Result<()> {
        let d = &self.dims;
        let v = d.vocab;
        let lg = logits.as_f32()?;
        let ps = pos.as_i32()?;
        let k = logits.shape()[1];
        for &b in slots {
            for j in 0..k {
                let p = ps[b * k + j] as usize;
                let g = p - d.prompt_len;
                let dst = (b * d.gen_len + g) * v;
                let src = (b * k + j) * v;
                self.logits[dst..dst + v].copy_from_slice(&lg[src..src + v]);
                self.conf[b * d.gen_len + g] = softmax_max(&lg[src..src + v]);
            }
        }
        Ok(())
    }

    pub fn kv_tensor(&self) -> HostTensor {
        let d = &self.dims;
        HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, self.batch, d.n_kv_heads, d.ctx, d.head_dim],
            data: self.kv.clone(),
        }
    }

    pub fn kv_sparse_tensor(&self) -> Result<HostTensor> {
        let d = &self.dims;
        let sp = self.kv_sparse.as_ref().ok_or_else(|| anyhow!("no sparse cache"))?;
        Ok(HostTensor::Bf16 {
            shape: vec![
                d.n_layers,
                2,
                self.batch,
                d.n_kv_heads,
                sp.keep_prompt + d.gen_len,
                d.head_dim,
            ],
            data: sp.kv.clone(),
        })
    }

    pub fn conf_tensor(&self) -> HostTensor {
        HostTensor::F32 {
            shape: vec![self.batch, self.dims.gen_len],
            data: self.conf.clone(),
        }
    }

    /// Confidence input with an occupancy mask applied: rows NOT in
    /// `slots` (vacant slots, or slots working a different block) are
    /// pinned to -1.0, below any real confidence in [0, 1], so they can
    /// never win the in-graph importance selection (I = α·conf +
    /// (1−α)·var, Eq. 1) and the executable's compute budget goes to the
    /// occupants. -1.0 rather than -inf keeps α·conf finite for α = 0.
    pub fn conf_tensor_masked(&self, slots: &[usize]) -> HostTensor {
        let gen = self.dims.gen_len;
        let mut data = vec![-1.0f32; self.batch * gen];
        for &b in slots {
            data[b * gen..(b + 1) * gen].copy_from_slice(&self.conf[b * gen..(b + 1) * gen]);
        }
        HostTensor::F32 { shape: vec![self.batch, gen], data }
    }

    // -- sparse-attention selection (Sparse-dLLM analog) --------------------

    /// Rebuild the pruned KV cache from the dense one: per batch element,
    /// retain the `keep_prompt` prompt rows with the highest
    /// kernel-smoothed attention mass, then all gen rows.
    pub fn rebuild_sparse(
        &mut self,
        attn_mass: &HostTensor,
        keep_prompt: usize,
        smooth_kernel: usize,
    ) -> Result<()> {
        let slots = self.all_slots();
        self.rebuild_sparse_slots(attn_mass, keep_prompt, smooth_kernel, &slots)
    }

    /// Row-filtered sparse rebuild: refresh the pruned rows of the given
    /// slots only, leaving the other occupants' pruned cache untouched
    /// (slot admission under sparse attention).
    pub fn rebuild_sparse_slots(
        &mut self,
        attn_mass: &HostTensor,
        keep_prompt: usize,
        smooth_kernel: usize,
        slots: &[usize],
    ) -> Result<()> {
        let d = self.dims.clone();
        let mass = attn_mass.as_f32()?;
        let keep_len = keep_prompt + d.gen_len;
        let hd = d.head_dim;
        if self
            .kv_sparse
            .as_ref()
            .map(|sp| sp.keep_prompt != keep_prompt)
            .unwrap_or(true)
        {
            self.kv_sparse = Some(SparseKv {
                kv: vec![0u16; d.n_layers * 2 * self.batch * d.n_kv_heads * keep_len * hd],
                keep_idx: vec![Vec::new(); self.batch],
                keep_prompt,
            });
        }
        let mut keep_by_slot: Vec<(usize, Vec<usize>)> = Vec::with_capacity(slots.len());
        for &b in slots {
            let row = &mass[b * d.ctx..b * d.ctx + d.prompt_len];
            let smoothed = smooth(row, smooth_kernel);
            let mut order: Vec<usize> = (0..d.prompt_len).collect();
            order.sort_by(|&i, &j| smoothed[j].total_cmp(&smoothed[i]));
            let mut keep: Vec<usize> = order[..keep_prompt].to_vec();
            keep.sort();
            keep_by_slot.push((b, keep));
        }
        // split borrow: the dense cache is read while the sparse one is
        // written
        let mut sp = self.kv_sparse.take().unwrap();
        for l in 0..d.n_layers {
            for s in 0..2 {
                for (b, keep) in &keep_by_slot {
                    let b = *b;
                    for h in 0..d.n_kv_heads {
                        let base_dst =
                            (((l * 2 + s) * self.batch + b) * d.n_kv_heads + h) * keep_len;
                        // retained prompt rows
                        for (r, &src_t) in keep.iter().enumerate() {
                            let srco = self.kv_off(d.ctx, l, s, b, h, src_t);
                            let dsto = (base_dst + r) * hd;
                            sp.kv[dsto..dsto + hd]
                                .copy_from_slice(&self.kv[srco..srco + hd]);
                        }
                        // full gen region
                        let srco = self.kv_off(d.ctx, l, s, b, h, d.prompt_len);
                        let dsto = (base_dst + keep_prompt) * hd;
                        sp.kv[dsto..dsto + d.gen_len * hd]
                            .copy_from_slice(&self.kv[srco..srco + d.gen_len * hd]);
                    }
                }
            }
        }
        for (b, keep) in keep_by_slot {
            sp.keep_idx[b] = keep;
        }
        self.kv_sparse = Some(sp);
        Ok(())
    }
}

fn smooth(xs: &[f32], kernel: usize) -> Vec<f32> {
    if kernel <= 1 {
        return xs.to_vec();
    }
    let half = kernel / 2;
    (0..xs.len())
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(xs.len());
            xs[lo..hi].iter().sum::<f32>() / (hi - lo) as f32
        })
        .collect()
}

pub fn softmax_max(row: &[f32]) -> f32 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = row.iter().map(|x| (x - m).exp()).sum();
    1.0 / denom // exp(m - m) / sum = 1/denom
}

// ---------------------------------------------------------------------------
// refresh scheduling (paper Table 5 / 6)
// ---------------------------------------------------------------------------

/// Per-benchmark refresh policy: prompt refresh every `prompt_period`
/// iterations (global), block refresh every `block_period` iterations
/// within a block. A prefill at every block start grounds the new block
/// (DualCache does this implicitly; the periods add the ES cadence).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RefreshPolicy {
    pub prompt_period: usize,
    pub block_period: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPlan {
    /// full forward (prompt refresh / vanilla / block-start grounding)
    Prefill,
    /// full-block step, no skipping (block refresh / DualCache step)
    DualStep,
    /// early-skip step
    EsStep,
}

impl RefreshPolicy {
    /// Decide the compute for (global iteration g, iteration-within-block
    /// i_b) of an ES-dLLM run.
    pub fn plan_es(&self, g: usize, i_b: usize) -> StepPlan {
        if i_b == 0 || (self.prompt_period > 0 && g % self.prompt_period == 0) {
            StepPlan::Prefill
        } else if self.block_period > 0 && i_b % self.block_period == 0 {
            StepPlan::DualStep
        } else {
            StepPlan::EsStep
        }
    }

    /// DualCache baseline: prefill at block start, dual step otherwise.
    pub fn plan_dual(i_b: usize) -> StepPlan {
        if i_b == 0 {
            StepPlan::Prefill
        } else {
            StepPlan::DualStep
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> Dims {
        Dims {
            vocab: 8, d_model: 4, n_layers: 2, n_heads: 2, n_kv_heads: 1,
            d_ff: 8, head_dim: 2, prompt_len: 4, gen_len: 4, ctx: 8,
        }
    }

    #[test]
    fn softmax_max_uniform_row() {
        let c = softmax_max(&[0.0, 0.0, 0.0, 0.0]);
        assert!((c - 0.25).abs() < 1e-6);
        let c2 = softmax_max(&[10.0, 0.0, 0.0, 0.0]);
        assert!(c2 > 0.99);
    }

    #[test]
    fn merge_step_logits_updates_only_computed_positions() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 1);
        c.logits.fill(1.0);
        c.recompute_conf();
        let before = c.conf.clone();
        let logits = HostTensor::F32 {
            shape: vec![1, 1, 8],
            data: vec![9.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        };
        let pos = HostTensor::I32 { shape: vec![1, 1], data: vec![5] };
        c.merge_step_logits(&logits, &pos).unwrap();
        assert!(c.conf[1] > 0.9); // gen idx 1 (pos 5 - prompt 4) updated
        assert_eq!(c.conf[0], before[0]);
        assert_eq!(c.logits[(1 * 8) as usize], 9.0);
    }

    #[test]
    fn kv_scatter_block_roundtrip() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 1);
        // block = gen region rows 0..2 at absolute pos 4..6
        let block = 2;
        let n = d.n_layers * 2 * 1 * d.n_kv_heads * block * d.head_dim;
        let data: Vec<u16> = (0..n as u16).collect();
        let t = HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, 1, d.n_kv_heads, block, d.head_dim],
            data: data.clone(),
        };
        c.scatter_kv_block(4, block, &t).unwrap();
        // layer 0, k, b0, h0, t=4..6 should hold rows 0..block
        let off = c.kv_off(d.ctx, 0, 0, 0, 0, 4);
        assert_eq!(&c.kv[off..off + block * d.head_dim], &data[..block * d.head_dim]);
        // untouched region stays zero
        let off2 = c.kv_off(d.ctx, 0, 0, 0, 0, 0);
        assert!(c.kv[off2..off2 + 4 * d.head_dim].iter().all(|&x| x == 0));
    }

    #[test]
    fn sparse_rebuild_retains_top_mass_rows() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 1);
        for (i, v) in c.kv.iter_mut().enumerate() {
            *v = i as u16;
        }
        let mass = HostTensor::F32 {
            shape: vec![1, d.ctx],
            data: vec![0.1, 0.9, 0.8, 0.05, 0.0, 0.0, 0.0, 0.0],
        };
        c.rebuild_sparse(&mass, 2, 1).unwrap();
        let sp = c.kv_sparse.as_ref().unwrap();
        assert_eq!(sp.keep_idx[0], vec![1, 2]);
        let keep_len = 2 + d.gen_len;
        assert_eq!(
            sp.kv.len(),
            d.n_layers * 2 * d.n_kv_heads * keep_len * d.head_dim
        );
        // first retained row equals dense row t=1 of layer0/k/h0
        let src = c.kv_off(d.ctx, 0, 0, 0, 0, 1);
        assert_eq!(&sp.kv[..d.head_dim], &c.kv[src..src + d.head_dim]);
    }

    #[test]
    fn slot_filtered_kv_scatter_leaves_spectators_untouched() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let block = 2;
        let n = d.n_layers * 2 * 2 * d.n_kv_heads * block * d.head_dim;
        let data: Vec<u16> = (1..=n as u16).collect();
        let t = HostTensor::Bf16 {
            shape: vec![d.n_layers, 2, 2, d.n_kv_heads, block, d.head_dim],
            data,
        };
        c.scatter_kv_block_slots(4, block, &t, &[1]).unwrap();
        // slot 0 untouched, slot 1 written
        let off0 = c.kv_off(d.ctx, 0, 0, 0, 0, 4);
        assert!(c.kv[off0..off0 + block * d.head_dim].iter().all(|&x| x == 0));
        let off1 = c.kv_off(d.ctx, 0, 0, 1, 0, 4);
        assert!(c.kv[off1..off1 + block * d.head_dim].iter().any(|&x| x != 0));
    }

    #[test]
    fn slot_filtered_logit_merge_and_reset() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let logits = HostTensor::F32 {
            shape: vec![2, 1, 8],
            data: vec![
                9.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // slot 0 row
                7.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // slot 1 row
            ],
        };
        let pos = HostTensor::I32 { shape: vec![2, 1], data: vec![4, 4] };
        c.merge_step_logits_slots(&logits, &pos, &[1]).unwrap();
        assert_eq!(c.logits[0], 0.0, "slot 0 must be untouched");
        assert_eq!(c.logits[d.gen_len * d.vocab], 7.0, "slot 1 gen row 0");
        c.reset_slot(1);
        assert_eq!(c.logits[d.gen_len * d.vocab], 0.0);
        assert_eq!(c.conf[d.gen_len], 0.0);
    }

    #[test]
    fn conf_tensor_masked_pins_vacant_rows() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        c.conf.fill(0.5);
        let t = c.conf_tensor_masked(&[0]);
        let data = t.as_f32().unwrap();
        assert!(data[..d.gen_len].iter().all(|&x| x == 0.5));
        assert!(data[d.gen_len..].iter().all(|&x| x == -1.0));
    }

    #[test]
    fn slot_filtered_prefill_refresh() {
        let d = dims();
        let mut c = GroupCaches::new(&d, 2);
        let v = d.vocab;
        let mut logits_full = vec![0.0f32; 2 * d.ctx * v];
        // peak token 3 for every gen position of both rows
        for b in 0..2 {
            for g in 0..d.gen_len {
                logits_full[(b * d.ctx + d.prompt_len + g) * v + 3] = 5.0;
            }
        }
        let kv_len = d.n_layers * 2 * 2 * d.n_kv_heads * d.ctx * d.head_dim;
        let ind_len = d.n_layers * 2 * d.gen_len * d.d_model;
        let outputs = vec![
            HostTensor::F32 { shape: vec![2, d.ctx, v], data: logits_full },
            HostTensor::Bf16 {
                shape: vec![d.n_layers, 2, 2, d.n_kv_heads, d.ctx, d.head_dim],
                data: vec![7u16; kv_len],
            },
            HostTensor::Bf16 { shape: vec![d.n_layers, 2, d.gen_len, d.d_model], data: vec![1u16; ind_len] },
            HostTensor::Bf16 { shape: vec![d.n_layers, 2, d.gen_len, d.d_model], data: vec![2u16; ind_len] },
            HostTensor::Bf16 { shape: vec![d.n_layers, 2, d.gen_len, d.d_model], data: vec![3u16; ind_len] },
            HostTensor::Bf16 { shape: vec![d.n_layers, 2, d.gen_len, d.d_model], data: vec![4u16; ind_len] },
            HostTensor::F32 { shape: vec![2, d.ctx], data: vec![0.0; 2 * d.ctx] },
        ];
        c.refresh_slots_from_prefill(&outputs, &[1]).unwrap();
        // slot 1 refreshed: confident logits + kv filled
        assert!(c.conf[d.gen_len] > 0.9);
        let off1 = c.kv_off(d.ctx, 0, 0, 1, 0, 0);
        assert_eq!(c.kv[off1], 7);
        // slot 0 untouched
        assert_eq!(c.conf[0], 0.0);
        let off0 = c.kv_off(d.ctx, 0, 0, 0, 0, 0);
        assert_eq!(c.kv[off0], 0);
    }

    #[test]
    fn refresh_plan_cadence() {
        let p = RefreshPolicy { prompt_period: 8, block_period: 2 };
        // block of 4: i_b 0 → prefill; odd iters es; even (non-0) dual
        assert_eq!(p.plan_es(0, 0), StepPlan::Prefill);
        assert_eq!(p.plan_es(1, 1), StepPlan::EsStep);
        assert_eq!(p.plan_es(2, 2), StepPlan::DualStep);
        assert_eq!(p.plan_es(3, 3), StepPlan::EsStep);
        assert_eq!(p.plan_es(8, 4), StepPlan::Prefill); // global prompt period
        assert_eq!(RefreshPolicy::plan_dual(0), StepPlan::Prefill);
        assert_eq!(RefreshPolicy::plan_dual(3), StepPlan::DualStep);
    }

    #[test]
    fn smooth_is_mean_filter() {
        let s = smooth(&[0.0, 3.0, 0.0], 3);
        assert!((s[1] - 1.0).abs() < 1e-6);
        assert_eq!(smooth(&[1.0, 2.0], 1), vec![1.0, 2.0]);
    }
}
