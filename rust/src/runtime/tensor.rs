//! Host-side tensor type crossing the Rust ↔ PJRT boundary.
//!
//! Caches travel as bf16 (half the upload bandwidth of f32 — the
//! interchange analog of the paper's BF16 KV caches); bf16 payloads are
//! stored as raw u16 bit patterns since no host math is ever done on them.

use anyhow::{anyhow, Result};

use crate::manifest::DType;

#[derive(Debug, Clone)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    Bf16 { shape: Vec<usize>, data: Vec<u16> },
}

impl HostTensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] },
            DType::I32 => HostTensor::I32 { shape: shape.to_vec(), data: vec![0; n] },
            DType::Bf16 => HostTensor::Bf16 { shape: shape.to_vec(), data: vec![0; n] },
        }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. }
            | HostTensor::I32 { shape, .. }
            | HostTensor::Bf16 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
            HostTensor::Bf16 { .. } => DType::Bf16,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    pub fn as_bf16(&self) -> Result<&[u16]> {
        match self {
            HostTensor::Bf16 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not bf16")),
        }
    }

    pub fn as_bf16_mut(&mut self) -> Result<&mut [u16]> {
        match self {
            HostTensor::Bf16 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not bf16")),
        }
    }
}

/// Inline fixed-capacity tensor shape (everything here is ≤ 6-D), so
/// building a view never heap-allocates.
#[derive(Debug, Clone, Copy)]
pub struct ShapeVec {
    len: u8,
    dims: [usize; 8],
}

impl ShapeVec {
    /// Panics if the rank exceeds the inline capacity of 8.
    pub fn from_slice(s: &[usize]) -> ShapeVec {
        assert!(s.len() <= 8, "tensor rank {} exceeds ShapeVec capacity", s.len());
        let mut dims = [0usize; 8];
        dims[..s.len()].copy_from_slice(s);
        ShapeVec { len: s.len() as u8, dims }
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.dims[..self.len as usize]
    }
}

/// Borrowed view of host tensor data crossing the upload boundary.
///
/// The step hot path uploads multi-megabyte cache tensors every tick;
/// building a [`HostTensor`] there would clone the whole backing vector
/// first. A `TensorView` carries an inline shape plus a borrowed slice
/// so the runtime can stream straight from the cache's own storage (or
/// from a pooled scratch buffer) with zero host-side copies and zero
/// allocations.
#[derive(Debug, Clone)]
pub enum TensorView<'a> {
    F32 { shape: ShapeVec, data: &'a [f32] },
    I32 { shape: ShapeVec, data: &'a [i32] },
    Bf16 { shape: ShapeVec, data: &'a [u16] },
}

impl<'a> TensorView<'a> {
    pub fn shape(&self) -> &[usize] {
        match self {
            TensorView::F32 { shape, .. }
            | TensorView::I32 { shape, .. }
            | TensorView::Bf16 { shape, .. } => shape.as_slice(),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorView::F32 { .. } => DType::F32,
            TensorView::I32 { .. } => DType::I32,
            TensorView::Bf16 { .. } => DType::Bf16,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype().bytes()
    }
}

impl HostTensor {
    /// Borrow this tensor as a [`TensorView`] (no copies, no allocation).
    pub fn view(&self) -> TensorView<'_> {
        match self {
            HostTensor::F32 { shape, data } => {
                TensorView::F32 { shape: ShapeVec::from_slice(shape), data }
            }
            HostTensor::I32 { shape, data } => {
                TensorView::I32 { shape: ShapeVec::from_slice(shape), data }
            }
            HostTensor::Bf16 { shape, data } => {
                TensorView::Bf16 { shape: ShapeVec::from_slice(shape), data }
            }
        }
    }
}

/// f32 → bf16 bits, round-to-nearest-even (exact for values that were
/// bf16 upstream, which is the cache round-trip case).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return 0x7FC0;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits + round) >> 16) as u16
}

/// bf16 bits → f32 (exact).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

pub fn f32s_to_bf16(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|x| f32_to_bf16(*x)).collect()
}

pub fn bf16s_to_f32(xs: &[u16]) -> Vec<f32> {
    xs.iter().map(|b| bf16_to_f32(*b)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_roundtrip_exact_for_bf16_values() {
        for bits in [0u16, 0x3F80, 0xBF80, 0x4000, 0x7F7F, 0x0080] {
            let f = bf16_to_f32(bits);
            assert_eq!(f32_to_bf16(f), bits, "bits {bits:#x}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-8 rounds down to 1.0; 1.0 + 3*2^-9 rounds up
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0 + 1.0 / 512.0)), 1.0);
        let up = bf16_to_f32(f32_to_bf16(1.0 + 3.0 / 512.0));
        assert!(up > 1.0);
    }

    #[test]
    fn nan_maps_to_quiet_nan() {
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn zeros_matches_dtype() {
        let t = HostTensor::zeros(DType::Bf16, &[2, 3]);
        assert_eq!(t.elements(), 6);
        assert_eq!(t.dtype(), DType::Bf16);
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn view_mirrors_tensor_without_copying() {
        let t = HostTensor::F32 { shape: vec![2, 3], data: vec![1.0; 6] };
        let v = t.view();
        assert_eq!(v.shape(), t.shape());
        assert_eq!(v.dtype(), DType::F32);
        assert_eq!(v.elements(), 6);
        assert_eq!(v.byte_len(), 24);
        // scalars view as rank-0
        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.view().shape(), &[] as &[usize]);
        assert_eq!(s.view().elements(), 1);
        assert_eq!(ShapeVec::from_slice(&[4, 5]).as_slice(), &[4, 5]);
    }
}
