//! Quickstart: load the artifacts, generate a few completions with each
//! method, print the outputs and timing.
//!
//! Run: `cargo run --release --example quickstart -- [--arch llada-nano]`

use esdllm::cli::Args;
use esdllm::engine::{Engine, EngineCfg, Method};
use esdllm::runtime::Runtime;
use esdllm::workload;

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let arch = args.str("arch", "llada-nano");
    let rt = Runtime::load_default()?;

    let items = workload::eval_set("arith", 4);
    let prompts: Vec<String> = items.iter().map(|i| i.prompt.clone()).collect();
    println!("prompts:");
    for it in &items {
        println!("  {:>28}  (expected {})", it.prompt, it.answer);
    }

    for method in [Method::Vanilla, Method::DualCache, Method::EsDllm] {
        let cfg = EngineCfg::new(&arch, method);
        let mut engine = Engine::new(&rt, cfg);
        let res = engine.generate(&prompts)?;
        let correct = items
            .iter()
            .zip(&res.texts)
            .filter(|(it, txt)| workload::score(&it.answer, txt))
            .count();
        println!(
            "\n[{:9}] {} iters ({}p/{}d/{}e) in {:.2}s — {:.1} tok/s — {}/{} correct",
            method.label(),
            res.iterations,
            res.n_prefill,
            res.n_dual,
            res.n_es,
            res.wall_s,
            res.tokens_generated as f64 / res.wall_s,
            correct,
            items.len(),
        );
        for (it, txt) in items.iter().zip(&res.texts) {
            println!("  {:>28} -> {}", it.prompt, txt);
        }
    }
    Ok(())
}
