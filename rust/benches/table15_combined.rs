//! Table 15: ES-dLLM combined with BOTH parallel decoding and sparse
//! attention, vs the DualCache baseline, on both architectures.

use esdllm::bench::{bench_archs, bench_n, Table};
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::runtime::Runtime;
use esdllm::workload::{paper_name, BENCHMARKS};

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let n = bench_n(16);

    for arch in bench_archs() {
        let mut table = Table::new(
            &format!("Table 15 analog: ES-dLLM+PD+Sparse on {arch}, {n} samples"),
            &["Benchmark", "TPS", "Speedup vs DualCache", "Score", "Δscore vs DualCache"],
        );
        for bench in BENCHMARKS {
            let base =
                evaluate(&rt, &arch, Method::DualCache, bench, n, &EvalOpts::default())?;
            let opts = EvalOpts {
                parallel_threshold: Some(0.9),
                sparse: true,
                ..Default::default()
            };
            let r = evaluate(&rt, &arch, Method::EsDllm, bench, n, &opts)?;
            table.row(&[
                paper_name(bench).to_string(),
                format!("{:.2}", r.tps),
                format!("{:.2}x", r.speedup_vs(&base)),
                format!("{:.2}", r.score),
                format!("{:+.2}", r.score - base.score),
            ]);
        }
        table.print();
        let suffix = if arch.starts_with("llada") { "llada" } else { "dream" };
        table.write_csv(&format!("artifacts/results/table15_{suffix}.csv"))?;
    }
    Ok(())
}
