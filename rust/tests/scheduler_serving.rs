//! Full-stack continuous-batching serving tests: HTTP front end →
//! router → slot scheduler → simulation backend. No artifacts and no
//! PJRT library are required — the sim model echoes the prompt and then
//! EOS-fills, so expected outputs are exact.

use std::time::{Duration, Instant};

use esdllm::batcher::BatcherCfg;
use esdllm::engine::{EngineCfg, Method};
use esdllm::httpd::Client;
use esdllm::json::{self, Json};
use esdllm::router::{Router, RouterCfg, SchedMode, SloPolicy, WorkerBackend};
use esdllm::scheduler::sim::SimCfg;
use esdllm::server::{serve, ServeCfg};

struct Stack {
    router: Router,
    server: esdllm::httpd::Server,
}

fn start_policy(
    slots: usize,
    queue_cap: usize,
    sim: SimCfg,
    workers: usize,
    policy: SloPolicy,
) -> Stack {
    let mut cfg = RouterCfg::new(
        EngineCfg::new("llada-nano", Method::EsDllm),
        std::path::PathBuf::from("/nonexistent"),
    );
    cfg.backend = WorkerBackend::Sim(sim);
    cfg.batcher = BatcherCfg { max_batch: slots, flush_ms: 2 };
    cfg.queue_cap = queue_cap;
    cfg.mode = SchedMode::Continuous;
    cfg.workers = workers;
    cfg.policy = policy;
    let router = Router::start(cfg);
    let server = serve(&ServeCfg::default(), router.clone()).unwrap();
    Stack { router, server }
}

fn start_workers(slots: usize, queue_cap: usize, sim: SimCfg, workers: usize) -> Stack {
    start_policy(slots, queue_cap, sim, workers, SloPolicy::SloAware)
}

fn start(slots: usize, queue_cap: usize, sim: SimCfg) -> Stack {
    start_workers(slots, queue_cap, sim, 1)
}

/// Value of one `name value` line in the Prometheus exposition.
fn metric_value(m: &str, name: &str) -> u64 {
    let prefix = format!("{name} ");
    m.lines()
        .find_map(|l| l.strip_prefix(prefix.as_str()))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or_else(|| panic!("metric {name} missing in:\n{m}"))
}

fn post_generate(client: &mut Client, body: &str) -> (u16, Json) {
    let (status, resp) = client.post("/generate", body.as_bytes()).unwrap();
    let j = Json::parse(std::str::from_utf8(&resp).unwrap_or("{}")).unwrap_or(Json::Null);
    (status, j)
}

#[test]
fn generate_with_per_request_gen_len() {
    let stack = start(2, 16, SimCfg::default());
    let mut client = Client::new(stack.server.addr);

    // default gen_len: the sim echoes the whole prompt
    let (st, j) = post_generate(&mut client, r#"{"prompt": "sort(3,1)=1,3"}"#);
    assert_eq!(st, 200, "{j:?}");
    assert_eq!(j.get("text").as_str(), Some("sort(3,1)=1,3"));
    assert!(j.get("iterations").as_usize().unwrap() > 0);
    assert!(j.get("queue_s").as_f64().is_some());

    // gen_len 8 (one block): the echo is truncated to 8 tokens
    let (st, j) = post_generate(
        &mut client,
        r#"{"prompt": "abcdefghij", "gen_len": 8}"#,
    );
    assert_eq!(st, 200, "{j:?}");
    assert_eq!(j.get("text").as_str(), Some("abcdefgh"));
    assert_eq!(j.get("tokens").as_usize(), Some(8));

    // invalid gen_len (not a multiple of the block) is a client error
    let (st, _) = post_generate(&mut client, r#"{"prompt": "ab", "gen_len": 5}"#);
    assert_eq!(st, 400);
    stack.router.shutdown();
}

#[test]
fn mid_flight_admission_and_early_retirement() {
    // Two slots, visible per-tick cost. A long request occupies slot 0;
    // a short request arrives mid-flight, is admitted into the free slot
    // at its own block boundary, retires early (EOS guard), and its
    // reply must come back while the long request is still running —
    // with correct output text for both.
    let sim = SimCfg::default().with_costs(6000, 4000, 3000);
    let stack = start(2, 16, sim);
    let addr = stack.server.addr;

    // 21 chars → 3 blocks of 8 → ~24 ticks at ≥3ms per tick
    let long_prompt = "a+b*c-d/e+f*g-h+i*j=k";
    let long_handle = std::thread::spawn(move || {
        let mut client = Client::new(addr);
        let body = json::obj(vec![("prompt", json::s(long_prompt))]).to_string();
        let (st, j) = post_generate(&mut client, &body);
        (st, j, Instant::now())
    });
    // let the long request get admitted and into its first block
    std::thread::sleep(Duration::from_millis(25));

    let mut client = Client::new(addr);
    let (st_short, j_short) = post_generate(&mut client, r#"{"prompt": "xy"}"#);
    let short_done = Instant::now();
    let (st_long, j_long, long_done) = long_handle.join().unwrap();

    assert_eq!(st_short, 200, "{j_short:?}");
    assert_eq!(st_long, 200, "{j_long:?}");
    assert_eq!(j_short.get("text").as_str(), Some("xy"));
    assert_eq!(j_long.get("text").as_str(), Some(long_prompt));
    // the short sequence entered the running group and retired first:
    // its reply must predate the long request's completion
    assert!(
        short_done < long_done,
        "short request must retire while the long one is still decoding"
    );
    // EOS-guard early retirement: 2 content tokens + EOS fill inside one
    // block of 8 → far fewer iterations than the long request
    let it_short = j_short.get("iterations").as_usize().unwrap();
    let it_long = j_long.get("iterations").as_usize().unwrap();
    assert!(it_short < it_long, "short {it_short} !< long {it_long}");

    // scheduler metrics: two admissions, two retirements, slots freed
    let (st, m) = client.get("/metrics").unwrap();
    assert_eq!(st, 200);
    let m = String::from_utf8_lossy(&m);
    assert!(m.contains("esdllm_admissions_total 2"), "{m}");
    assert!(m.contains("esdllm_retirements_total 2"), "{m}");
    assert!(m.contains("esdllm_active_slots 0"), "{m}");
    // resident-cache accounting is exposed: at most one full-KV upload
    // per batch class — the residency seeds — never one per request
    // (the lone request ran on the b=1 class; the mid-flight admission
    // upshifted to the full class at a block boundary)
    let seeds = metric_value(&m, "esdllm_full_kv_uploads");
    assert!((1..=2).contains(&seeds), "one seed per touched class: {m}");
    assert!(!m.contains("esdllm_upload_bytes_saved 0\n"), "{m}");
    stack.router.shutdown();
}

#[test]
fn two_workers_serve_mid_flight_against_the_shared_pool() {
    // Two workers, two slots each, visible per-tick cost. A long
    // request pins one worker; shorts submitted mid-flight are absorbed
    // (by either worker) and retire first — and both workers publish
    // into the one shared residency pool.
    let sim = SimCfg::default().with_costs(4000, 2500, 2000);
    let stack = start_workers(2, 32, sim, 2);
    let addr = stack.server.addr;

    let long_prompt = "a+b*c-d/e+f*g-h+i*j=k"; // 21 chars → 3 blocks
    let long_handle = std::thread::spawn(move || {
        let mut client = Client::new(addr);
        let body = json::obj(vec![("prompt", json::s(long_prompt))]).to_string();
        let (st, j) = post_generate(&mut client, &body);
        (st, j, Instant::now())
    });
    std::thread::sleep(Duration::from_millis(25));

    // a small mid-flight burst of shorts
    let shorts: Vec<_> = ["xy", "pq", "ab"]
        .iter()
        .map(|p| {
            let prompt = p.to_string();
            std::thread::spawn(move || {
                let mut client = Client::new(addr);
                let body = json::obj(vec![("prompt", json::s(&prompt))]).to_string();
                let (st, j) = post_generate(&mut client, &body);
                (st, j, prompt, Instant::now())
            })
        })
        .collect();
    let mut first_short: Option<Instant> = None;
    for h in shorts {
        let (st, j, prompt, done) = h.join().unwrap();
        assert_eq!(st, 200, "{j:?}");
        assert_eq!(j.get("text").as_str(), Some(prompt.as_str()), "exact echo");
        first_short = Some(first_short.map_or(done, |f| f.min(done)));
    }
    let (st_long, j_long, long_done) = long_handle.join().unwrap();
    assert_eq!(st_long, 200, "{j_long:?}");
    assert_eq!(j_long.get("text").as_str(), Some(long_prompt));
    assert!(
        first_short.unwrap() < long_done,
        "mid-flight shorts must start retiring while the long request is \
         still decoding"
    );

    let mut client = Client::new(addr);
    let (st, m) = client.get("/metrics").unwrap();
    assert_eq!(st, 200);
    let m = String::from_utf8_lossy(&m);
    // both workers registered their capacity and drained cleanly
    assert!(m.contains("esdllm_slots_total 4"), "{m}");
    assert!(m.contains("esdllm_admissions_total 4"), "{m}");
    assert!(m.contains("esdllm_retirements_total 4"), "{m}");
    assert!(m.contains("esdllm_active_slots 0"), "{m}");
    // the shared pool: every seeded chain is registered in one ledger —
    // bounded by workers × classes, and the seeds match the chains that
    // actually went live (never one per request)
    let chains = metric_value(&m, "esdllm_resident_chains");
    assert!((1..=4).contains(&chains), "pool-registered chains: {m}");
    let seeds = metric_value(&m, "esdllm_full_kv_uploads");
    assert!((1..=4).contains(&seeds), "at most one seed per (worker, class): {m}");
    stack.router.shutdown();
}

#[test]
fn queue_full_returns_503_backpressure() {
    // One slot, one queue position, slow ticks: under the FIFO baseline
    // policy a burst must overflow the bounded queue and be answered 503
    // without stalling the requests that were accepted. (The default
    // SLO-aware policy answers overload 429 instead — next test.)
    let sim = SimCfg::default().with_costs(20_000, 15_000, 10_000);
    let stack = start_policy(1, 1, sim, 1, SloPolicy::Fifo);
    let addr = stack.server.addr;

    let burst = 6;
    let handles: Vec<_> = (0..burst)
        .map(|_| {
            std::thread::spawn(move || {
                let mut client = Client::new(addr);
                // 20 chars → several blocks → the slot stays busy long
                // enough for the burst to hit a full queue
                let (st, _) =
                    post_generate(&mut client, r#"{"prompt": "0123456789+0123456789"}"#);
                st
            })
        })
        .collect();
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let ok = statuses.iter().filter(|&&s| s == 200).count();
    let busy = statuses.iter().filter(|&&s| s == 503).count();
    assert_eq!(ok + busy, burst, "only 200s and 503s expected: {statuses:?}");
    assert!(ok >= 1, "at least the admitted request completes: {statuses:?}");
    assert!(busy >= 1, "backpressure must reject part of the burst: {statuses:?}");

    let (_, m) = Client::new(addr).get("/metrics").unwrap();
    let m = String::from_utf8_lossy(&m);
    assert!(m.contains("esdllm_requests_rejected"), "{m}");
    stack.router.shutdown();
}

#[test]
fn slo_policy_answers_overload_with_structured_429() {
    // Same overload geometry under the default SLO-aware policy: the
    // overflow is shed with a structured `overloaded:` 429 through the
    // oneshot — every submission gets a reply, nothing hangs, nothing
    // silently drops.
    let sim = SimCfg::default().with_costs(20_000, 15_000, 10_000);
    let stack = start(1, 1, sim);
    let addr = stack.server.addr;

    let burst = 6;
    let handles: Vec<_> = (0..burst)
        .map(|_| {
            std::thread::spawn(move || {
                let mut client = Client::new(addr);
                post_generate(&mut client, r#"{"prompt": "0123456789+0123456789"}"#)
            })
        })
        .collect();
    let results: Vec<(u16, Json)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let statuses: Vec<u16> = results.iter().map(|(s, _)| *s).collect();
    let ok = statuses.iter().filter(|&&s| s == 200).count();
    let shed = statuses.iter().filter(|&&s| s == 429).count();
    assert_eq!(ok + shed, burst, "only 200s and 429s expected: {statuses:?}");
    assert!(ok >= 1, "at least the admitted request completes: {statuses:?}");
    assert!(shed >= 1, "the overload controller shed part of the burst: {statuses:?}");
    for (status, j) in &results {
        if *status == 429 {
            assert!(
                j.get("error").as_str().unwrap_or("").starts_with("overloaded:"),
                "shed replies carry the structured overload error"
            );
        }
    }

    let (_, m) = Client::new(addr).get("/metrics").unwrap();
    let m = String::from_utf8_lossy(&m);
    assert!(metric_value(&m, "esdllm_shed_total") >= 1, "{m}");
    stack.router.shutdown();
}
