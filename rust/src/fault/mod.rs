//! Deterministic fault injection and the fault/recovery ledger.
//!
//! A production serving stack has to keep answering requests when a
//! device dispatch fails. This module provides the three pieces the
//! fault-tolerant serving path is built from:
//!
//!   * [`FaultPlan`] — a seeded, deterministic schedule of injected
//!     faults, configurable via [`crate::engine::EngineCfg::fault_plan`]
//!     and the `--fault-plan` CLI knob. Faults are addressed by
//!     **per-kind event ordinals** (`exec@3` = the third executable run
//!     faults), optionally combined with a seeded Bernoulli rate
//!     (`rate=0.02,seed=7`) for Poisson-style soak traces. The same plan
//!     drives the sim backend's injector and converts to the vendored
//!     xla stub's [`xla::FaultSchedule`] via
//!     [`FaultPlan::stub_schedule`], so an ordinal faults at the same
//!     event on both layers.
//!   * [`FaultInjector`] — the shared per-backend injector: each
//!     injection site calls [`FaultInjector::check`] with its
//!     [`FaultKind`]; the injector counts the event, consults the plan,
//!     and returns a typed [`FaultError`] when the event is scheduled to
//!     fault. The injector also owns the [`FaultStats`] ledger the
//!     router's recovery loop feeds (`ticks_retried`,
//!     `chains_regrounded`, demotions, `requests_failed`), mirrored into
//!     `/metrics` exactly like the transfer ledger.
//!   * [`classify`] — the error taxonomy: a tick error is **transient**
//!     (an injected exec/transfer/alloc fault — invalidate the chain,
//!     re-ground, retry), **poisoned** (a fused committed-count
//!     divergence or an explicit [`PoisonedChain`] audit failure — the
//!     retained device state can no longer be trusted at the current
//!     fused depth; demote `k` before retrying), or a
//!     **misconfiguration** (anything else — retrying cannot help, fail
//!     fast).
//!
//! Determinism: ordinal faults are a pure function of the per-kind event
//! count; rate faults hash `(seed, kind, event)` through SplitMix64, so
//! a replayed trace faults at identical events. Nothing here consults a
//! clock or an RNG stream shared with decoding.

use std::fmt;
use std::sync::{Arc, Mutex};

/// Which failure mode an injected fault models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// An executable run fails (device-side compute error).
    Exec,
    /// A device→host transfer fails after the run (downlink error).
    Transfer,
    /// An allocation fails on chain seed / checkout (device OOM).
    Alloc,
    /// A fused k-step run's committed-count audit diverges: the chain is
    /// poisoned at the current fused depth.
    FusedDivergence,
}

impl FaultKind {
    fn index(self) -> usize {
        match self {
            FaultKind::Exec => 0,
            FaultKind::Transfer => 1,
            FaultKind::Alloc => 2,
            FaultKind::FusedDivergence => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Exec => "exec",
            FaultKind::Transfer => "transfer",
            FaultKind::Alloc => "alloc",
            FaultKind::FusedDivergence => "diverge",
        }
    }
}

/// A typed injected fault, carried through `anyhow` chains so the
/// router's recovery loop can [`classify`] a tick error without string
/// matching.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultError {
    pub kind: FaultKind,
    /// 1-based per-kind event ordinal at which the fault fired.
    pub event: u64,
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected {} fault at event {}", self.kind.name(), self.event)
    }
}

impl std::error::Error for FaultError {}

/// Marker for audit failures that mean the retained device chain can no
/// longer be trusted (e.g. a fused run committed a different number of
/// tokens than the host replay expected). Distinct from a transient
/// fault: retrying at the same fused depth would re-poison the chain,
/// so the recovery loop demotes `k` first.
#[derive(Debug, Clone)]
pub struct PoisonedChain(pub String);

impl fmt::Display for PoisonedChain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "poisoned chain: {}", self.0)
    }
}

impl std::error::Error for PoisonedChain {}

/// The recovery loop's error taxonomy (see the module doc).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TickErrorClass {
    /// Invalidate the affected chain, re-ground, retry within budget.
    Transient,
    /// As transient, but demote the fused depth before retrying.
    Poisoned,
    /// Retrying cannot help; fail the affected sequences immediately.
    Misconfig,
}

/// Classify a tick error by walking its cause chain for the typed
/// markers. Anything without a marker is a misconfiguration — the
/// conservative default, so a genuine bug never spins the retry loop.
pub fn classify(e: &anyhow::Error) -> TickErrorClass {
    for cause in e.chain() {
        if let Some(f) = cause.downcast_ref::<FaultError>() {
            return match f.kind {
                FaultKind::FusedDivergence => TickErrorClass::Poisoned,
                _ => TickErrorClass::Transient,
            };
        }
        if cause.downcast_ref::<PoisonedChain>().is_some() {
            return TickErrorClass::Poisoned;
        }
    }
    TickErrorClass::Misconfig
}

/// A deterministic fault schedule. Per-kind lists hold 1-based event
/// ordinals that fault; `rate`/`seed` add a seeded Bernoulli draw per
/// event on top (0.0 disables it).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub exec_at: Vec<u64>,
    pub transfer_at: Vec<u64>,
    pub alloc_at: Vec<u64>,
    pub diverge_at: Vec<u64>,
    pub rate: f64,
    pub seed: u64,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.exec_at.is_empty()
            && self.transfer_at.is_empty()
            && self.alloc_at.is_empty()
            && self.diverge_at.is_empty()
            && self.rate <= 0.0
    }

    fn at(&self, kind: FaultKind) -> &[u64] {
        match kind {
            FaultKind::Exec => &self.exec_at,
            FaultKind::Transfer => &self.transfer_at,
            FaultKind::Alloc => &self.alloc_at,
            FaultKind::FusedDivergence => &self.diverge_at,
        }
    }

    /// Parse the CLI grammar: comma-separated `kind@ordinal` tokens
    /// (kinds: `exec`, `transfer`, `alloc`, `diverge`; repeatable) plus
    /// optional `rate=F` and `seed=N`. Empty input is the empty plan.
    ///
    /// Example: `exec@3,exec@7,alloc@1,rate=0.02,seed=42`
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            if let Some((kind, ord)) = tok.split_once('@') {
                let n: u64 = ord
                    .parse()
                    .map_err(|_| format!("bad fault ordinal in '{tok}'"))?;
                if n == 0 {
                    return Err(format!("fault ordinals are 1-based: '{tok}'"));
                }
                match kind {
                    "exec" => plan.exec_at.push(n),
                    "transfer" => plan.transfer_at.push(n),
                    "alloc" => plan.alloc_at.push(n),
                    "diverge" => plan.diverge_at.push(n),
                    _ => return Err(format!("unknown fault kind '{kind}' in '{tok}'")),
                }
            } else if let Some((key, val)) = tok.split_once('=') {
                match key {
                    "rate" => {
                        plan.rate = val
                            .parse()
                            .map_err(|_| format!("bad fault rate '{val}'"))?;
                        if !(0.0..=1.0).contains(&plan.rate) {
                            return Err(format!("fault rate out of [0,1]: '{val}'"));
                        }
                    }
                    "seed" => {
                        plan.seed = val
                            .parse()
                            .map_err(|_| format!("bad fault seed '{val}'"))?;
                    }
                    _ => return Err(format!("unknown fault-plan key '{key}'")),
                }
            } else {
                return Err(format!("bad fault-plan token '{tok}'"));
            }
        }
        Ok(plan)
    }

    /// Convert to the vendored xla stub's self-contained schedule so the
    /// same exec/alloc ordinals fault at the same modeled events on the
    /// device layer (the stub cannot depend on this crate).
    pub fn stub_schedule(&self) -> xla::FaultSchedule {
        xla::FaultSchedule {
            exec_at: self.exec_at.clone(),
            alloc_at: self.alloc_at.clone(),
        }
    }
}

/// Cumulative fault/recovery ledger, mirrored into `/metrics` each
/// scheduler tick — and, like [`crate::runtime::resident::TransferStats`],
/// kept count-exact between the sim and PJRT planners because both
/// drive the same injector API from the same sites.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// faults the plan actually fired
    pub faults_injected: u64,
    /// scheduler ticks re-run after a transient fault
    pub ticks_retried: u64,
    /// resident chains invalidated + re-grounded by the recovery loop
    pub chains_regrounded: u64,
    /// fused-depth demotions (k → k/2) after a poisoned-chain error
    pub fused_k_demotions: u64,
    /// Device-apply → Host quarantines after repeated device faults
    pub host_demotions: u64,
    /// sequences failed after the retry budget was exhausted (or on a
    /// misconfiguration)
    pub requests_failed: u64,
}

impl FaultStats {
    /// Field-wise accumulate of another ledger (or a ledger delta).
    pub fn merge(&mut self, d: &FaultStats) {
        self.faults_injected += d.faults_injected;
        self.ticks_retried += d.ticks_retried;
        self.chains_regrounded += d.chains_regrounded;
        self.fused_k_demotions += d.fused_k_demotions;
        self.host_demotions += d.host_demotions;
        self.requests_failed += d.requests_failed;
    }

    /// Field-wise delta against an earlier snapshot of the same ledger.
    pub fn since(&self, earlier: &FaultStats) -> FaultStats {
        FaultStats {
            faults_injected: self.faults_injected.saturating_sub(earlier.faults_injected),
            ticks_retried: self.ticks_retried.saturating_sub(earlier.ticks_retried),
            chains_regrounded: self
                .chains_regrounded
                .saturating_sub(earlier.chains_regrounded),
            fused_k_demotions: self
                .fused_k_demotions
                .saturating_sub(earlier.fused_k_demotions),
            host_demotions: self.host_demotions.saturating_sub(earlier.host_demotions),
            requests_failed: self.requests_failed.saturating_sub(earlier.requests_failed),
        }
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

struct InjectorInner {
    plan: FaultPlan,
    /// per-kind events seen, indexed by [`FaultKind::index`]
    seen: [u64; 4],
    stats: FaultStats,
}

/// The shared injector a backend consults at each injection site. Also
/// the home of the [`FaultStats`] ledger: the backend credits
/// `faults_injected`, the router's recovery loop credits the rest.
pub struct FaultInjector {
    inner: Mutex<InjectorInner>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            inner: Mutex::new(InjectorInner {
                plan,
                seen: [0; 4],
                stats: FaultStats::default(),
            }),
        })
    }

    /// Count one `kind` event and fault it if the plan says so.
    pub fn check(&self, kind: FaultKind) -> Result<(), FaultError> {
        let mut g = self.inner.lock().unwrap();
        let i = kind.index();
        g.seen[i] += 1;
        let n = g.seen[i];
        let ordinal_hit = g.plan.at(kind).contains(&n);
        let rate_hit = g.plan.rate > 0.0 && {
            let h = splitmix64(
                g.plan.seed ^ (i as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F) ^ n,
            );
            (h as f64 / u64::MAX as f64) < g.plan.rate
        };
        if ordinal_hit || rate_hit {
            g.stats.faults_injected += 1;
            return Err(FaultError { kind, event: n });
        }
        Ok(())
    }

    /// Whether any fault can ever fire (cheap gate for hot paths).
    pub fn armed(&self) -> bool {
        !self.inner.lock().unwrap().plan.is_empty()
    }

    pub fn note_tick_retried(&self) {
        self.inner.lock().unwrap().stats.ticks_retried += 1;
    }

    pub fn note_chain_regrounded(&self) {
        self.inner.lock().unwrap().stats.chains_regrounded += 1;
    }

    pub fn note_fused_k_demotion(&self) {
        self.inner.lock().unwrap().stats.fused_k_demotions += 1;
    }

    pub fn note_host_demotion(&self) {
        self.inner.lock().unwrap().stats.host_demotions += 1;
    }

    pub fn note_requests_failed(&self, n: u64) {
        self.inner.lock().unwrap().stats.requests_failed += n;
    }

    pub fn stats(&self) -> FaultStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_grammar() {
        let p = FaultPlan::parse("exec@3,exec@7,transfer@1,alloc@2,diverge@5,rate=0.25,seed=42")
            .unwrap();
        assert_eq!(p.exec_at, vec![3, 7]);
        assert_eq!(p.transfer_at, vec![1]);
        assert_eq!(p.alloc_at, vec![2]);
        assert_eq!(p.diverge_at, vec![5]);
        assert!((p.rate - 0.25).abs() < 1e-12);
        assert_eq!(p.seed, 42);
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("bogus@1").is_err());
        assert!(FaultPlan::parse("exec@0").is_err());
        assert!(FaultPlan::parse("rate=1.5").is_err());
        assert!(FaultPlan::parse("exec-3").is_err());
    }

    #[test]
    fn ordinal_faults_fire_deterministically() {
        let inj = FaultInjector::new(FaultPlan::parse("exec@2,alloc@1").unwrap());
        assert!(inj.check(FaultKind::Exec).is_ok(), "event 1 clean");
        let e = inj.check(FaultKind::Exec).unwrap_err();
        assert_eq!(e.kind, FaultKind::Exec);
        assert_eq!(e.event, 2);
        assert!(inj.check(FaultKind::Exec).is_ok(), "event 3 clean");
        // kinds count independently
        assert!(inj.check(FaultKind::Transfer).is_ok());
        assert!(inj.check(FaultKind::Alloc).is_err());
        assert_eq!(inj.stats().faults_injected, 2);
    }

    #[test]
    fn rate_faults_are_seed_deterministic() {
        let plan = FaultPlan::parse("rate=0.3,seed=7").unwrap();
        let run = |plan: FaultPlan| -> Vec<bool> {
            let inj = FaultInjector::new(plan);
            (0..64).map(|_| inj.check(FaultKind::Exec).is_err()).collect()
        };
        let a = run(plan.clone());
        let b = run(plan);
        assert_eq!(a, b, "same seed, same fault pattern");
        let n = a.iter().filter(|&&f| f).count();
        assert!(n > 0 && n < 64, "rate 0.3 faults some but not all: {n}");
    }

    #[test]
    fn classify_walks_the_cause_chain() {
        let t = anyhow::Error::from(FaultError { kind: FaultKind::Exec, event: 1 })
            .context("run_step failed");
        assert_eq!(classify(&t), TickErrorClass::Transient);
        let p = anyhow::Error::from(FaultError {
            kind: FaultKind::FusedDivergence,
            event: 1,
        });
        assert_eq!(classify(&p), TickErrorClass::Poisoned);
        let p2 = anyhow::Error::from(PoisonedChain("audit".into()));
        assert_eq!(classify(&p2), TickErrorClass::Poisoned);
        let m = anyhow::anyhow!("unknown indicator q");
        assert_eq!(classify(&m), TickErrorClass::Misconfig);
    }

    #[test]
    fn stats_merge_and_since_are_fieldwise() {
        let mut a = FaultStats { faults_injected: 2, ticks_retried: 1, ..Default::default() };
        let snap = a;
        a.merge(&FaultStats { chains_regrounded: 3, requests_failed: 1, ..Default::default() });
        let d = a.since(&snap);
        assert_eq!(d.chains_regrounded, 3);
        assert_eq!(d.requests_failed, 1);
        assert_eq!(d.faults_injected, 0);
    }

    #[test]
    fn stub_schedule_carries_the_same_ordinals() {
        let p = FaultPlan::parse("exec@4,alloc@2,transfer@9").unwrap();
        let s = p.stub_schedule();
        assert_eq!(s.exec_at, vec![4]);
        assert_eq!(s.alloc_at, vec![2]);
    }
}
