//! Stub of the `xla` PJRT bindings used by `crate::runtime`.
//!
//! This container image ships no PJRT shared library, so the real
//! bindings cannot link. The stub exposes the exact API surface the
//! runtime uses and fails fast at [`PjRtClient::cpu`] with a clear
//! message; everything downstream (router, scheduler, HTTP front end)
//! degrades gracefully, and the simulation backend plus all host-side
//! tests run without it. Point the `xla` path dependency in the root
//! `Cargo.toml` at the real bindings to enable PJRT execution — no
//! source changes are needed.
//!
//! One piece of behavior IS modeled rather than stubbed: device-buffer
//! lifetime under input-output aliasing (donation). The runtime declares
//! alias pairs at compile time
//! ([`PjRtClient::compile_with_io_aliases`], from the manifest's
//! retained-chaining signatures) so the device-apply cache update writes
//! its input buffer in place. [`StubDevice`] reproduces exactly the
//! allocation consequences of that contract — an aliased output reuses
//! its donated input's allocation, an unaliased one materializes a fresh
//! buffer while the input is still live — behind a live/peak allocation
//! ledger, so tests can pin the invariant donation buys ("at most one
//! live copy per chained tensor, even transiently during execution")
//! without any PJRT library present.

use std::cell::Cell;
use std::fmt;
use std::path::Path;
use std::rc::Rc;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (stub xla crate; link the real \
         xla bindings to enable execution)"
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Bf16,
    F32,
    S32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    Bf16,
    S32,
}

pub struct PjRtDevice;

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile"))
    }

    /// Compile with an input-output alias (donation) config: each
    /// `(output_index, parameter_number)` pair tells the runtime that the
    /// output may reuse — and therefore invalidates — the argument
    /// buffer passed at that parameter position. The real bindings lower
    /// this to `HloInputOutputAliasConfig` before `client.compile`; the
    /// stub fails like every other compile entry point.
    pub fn compile_with_io_aliases(
        &self,
        _comp: &XlaComputation,
        _aliases: &[(usize, usize)],
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile_with_io_aliases"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("buffer_from_host_literal"))
    }
}

/// A device buffer. Real-path constructors all fail in the stub, so a
/// live `PjRtBuffer` only ever exists with a [`StubDevice`] allocation
/// behind it (the donation-model tests).
pub struct PjRtBuffer {
    alloc: Option<Rc<Allocation>>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("to_literal_sync"))
    }

    /// Size of the backing stub allocation in bytes (0 when the buffer
    /// has no stub allocation).
    pub fn stub_bytes(&self) -> usize {
        self.alloc.as_ref().map(|a| a.bytes).unwrap_or(0)
    }

    /// Whether this buffer shares its device allocation with `other` —
    /// true exactly when one was produced by donating the other (or a
    /// chain of donations) under an input-output alias config.
    pub fn shares_allocation(&self, other: &PjRtBuffer) -> bool {
        match (&self.alloc, &other.alloc) {
            (Some(a), Some(b)) => Rc::ptr_eq(a, b),
            _ => false,
        }
    }
}

// --------------------------------------------------------------------------
// Stubbed device-memory model: allocation ledger + donation semantics
// --------------------------------------------------------------------------

struct LedgerCells {
    live: Cell<usize>,
    peak: Cell<usize>,
}

/// One device allocation; dropping the last buffer that references it
/// releases it from the ledger.
struct Allocation {
    ledger: Rc<LedgerCells>,
    bytes: usize,
}

impl Allocation {
    fn fresh(ledger: &Rc<LedgerCells>, bytes: usize) -> Rc<Allocation> {
        let live = ledger.live.get() + 1;
        ledger.live.set(live);
        if live > ledger.peak.get() {
            ledger.peak.set(live);
        }
        Rc::new(Allocation { ledger: ledger.clone(), bytes })
    }
}

impl Drop for Allocation {
    fn drop(&mut self) {
        self.ledger.live.set(self.ledger.live.get() - 1);
    }
}

/// Allocation-accurate model of a PJRT device for donation tests: counts
/// live allocations (and the peak), hands out buffers, and builds
/// executables whose outputs either materialize fresh allocations or —
/// for pairs named in an input-output alias config — reuse the donated
/// input's allocation in place, exactly as a donation-capable PJRT build
/// does. Single-threaded by construction (`Rc`/`Cell`), matching the
/// non-`Send` threading model of the real wrapper types.
pub struct StubDevice {
    ledger: Rc<LedgerCells>,
}

impl StubDevice {
    pub fn new() -> StubDevice {
        StubDevice {
            ledger: Rc::new(LedgerCells { live: Cell::new(0), peak: Cell::new(0) }),
        }
    }

    /// Currently live device allocations.
    pub fn live_buffers(&self) -> usize {
        self.ledger.live.get()
    }

    /// High-water mark of live allocations since construction (or the
    /// last [`StubDevice::reset_peak`]).
    pub fn peak_live_buffers(&self) -> usize {
        self.ledger.peak.get()
    }

    /// Restart peak tracking from the current live count.
    pub fn reset_peak(&self) {
        self.ledger.peak.set(self.ledger.live.get());
    }

    /// Allocate a device buffer of `bytes` (a seed upload).
    pub fn alloc(&self, bytes: usize) -> PjRtBuffer {
        PjRtBuffer { alloc: Some(Allocation::fresh(&self.ledger, bytes)) }
    }

    /// Build a stub executable producing one output per `out_bytes`
    /// entry. `aliases` holds `(output_index, parameter_number)` pairs in
    /// the same format the runtime derives from the manifest
    /// ([`PjRtClient::compile_with_io_aliases`]): at execution, an
    /// aliased output donates the named argument's allocation instead of
    /// materializing a second copy.
    pub fn executable(&self, out_bytes: &[usize], aliases: &[(usize, usize)]) -> StubExecutable {
        StubExecutable {
            ledger: self.ledger.clone(),
            out_bytes: out_bytes.to_vec(),
            aliases: aliases.to_vec(),
        }
    }
}

impl Default for StubDevice {
    fn default() -> Self {
        StubDevice::new()
    }
}

/// A compiled executable under the stub device model: execution
/// allocates fresh output buffers, except for aliased outputs, which
/// reuse (donate) their input's allocation — the device-side effect of
/// `HloInputOutputAliasConfig`.
pub struct StubExecutable {
    ledger: Rc<LedgerCells>,
    out_bytes: Vec<usize>,
    aliases: Vec<(usize, usize)>,
}

impl StubExecutable {
    /// Run once over `args`. Aliased outputs share their donated input's
    /// allocation (the caller must treat that input as invalidated, as
    /// under real donation); every other output is a fresh allocation
    /// held live alongside the inputs for the duration of the call.
    pub fn execute(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>, Error> {
        for &(out, param) in &self.aliases {
            if out >= self.out_bytes.len() {
                return Err(Error(format!(
                    "alias names output {out}, executable has {}",
                    self.out_bytes.len()
                )));
            }
            if param >= args.len() {
                return Err(Error(format!(
                    "alias names parameter {param}, called with {} args",
                    args.len()
                )));
            }
            if self.aliases.iter().filter(|(_, p)| *p == param).count() > 1 {
                return Err(Error(format!(
                    "parameter {param} donated to more than one output"
                )));
            }
        }
        let mut out = Vec::with_capacity(self.out_bytes.len());
        for (i, &bytes) in self.out_bytes.iter().enumerate() {
            let donated = self.aliases.iter().find(|(o, _)| *o == i).map(|&(_, p)| p);
            let alloc = match donated {
                Some(p) => match &args[p].alloc {
                    Some(a) => a.clone(),
                    None => return Err(Error(format!(
                        "parameter {p} has no stub allocation to donate"
                    ))),
                },
                None => Allocation::fresh(&self.ledger, bytes),
            };
            out.push(PjRtBuffer { alloc: Some(alloc) });
        }
        Ok(out)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_b"))
    }

    /// Untupled execution: the real bindings run with
    /// `ExecuteOptions.untuple_result = true`, so the inner vector holds
    /// one `PjRtBuffer` per root-tuple element. This is what lets the
    /// runtime retain individual outputs on device (device-apply cache
    /// chaining) instead of downloading one fused result tuple.
    pub fn execute_untupled<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_untupled"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Err(unavailable("array_shape"))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("to_vec"))
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, Error> {
        Err(unavailable("convert"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("to_tuple"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("PJRT backend unavailable"));
    }

    #[test]
    fn donated_output_reuses_the_allocation() {
        let dev = StubDevice::new();
        let seed = dev.alloc(1024);
        let exe = dev.executable(&[1024], &[(0, 0)]);
        let out = exe.execute(&[&seed]).unwrap();
        assert_eq!(dev.live_buffers(), 1, "no second copy, even transiently");
        assert_eq!(dev.peak_live_buffers(), 1);
        assert!(out[0].shares_allocation(&seed));
        drop(seed);
        assert_eq!(dev.live_buffers(), 1, "chained handle keeps it alive");
    }

    #[test]
    fn unaliased_output_holds_two_copies_transiently() {
        let dev = StubDevice::new();
        let seed = dev.alloc(1024);
        let exe = dev.executable(&[1024], &[]);
        let out = exe.execute(&[&seed]).unwrap();
        assert_eq!(dev.live_buffers(), 2, "replace-and-drop's transient");
        assert!(!out[0].shares_allocation(&seed));
        drop(seed);
        assert_eq!(dev.live_buffers(), 1);
    }

    #[test]
    fn invalid_alias_configs_error() {
        let dev = StubDevice::new();
        let a = dev.alloc(8);
        assert!(dev.executable(&[8], &[(1, 0)]).execute(&[&a]).is_err());
        assert!(dev.executable(&[8], &[(0, 3)]).execute(&[&a]).is_err());
        assert!(dev
            .executable(&[8, 8], &[(0, 0), (1, 0)])
            .execute(&[&a])
            .is_err());
    }
}
