//! Golden-manifest parse contract for the device-apply executable kinds:
//! a checked-in fixture (mirroring what `python/compile/aot.py` emits)
//! pins the `prefill_apply` / `step_apply` / `step_apply_k` kinds (the
//! last with its required `k` unroll-depth field), their
//! `retained_outputs` chaining signatures with the `alias` (donation)
//! flags, and the gen-region `logits_gen` output signature, and the
//! error paths must name the offending executable and field instead of
//! failing generically. The live-context family is pinned too:
//! `generation.ctx_tiers` (validated ascending, in range, ending at the
//! full context), the block-sliced `prefill_apply_blk*` variant with
//! its `blk_start` input and `[B, block, V]` `logits_blk` downlink, and
//! a `_ctx*` tier variant whose chained tensors carry the reduced
//! live-context shapes, resolvable through `ArchSpec::tier_exe_name`.

use std::path::{Path, PathBuf};

use esdllm::manifest::{ExeKind, Manifest, RetainedSig};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/golden_artifacts")
}

#[test]
fn golden_manifest_parses_device_apply_kinds() {
    let m = Manifest::load(&golden_dir()).expect("golden manifest parses");
    let a = m.arch("llada-nano").unwrap();

    let pf = a.exe("prefill_apply_b8").unwrap();
    assert_eq!(pf.kind, ExeKind::PrefillApply);
    assert_eq!(pf.batch, 8);
    // non-parameter inputs only (the one param is stripped)
    assert_eq!(pf.inputs.len(), 5);
    assert_eq!(pf.inputs[0].name, "tokens");
    assert_eq!(pf.inputs[4].name, "refresh");
    assert_eq!(
        pf.retained,
        vec![
            RetainedSig { output: "kv".into(), input: "kv".into(), donate: true },
            RetainedSig { output: "ind".into(), input: "ind".into(), donate: true },
            RetainedSig { output: "conf".into(), input: "conf".into(), donate: true },
        ]
    );
    // retain flags in output order: logits download, the cache chain
    // stays on device
    assert_eq!(pf.retain_flags(), vec![false, true, true, true]);
    assert_eq!(pf.output_index("kv").unwrap(), 1);
    assert_eq!(pf.output_index("conf").unwrap(), 3);
    assert!(pf.output_index("nope").is_err());
    // gen-region logit output: [B, gen, V], not [B, ctx, V] — and the
    // old full-context name is gone, so a stale runtime fails loudly
    let lg = pf.output_index("logits_gen").unwrap();
    assert_eq!(lg, 0);
    assert_eq!(pf.outputs[lg].shape, vec![8, 32, 64]);
    assert!(pf.output_index("logits").is_err());
    // input-output alias (donation) pairs in the executable's true
    // argument order: 1 model param, then tokens/kv/ind/conf/refresh
    assert_eq!(pf.alias_pairs(1), vec![(1, 2), (2, 3), (3, 4)]);

    let st = a.exe("es_apply_blk8_b8").unwrap();
    assert_eq!(st.kind, ExeKind::StepApply);
    assert_eq!(st.block, Some(8));
    assert_eq!(st.skip_layers, vec![1, 2]);
    assert_eq!(st.k, None, "single-step kinds carry no unroll depth");
    assert_eq!(st.retain_flags(), vec![false, false, true, true, true]);
    // args: param, x_tok, block_start, kv, ind, conf, occ, alpha
    assert_eq!(st.alias_pairs(1), vec![(2, 4), (3, 5), (4, 6)]);

    // the fused k-step variant: same chain/donation contract as the
    // single-step exe, plus the unroll depth, a threshold input for the
    // in-graph unmask, and the per-slot committed-count downlink
    let fk = a.exe("es_applyk4_blk8_b8").unwrap();
    assert_eq!(fk.kind, ExeKind::StepApplyK);
    assert_eq!(fk.k, Some(4));
    assert_eq!(fk.block, Some(8));
    assert_eq!(fk.skip_layers, vec![1, 2]);
    assert_eq!(fk.inputs.last().unwrap().name, "threshold");
    assert_eq!(
        fk.retain_flags(),
        vec![false, false, true, true, true, false],
        "logits/pos/committed download, the cache chain stays on device"
    );
    // args: param, x_tok, block_start, kv, ind, conf, occ, alpha, threshold
    assert_eq!(fk.alias_pairs(1), vec![(2, 4), (3, 5), (4, 6)]);
    let cm = fk.output_index("committed").unwrap();
    assert_eq!(fk.outputs[cm].shape, vec![8], "per-slot committed count");

    // plain step executables carry no retained outputs and no aliases
    let dual = a.exe("dual_blk8_b8").unwrap();
    assert_eq!(dual.kind, ExeKind::Step);
    assert!(dual.retained.is_empty());
    assert_eq!(dual.retain_flags(), vec![false; 4]);
    assert!(dual.alias_pairs(1).is_empty());

    // the Host-fallback full forwards are gen-sliced too: `vanilla_b*`
    // (and `prefill_b*`) emit `logits_gen` [B, gen, V], and the old
    // full-context `logits` name is gone so a stale runtime fails
    // loudly at output lookup instead of mis-slicing rows
    let vanilla = a.exe("vanilla_b8").unwrap();
    assert_eq!(vanilla.kind, ExeKind::Prefill);
    let lg = vanilla.output_index("logits_gen").unwrap();
    assert_eq!(lg, 0);
    assert_eq!(vanilla.outputs[lg].shape, vec![8, 32, 64], "[B, gen, V]");
    assert!(vanilla.output_index("logits").is_err());
    assert!(vanilla.retained.is_empty(), "stateless: nothing chained");

    // and the cache-refreshing prefill keeps its output ORDER (logits
    // first, then kv / ind_h..ind_v / attn_mass — what
    // refresh_slots_from_prefill indexes positionally) with the logit
    // output gen-sliced: [B, gen, V], distinguishable from [B, ctx, V]
    // by its second dimension, which is the compat sniff the host merge
    // relies on
    let pf = a.exe("prefill_b8").unwrap();
    assert_eq!(pf.kind, ExeKind::Prefill);
    assert_eq!(pf.output_index("logits_gen").unwrap(), 0);
    assert_eq!(pf.outputs[0].shape, vec![8, 32, 64], "[B, gen, V] not ctx");
    assert_eq!(pf.output_index("kv").unwrap(), 1);
    assert_eq!(pf.output_index("attn_mass").unwrap(), 6);
    assert_eq!(pf.outputs.len(), 7);
    assert!(pf.output_index("logits").is_err());
}

#[test]
fn golden_manifest_parses_live_context_family() {
    let m = Manifest::load(&golden_dir()).expect("golden manifest parses");
    assert_eq!(m.generation.ctx_tiers, vec![56, 64, 72, 80]);
    let a = m.arch("llada-nano").unwrap();

    // the block-sliced grounding prefill: prefill_apply chaining plus a
    // per-slot [B] blk_start input and a [B, block, V] window downlink
    let blk = a.exe("prefill_apply_blk8_b8").unwrap();
    assert_eq!(blk.kind, ExeKind::PrefillApply);
    assert_eq!(blk.block, Some(8));
    assert_eq!(blk.inputs.last().unwrap().name, "blk_start");
    assert_eq!(blk.inputs.last().unwrap().shape, vec![8], "per-slot starts");
    let lb = blk.output_index("logits_blk").unwrap();
    assert_eq!(lb, 0);
    assert_eq!(blk.outputs[lb].shape, vec![8, 8, 64], "[B, block, V]");
    assert!(blk.output_index("logits_gen").is_err(), "window, not gen slice");
    // same chain/donation contract as the full-region prefill
    assert_eq!(blk.retain_flags(), vec![false, true, true, true]);
    assert_eq!(blk.alias_pairs(1), vec![(1, 2), (2, 3), (3, 4)]);

    // a context-tier variant: kv_len at the tier, gen_live < gen, and
    // every chained tensor at the reduced live-context shapes
    let t = a.exe("es_apply_blk8_b8_ctx64").unwrap();
    assert_eq!(t.kind, ExeKind::StepApply);
    assert_eq!(t.kv_len, 64);
    assert_eq!(t.gen_live, Some(16));
    let kv_in = t.inputs.iter().find(|i| i.name == "kv").unwrap();
    assert_eq!(kv_in.shape[4], 64, "chained KV covers live rows only");
    let conf_in = t.inputs.iter().find(|i| i.name == "conf").unwrap();
    assert_eq!(conf_in.shape, vec![8, 16], "[B, gen_live]");
    // the untiered sibling stays the full-context executable
    assert_eq!(a.exe("es_apply_blk8_b8").unwrap().gen_live, None);

    // tier-name resolution: live_ctx below the full context maps the
    // base name onto the _ctx* variant; at (or past) the full context
    // the base name IS the tier
    assert_eq!(a.tier_exe_name("es_apply_blk8_b8", 64), "es_apply_blk8_b8_ctx64");
    assert_eq!(a.tier_exe_name("es_apply_blk8_b8", 80), "es_apply_blk8_b8");
    assert!(a.exe(&a.tier_exe_name("es_apply_blk8_b8", 64)).is_ok());
}

#[test]
fn bad_ctx_tiers_error_states_the_constraint() {
    // not strictly ascending
    let err = load_patched(
        |src| src.replace("\"ctx_tiers\": [56, 64, 72, 80]",
                          "\"ctx_tiers\": [64, 56, 72, 80]"),
        "tiers-order",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("strictly"), "states the ordering rule: {msg}");
    assert!(msg.contains("ctx_tiers"), "names the field: {msg}");

    // not ending at the full compiled context
    let err = load_patched(
        |src| src.replace("\"ctx_tiers\": [56, 64, 72, 80]",
                          "\"ctx_tiers\": [56, 64, 72]"),
        "tiers-end",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("full"), "states the last-tier rule: {msg}");

    // a tier at or below the prompt region
    let err = load_patched(
        |src| src.replace("\"ctx_tiers\": [56, 64, 72, 80]",
                          "\"ctx_tiers\": [48, 64, 80]"),
        "tiers-lo",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("prompt_len"), "states the range rule: {msg}");
}

fn load_patched(patch: impl Fn(&str) -> String, subdir: &str) -> anyhow::Error {
    let src = std::fs::read_to_string(golden_dir().join("manifest.json")).unwrap();
    let dir = std::env::temp_dir().join(format!("esdllm-golden-{subdir}"));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), patch(&src)).unwrap();
    Manifest::load(&dir).expect_err("patched manifest must fail to parse")
}

#[test]
fn unknown_kind_error_names_the_executable() {
    let err = load_patched(
        |src| src.replace("\"kind\": \"step_apply\"", "\"kind\": \"warp_apply\""),
        "kind",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("es_apply_blk8_b8"), "names the exe: {msg}");
    assert!(msg.contains("warp_apply"), "names the bad value: {msg}");
    assert!(msg.contains("`kind`"), "names the field: {msg}");
    assert!(msg.contains("prefill_apply"), "lists the accepted kinds: {msg}");
}

#[test]
fn bad_fused_k_error_names_the_executable() {
    // an unroll depth of 1 is not a fused executable: the parse must
    // fail naming the exe and the bad value
    let err = load_patched(
        |src| src.replace("\"kind\": \"step_apply_k\", \"k\": 4",
                          "\"kind\": \"step_apply_k\", \"k\": 1"),
        "badk",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("es_applyk4_blk8_b8"), "names the exe: {msg}");
    assert!(msg.contains("`k`"), "names the field: {msg}");
    assert!(msg.contains("k >= 2"), "states the constraint: {msg}");

    // a step_apply_k entry without a `k` field at all (older emitter)
    // must also fail naming the exe
    let err = load_patched(
        |src| src.replace("\"kind\": \"step_apply_k\", \"k\": 4",
                          "\"kind\": \"step_apply_k\""),
        "missingk",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("es_applyk4_blk8_b8"), "names the exe: {msg}");
    assert!(msg.contains("requires a `k` field"), "{msg}");
}

#[test]
fn retained_output_must_reference_real_output_and_input() {
    let err = load_patched(
        |src| src.replacen("{\"output\": \"kv\", \"input\": \"kv\", \"alias\": true}",
                           "{\"output\": \"kvx\", \"input\": \"kv\", \"alias\": true}", 1),
        "retout",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("retained_outputs"), "{msg}");
    assert!(msg.contains("kvx"), "{msg}");

    let err = load_patched(
        |src| src.replacen("{\"output\": \"kv\", \"input\": \"kv\", \"alias\": true}",
                           "{\"output\": \"kv\", \"input\": \"kvx\", \"alias\": true}", 1),
        "retin",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("retained_outputs"), "{msg}");
    assert!(msg.contains("kvx"), "{msg}");
}

#[test]
fn alias_flag_must_be_boolean_and_error_names_the_exe() {
    // patch the first alias flag (prefill_apply_b8's kv signature) to a
    // string: the parse must fail naming the executable and the field
    let err = load_patched(
        |src| src.replacen("\"input\": \"kv\", \"alias\": true}",
                           "\"input\": \"kv\", \"alias\": \"yes\"}", 1),
        "aliastype",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("prefill_apply_b8"), "names the exe: {msg}");
    assert!(msg.contains("`alias`"), "names the field: {msg}");
    assert!(msg.contains("boolean"), "names the expected type: {msg}");
}

#[test]
fn alias_flag_defaults_to_no_donation() {
    // a manifest without alias flags (the pre-donation format) still
    // parses; the chain works, donation is just not declared
    let src = std::fs::read_to_string(golden_dir().join("manifest.json")).unwrap();
    let patched = src.replace(", \"alias\": true}", "}");
    let dir = std::env::temp_dir().join("esdllm-golden-noalias");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), patched).unwrap();
    let m = Manifest::load(&dir).expect("alias-less manifest parses");
    let pf = m.arch("llada-nano").unwrap().exe("prefill_apply_b8").unwrap();
    assert_eq!(pf.retained.len(), 3);
    assert!(pf.retained.iter().all(|r| !r.donate));
    assert!(pf.alias_pairs(1).is_empty(), "no donation declared");
    assert_eq!(pf.retain_flags(), vec![false, true, true, true], "chain intact");
}
