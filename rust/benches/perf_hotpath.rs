//! Hot-path microbenchmarks + §7 analyses:
//!   * resident-cache vs clone-and-reupload transfer comparison (bytes
//!     per step and host staging ms per step), driven through the real
//!     scheduler over the sim backend — runs on any machine, no PJRT —
//!     and emitted machine-readably as `BENCH_transfer.json`,
//!   * Host-apply vs Device-apply on the identical workload (what the
//!     in-graph scatter/merge + retained-output chain removes from the
//!     bus in both directions), artifact-free, emitted as
//!     `BENCH_device_apply.json`,
//!   * full-context vs gen-region logit download per tick (the
//!     `logits_gen` slice + selected step rows vs a `[B, ctx, V]`-every-
//!     run downlink), artifact-free with a ≥60% reduction acceptance
//!     gate, emitted as `BENCH_logit_slice.json`,
//!   * the fused k-step dispatch sweep (k ∈ {1, 2, 4, 8} on the same
//!     workload; identical tokens, fewer device dispatches), artifact-
//!     free with a ≥2× dispatch-reduction gate at k = 4, emitted as
//!     `BENCH_kstep.json`,
//!   * per-executable latency (prefill / dual / es, b1 / b8) with the
//!     upload/execute/download breakdown from runtime counters (needs
//!     compiled artifacts; skipped gracefully without them),
//!   * the paper's §7 memory-overhead table analog (cache bytes/seq),
//!   * the §7 speedup-vs-FLOPs gap: measured speedup vs the analytic
//!     FLOPs ratio, explained by the per-iteration byte traffic that
//!     early-skipping does NOT reduce — traffic the resident-cache layer
//!     now keeps on the device.

use std::time::Instant;

use esdllm::bench::{bench, bench_n, Table};
use esdllm::cache::{GroupCaches, RefreshPolicy};
use esdllm::engine::Method;
use esdllm::flops;
use esdllm::manifest::{Dims, ExeKind};
use esdllm::runtime::resident::{ApplyMode, TransferStats};
use esdllm::runtime::tensor::HostTensor;
use esdllm::runtime::Runtime;
use esdllm::sampler::SamplerCfg;
use esdllm::scheduler::sim::{SimBackend, SimCfg};
use esdllm::scheduler::{GroupScheduler, SchedCfg, SeqInput, SeqParams};

/// The nano-arch geometry (manifest.json) at batch 8: big enough that
/// the KV tensor dominates per-step traffic, as on the real artifacts.
fn bench_dims() -> Dims {
    Dims {
        vocab: 64, d_model: 64, n_layers: 8, n_heads: 4, n_kv_heads: 4,
        d_ff: 256, head_dim: 16, prompt_len: 48, gen_len: 32, ctx: 80,
    }
}

/// Resident-cache vs clone-and-reupload: drive the slot scheduler over
/// the sim backend and read the transfer ledger, plus microbenchmark the
/// host-side staging cost (full-tensor clone vs borrowed view).
fn transfer_section() -> anyhow::Result<()> {
    let batch = 8;
    let d = bench_dims();
    let sim_cfg = SimCfg { dims: d, ..SimCfg::default() };
    let backend = SimBackend::new(sim_cfg);
    let cfg = SchedCfg {
        method: Method::EsDllm,
        block: 8,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
        sampler: SamplerCfg::llada(),
        seed: 0,
        k: 1,
        hysteresis: None,
    };
    let mut sched = GroupScheduler::new(Box::new(backend), batch, cfg)?;
    let t0 = Instant::now();
    for i in 0..batch as u64 {
        sched.admit(SeqInput {
            id: i,
            // mixed lengths so blocks diverge like real traffic
            prompt: ["sort(9,8,7)=789", "1+2", "a|b", "0-1", "9*8", "x&y", "7*7", "3,4"]
                [i as usize % 8]
                .to_string(),
            params: SeqParams::default(),
            submitted: t0,
        })?;
    }
    let mut guard = 0;
    while sched.active() > 0 {
        sched.tick()?;
        guard += 1;
        assert!(guard < 10_000, "scheduler failed to drain");
    }
    let stats = sched.transfer_stats();
    let runs = (sched.n_prefill + sched.n_dual + sched.n_es).max(1) as u64;
    // only block steps uploaded KV in the clone-and-reupload design;
    // prefills shipped tokens only
    let step_runs = (sched.n_dual + sched.n_es).max(1) as u64;
    let resident_per_step = stats.upload_bytes / runs;
    let baseline_per_step = (stats.upload_bytes + stats.upload_bytes_saved) / runs;

    // host staging cost: the old path cloned the full KV into a fresh
    // HostTensor every step; the resident path borrows a view
    let caches = GroupCaches::new(&d, batch);
    let iters = 200;
    let clone_stats = bench(3, iters, || {
        let t = caches.kv_tensor();
        std::hint::black_box(&t);
    });
    let view_stats = bench(3, iters, || {
        let v = caches.kv_view();
        std::hint::black_box(&v);
    });

    let mut table = Table::new(
        "perf_hotpath: resident caches vs clone-and-reupload (sim, b8, ES)",
        &["mode", "bytes/step up", "KV bytes total", "full KV uploads", "staging ms/step"],
    );
    table.row(&[
        "clone-and-reupload".to_string(),
        format!("{baseline_per_step}"),
        format!("{}", (caches.kv_bytes() as u64) * step_runs),
        format!("{step_runs}"),
        format!("{:.4}", clone_stats.mean_s * 1e3),
    ]);
    table.row(&[
        "resident (dirty-delta)".to_string(),
        format!("{resident_per_step}"),
        format!("{}", stats.kv_upload_bytes),
        format!("{}", stats.full_kv_uploads),
        format!("{:.4}", view_stats.mean_s * 1e3),
    ]);
    table.print();
    table.write_csv("artifacts/results/perf_transfer.csv")?;
    println!(
        "resident caches ship {resident_per_step} B/step vs {baseline_per_step} B/step \
         clone-and-reupload ({:.1}x less traffic); {} executable runs, {} full-KV \
         upload(s) total (the residency seed)",
        baseline_per_step as f64 / resident_per_step.max(1) as f64,
        runs,
        stats.full_kv_uploads,
    );

    std::fs::create_dir_all("artifacts/results")?;
    let json = format!(
        "{{\n  \"bench\": \"perf_hotpath_transfer\",\n  \"batch\": {batch},\n  \
         \"block\": 8,\n  \"executable_runs\": {runs},\n  \
         \"resident_bytes_per_step\": {resident_per_step},\n  \
         \"baseline_bytes_per_step\": {baseline_per_step},\n  \
         \"upload_bytes\": {},\n  \"upload_bytes_saved\": {},\n  \
         \"kv_upload_bytes\": {},\n  \"ind_upload_bytes\": {},\n  \
         \"conf_upload_bytes\": {},\n  \"token_upload_bytes\": {},\n  \
         \"full_kv_uploads\": {},\n  \"resident_reuses\": {},\n  \
         \"clone_staging_ms_per_step\": {:.6},\n  \
         \"view_staging_ms_per_step\": {:.6}\n}}\n",
        stats.upload_bytes,
        stats.upload_bytes_saved,
        stats.kv_upload_bytes,
        stats.ind_upload_bytes,
        stats.conf_upload_bytes,
        stats.token_upload_bytes,
        stats.full_kv_uploads,
        stats.resident_reuses,
        clone_stats.mean_s * 1e3,
        view_stats.mean_s * 1e3,
    );
    std::fs::write("artifacts/results/BENCH_transfer.json", json)?;
    println!("wrote artifacts/results/BENCH_transfer.json");
    Ok(())
}

/// Drain one mixed-length workload through the slot scheduler over the
/// sim backend in the given apply mode; returns (ledger, executable
/// runs, scheduler ticks).
fn run_apply_mode(apply: ApplyMode) -> anyhow::Result<(TransferStats, u64, u64)> {
    let batch = 8;
    let d = bench_dims();
    let sim_cfg = SimCfg { dims: d, ..SimCfg::default() }.with_apply(apply);
    let cfg = SchedCfg {
        method: Method::EsDllm,
        block: 8,
        refresh: RefreshPolicy { prompt_period: 16, block_period: 2 },
        sampler: SamplerCfg::llada(),
        seed: 0,
        k: 1,
        hysteresis: None,
    };
    let mut sched = GroupScheduler::new(Box::new(SimBackend::new(sim_cfg)), batch, cfg)?;
    let t0 = Instant::now();
    for i in 0..batch as u64 {
        sched.admit(SeqInput {
            id: i,
            prompt: ["sort(9,8,7)=789", "1+2", "a|b", "0-1", "9*8", "x&y", "7*7", "3,4"]
                [i as usize % 8]
                .to_string(),
            params: SeqParams::default(),
            submitted: t0,
        })?;
    }
    let mut guard = 0;
    while sched.active() > 0 {
        sched.tick()?;
        guard += 1;
        assert!(guard < 10_000, "scheduler failed to drain");
    }
    let runs = (sched.n_prefill + sched.n_dual + sched.n_es).max(1) as u64;
    let ticks = sched.ticks.max(1) as u64;
    Ok((sched.transfer_stats(), runs, ticks))
}

/// Host-apply vs device-apply on the identical workload: what the
/// in-graph scatter/merge + retained-output chain removes from the bus
/// per step, in both directions. Artifact-free; emits
/// `BENCH_device_apply.json`. Returns the Device-mode (ledger, runs,
/// ticks) so the logit-slice section can reuse the same deterministic
/// drain instead of re-running it.
fn device_apply_section() -> anyhow::Result<(TransferStats, u64, u64)> {
    let (host, host_runs, _) = run_apply_mode(ApplyMode::Host)?;
    let (dev, dev_runs, dev_ticks) = run_apply_mode(ApplyMode::Device)?;

    let mut table = Table::new(
        "perf_hotpath: Host-apply vs Device-apply (sim, b8, ES)",
        &[
            "mode", "up B/step", "KV up B", "ind up B", "conf up B",
            "d2h avoided B", "chain reuses", "ingraph conf",
        ],
    );
    for (label, st, runs) in
        [("host-apply (fallback)", &host, host_runs), ("device-apply (chained)", &dev, dev_runs)]
    {
        table.row(&[
            label.to_string(),
            format!("{}", st.upload_bytes / runs),
            format!("{}", st.kv_upload_bytes),
            format!("{}", st.ind_upload_bytes),
            format!("{}", st.conf_upload_bytes),
            format!("{}", st.d2h_bytes_avoided),
            format!("{}", st.retained_out_reuses),
            format!("{}", st.ingraph_conf_steps),
        ]);
    }
    table.print();
    table.write_csv("artifacts/results/perf_device_apply.csv")?;
    println!(
        "device-apply ships {:.1}x less H2D than host-apply on the same workload \
         and avoids {} B of D2H cache downloads (host-apply avoids none)",
        host.upload_bytes as f64 / dev.upload_bytes.max(1) as f64,
        dev.d2h_bytes_avoided,
    );

    std::fs::create_dir_all("artifacts/results")?;
    let json = format!(
        "{{\n  \"bench\": \"perf_hotpath_device_apply\",\n  \"batch\": 8,\n  \
         \"block\": 8,\n  \
         \"host\": {{\n    \"executable_runs\": {},\n    \"upload_bytes\": {},\n    \
         \"kv_upload_bytes\": {},\n    \"ind_upload_bytes\": {},\n    \
         \"conf_upload_bytes\": {},\n    \"token_upload_bytes\": {},\n    \
         \"full_kv_uploads\": {},\n    \"d2h_bytes_avoided\": {}\n  }},\n  \
         \"device\": {{\n    \"executable_runs\": {},\n    \"upload_bytes\": {},\n    \
         \"kv_upload_bytes\": {},\n    \"ind_upload_bytes\": {},\n    \
         \"conf_upload_bytes\": {},\n    \"token_upload_bytes\": {},\n    \
         \"full_kv_uploads\": {},\n    \"d2h_bytes_avoided\": {},\n    \
         \"retained_out_reuses\": {},\n    \"ingraph_conf_steps\": {}\n  }}\n}}\n",
        host_runs,
        host.upload_bytes,
        host.kv_upload_bytes,
        host.ind_upload_bytes,
        host.conf_upload_bytes,
        host.token_upload_bytes,
        host.full_kv_uploads,
        host.d2h_bytes_avoided,
        dev_runs,
        dev.upload_bytes,
        dev.kv_upload_bytes,
        dev.ind_upload_bytes,
        dev.conf_upload_bytes,
        dev.token_upload_bytes,
        dev.full_kv_uploads,
        dev.d2h_bytes_avoided,
        dev.retained_out_reuses,
        dev.ingraph_conf_steps,
    );
    std::fs::write("artifacts/results/BENCH_device_apply.json", json)?;
    println!("wrote artifacts/results/BENCH_device_apply.json");
    Ok((dev, dev_runs, dev_ticks))
}

/// Full-context vs gen-region logit downlink on the identical
/// device-apply workload (the ledger from `device_apply_section`'s
/// Device-mode drain — the sim is deterministic, so re-running it would
/// only double the bench time): what slicing the `prefill_apply` logit
/// output to `[B, gen, V]` (and downloading only the selected
/// `[B, k, V]` step rows) removes from the per-tick D2H traffic, vs a
/// design that ships `[B, ctx, V]` every run. Artifact-free; emits
/// `BENCH_logit_slice.json`. Acceptance: ≥ 60% per-tick reduction at
/// the nano geometry (gen/ctx = 32/80 alone is a 60% cut on prefill
/// ticks; step ticks cut far deeper).
fn logit_slice_section(dev: &TransferStats, runs: u64, ticks: u64) -> anyhow::Result<()> {
    let shipped = dev.d2h_bytes_shipped;
    let baseline = dev.d2h_bytes_shipped + dev.d2h_bytes_saved;
    let shipped_per_tick = shipped as f64 / ticks as f64;
    let baseline_per_tick = baseline as f64 / ticks as f64;
    let reduction_pct = 100.0 * (1.0 - shipped as f64 / baseline.max(1) as f64);

    let mut table = Table::new(
        "perf_hotpath: full-context vs gen-region logit download (sim, b8, ES)",
        &["downlink", "bytes/tick down", "bytes total", "donated execs"],
    );
    table.row(&[
        "full-context [B, ctx, V]".to_string(),
        format!("{baseline_per_tick:.0}"),
        format!("{baseline}"),
        "0".to_string(),
    ]);
    table.row(&[
        "gen-region slice".to_string(),
        format!("{shipped_per_tick:.0}"),
        format!("{shipped}"),
        format!("{}", dev.donated_execs),
    ]);
    table.print();
    table.write_csv("artifacts/results/perf_logit_slice.csv")?;
    let ok = reduction_pct >= 60.0;
    println!(
        "gen-region logit outputs download {shipped_per_tick:.0} B/tick vs \
         {baseline_per_tick:.0} B/tick full-context ({reduction_pct:.1}% less \
         D2H) over {runs} executable runs / {ticks} ticks; acceptance \
         (>= 60% reduction at nano scale): {}",
        if ok { "PASS" } else { "FAIL" }
    );

    std::fs::create_dir_all("artifacts/results")?;
    let json = format!(
        "{{\n  \"bench\": \"perf_hotpath_logit_slice\",\n  \"batch\": 8,\n  \
         \"block\": 8,\n  \"executable_runs\": {runs},\n  \"ticks\": {ticks},\n  \
         \"full_context_bytes_per_tick\": {baseline_per_tick:.1},\n  \
         \"gen_region_bytes_per_tick\": {shipped_per_tick:.1},\n  \
         \"d2h_bytes_shipped\": {shipped},\n  \
         \"d2h_bytes_saved\": {},\n  \
         \"donated_execs\": {},\n  \
         \"reduction_pct\": {reduction_pct:.2},\n  \
         \"acceptance_min_reduction_pct\": 60.0,\n  \
         \"acceptance_pass\": {ok}\n}}\n",
        dev.d2h_bytes_saved, dev.donated_execs,
    );
    std::fs::write("artifacts/results/BENCH_logit_slice.json", json)?;
    println!("wrote artifacts/results/BENCH_logit_slice.json");
    if !ok {
        return Err(anyhow::anyhow!(
            "logit-slice acceptance failed: {reduction_pct:.1}% < 60% reduction"
        ));
    }
    Ok(())
}

/// One fused-depth run for the kstep sweep: drain the mixed workload at
/// fused depth `k` over the sim backend with a pure steady-state decode
/// cadence (one grounding prefill per block, every other iteration an
/// ES step — the loop the fused executables unroll). Returns
/// (dispatches, fused dispatches, decoded tokens, iterations, ticks).
fn run_fused_depth(k: usize) -> anyhow::Result<(u64, u64, u64, u64, u64)> {
    let batch = 8;
    let d = bench_dims();
    let sim_cfg = SimCfg { dims: d, ..SimCfg::default() };
    let cfg = SchedCfg {
        method: Method::EsDllm,
        block: 8,
        refresh: RefreshPolicy { prompt_period: 0, block_period: 0 },
        sampler: SamplerCfg::llada(),
        seed: 0,
        k,
        hysteresis: None,
    };
    let mut sched = GroupScheduler::new(Box::new(SimBackend::new(sim_cfg)), batch, cfg)?;
    let t0 = Instant::now();
    for i in 0..batch as u64 {
        sched.admit(SeqInput {
            id: i,
            prompt: ["sort(9,8,7)=789", "1+2", "a|b", "0-1", "9*8", "x&y", "7*7", "3,4"]
                [i as usize % 8]
                .to_string(),
            params: SeqParams::default(),
            submitted: t0,
        })?;
    }
    let (mut tokens, mut iterations) = (0u64, 0u64);
    let mut guard = 0;
    while sched.active() > 0 {
        for f in sched.tick()? {
            tokens += f.tokens as u64;
            iterations += f.iterations as u64;
        }
        guard += 1;
        assert!(guard < 10_000, "scheduler failed to drain");
    }
    let dispatches = (sched.n_prefill + sched.n_dual + sched.n_es) as u64;
    Ok((dispatches, sched.n_fused as u64, tokens, iterations, sched.ticks as u64))
}

/// Fused k-step dispatch sweep: the identical mixed workload decoded at
/// fused depths k ∈ {1, 2, 4, 8}. Every depth must decode the same
/// tokens over the same iteration count (the fused loop is
/// trajectory-exact); what changes is how many device dispatches (and
/// host round-trips) that trajectory costs. Artifact-free; emits
/// `BENCH_kstep.json`. Acceptance: k = 4 needs at most half the
/// dispatches of k = 1.
fn kstep_section() -> anyhow::Result<()> {
    let ks = [1usize, 2, 4, 8];
    let mut rows = Vec::new();
    for &k in &ks {
        rows.push((k, run_fused_depth(k)?));
    }
    let (_, (d1, _, tok1, iter1, _)) = rows[0];
    for &(k, (_, _, tokens, iterations, _)) in &rows[1..] {
        anyhow::ensure!(
            tokens == tok1 && iterations == iter1,
            "fused depth {k} diverged from k=1: {tokens}/{iterations} tokens/iters \
             vs {tok1}/{iter1} — the fused loop must be trajectory-exact"
        );
    }

    let mut table = Table::new(
        "perf_hotpath: fused k-step dispatch sweep (sim, b8, ES steady state)",
        &["k", "dispatches", "fused", "iters/dispatch", "tokens", "iterations", "ticks"],
    );
    for &(k, (dispatches, fused, tokens, iterations, ticks)) in &rows {
        table.row(&[
            format!("{k}"),
            format!("{dispatches}"),
            format!("{fused}"),
            format!("{:.2}", iterations as f64 / dispatches.max(1) as f64),
            format!("{tokens}"),
            format!("{iterations}"),
            format!("{ticks}"),
        ]);
    }
    table.print();
    table.write_csv("artifacts/results/perf_kstep.csv")?;

    let d4 = rows.iter().find(|r| r.0 == 4).unwrap().1 .0;
    let ratio = d1 as f64 / d4.max(1) as f64;
    let ok = ratio >= 2.0;
    println!(
        "fused k-step: k=4 decodes the same {tok1} tokens in {d4} dispatches vs \
         {d1} at k=1 ({ratio:.2}x fewer host round-trips); acceptance \
         (>= 2x dispatch reduction at k=4): {}",
        if ok { "PASS" } else { "FAIL" }
    );

    std::fs::create_dir_all("artifacts/results")?;
    let mut json = String::from("{\n  \"bench\": \"perf_hotpath_kstep\",\n  \"batch\": 8,\n  \"block\": 8,\n  \"depths\": [\n");
    for (i, &(k, (dispatches, fused, tokens, iterations, ticks))) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"k\": {k}, \"dispatches\": {dispatches}, \"fused_dispatches\": {fused}, \
             \"tokens\": {tokens}, \"iterations\": {iterations}, \"ticks\": {ticks}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str(&format!(
        "  ],\n  \"dispatch_reduction_k4\": {ratio:.3},\n  \
         \"acceptance_min_reduction\": 2.0,\n  \"acceptance_pass\": {ok}\n}}\n"
    ));
    std::fs::write("artifacts/results/BENCH_kstep.json", json)?;
    println!("wrote artifacts/results/BENCH_kstep.json");
    if !ok {
        return Err(anyhow::anyhow!(
            "kstep acceptance failed: k=4 used {d4} dispatches vs {d1} at k=1 \
             ({ratio:.2}x < 2x reduction)"
        ));
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    transfer_section()?;
    let (dev, dev_runs, dev_ticks) = device_apply_section()?;
    logit_slice_section(&dev, dev_runs, dev_ticks)?;
    kstep_section()?;

    let rt = match Runtime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!(
                "\nperf_hotpath: PJRT artifacts unavailable ({e:#}); \
                 per-executable latency section skipped."
            );
            return Ok(());
        }
    };
    let iters = bench_n(12);

    for arch_name in ["llada-nano", "dream-nano"] {
        let arch = rt.arch(arch_name)?.clone();
        let d = arch.dims;

        let mut table = Table::new(
            &format!("perf_hotpath: {arch_name} per-executable latency ({iters} iters)"),
            &["executable", "mean ms", "p90 ms", "exec ms", "transfer ms", "GFLOP", "GFLOP/s"],
        );

        for exe_name in [
            "vanilla_b8", "prefill_b8", "dual_blk8_b8", "es_blk8_b8",
            "dual_blk8_b1", "es_blk8_b1",
        ] {
            let exe = match arch.exe(exe_name) {
                Ok(e) => e.clone(),
                Err(_) => continue,
            };
            let batch = exe.batch;
            let caches = GroupCaches::new(&d, batch);
            let inputs: Vec<HostTensor> = match exe.kind {
                ExeKind::Prefill | ExeKind::Observe => vec![HostTensor::I32 {
                    shape: vec![batch, d.ctx],
                    data: vec![2; batch * d.ctx],
                }],
                ExeKind::Step => {
                    let layers: Vec<usize> = if exe.skip.is_empty() {
                        (0..d.n_layers).collect()
                    } else {
                        exe.skip_layers.clone()
                    };
                    vec![
                        HostTensor::I32 {
                            shape: vec![batch, exe.block.unwrap()],
                            data: vec![1; batch * exe.block.unwrap()],
                        },
                        HostTensor::scalar_i32(d.prompt_len as i32),
                        caches.kv_tensor(),
                        caches.gather_ind("h", &layers)?,
                        caches.conf_tensor(),
                        HostTensor::scalar_f32(0.5),
                    ]
                }
                // the device-apply variants chain retained outputs and
                // are measured through the scheduler, not standalone
                ExeKind::PrefillApply | ExeKind::StepApply | ExeKind::StepApplyK => continue,
            };
            // warm compile + measure
            rt.run(&arch, &exe, "instruct", &inputs)?;
            let _ = rt.take_stats();
            let stats = bench(1, iters, || {
                rt.run(&arch, &exe, "instruct", &inputs).unwrap();
            });
            let rstats = rt.take_stats();
            let per = rstats.executions.max(1) as f64;
            let gflop = match exe.kind {
                ExeKind::Step => flops::step_flops(
                    &d,
                    exe.block.unwrap(),
                    &exe.skip,
                    exe.kv_len,
                ) * batch as f64 / 8.0 / 1e9,
                _ => flops::prefill_flops(&d) * batch as f64 / 8.0 / 1e9,
            };
            table.row(&[
                exe_name.to_string(),
                format!("{:.2}", stats.mean_s * 1e3),
                format!("{:.2}", stats.p90_s * 1e3),
                format!("{:.2}", rstats.exec_seconds / per * 1e3),
                format!("{:.2}", rstats.transfer_seconds / per * 1e3),
                format!("{gflop:.3}"),
                format!("{:.2}", gflop / stats.mean_s),
            ]);
        }
        table.print();
        table.write_csv(&format!("artifacts/results/perf_{arch_name}.csv"))?;

        // §7 memory-overhead analog
        let mut mem = Table::new(
            &format!("§7 analog: cache state per sequence ({arch_name})"),
            &["component", "bytes/seq", "bytes/output-token"],
        );
        let kv = (d.n_layers * 2 * d.n_kv_heads * d.ctx * d.head_dim * 2) as u64;
        let ind = (2 * d.gen_len * d.d_model * 2) as u64; // default 2 skip layers
        let logits = (d.gen_len * d.vocab * 4) as u64;
        for (name, b) in [("KV cache (bf16)", kv), ("indicator cache", ind),
                          ("latest logits", logits),
                          ("total", kv + ind + logits)] {
            mem.row(&[
                name.to_string(),
                format!("{b}"),
                format!("{}", b / d.gen_len as u64),
            ]);
        }
        mem.print();

        // §7 speedup-vs-FLOPs gap
        let skip = [(1usize, 0.5f64), (2, 0.5)];
        let fl_ratio = flops::step_flops(&d, 8, &[], d.ctx)
            / flops::step_flops(&d, 8, &skip, d.ctx);
        let traffic = flops::step_traffic_bytes(&d, 8, 2, d.ctx);
        println!(
            "\n§7 analog ({arch_name}): ES step FLOPs reduction {fl_ratio:.2}x; the \
             clone-and-reupload design streamed {:.2} MB/iteration regardless — the \
             memory-bound gap the paper reports (2.5x FLOPs -> 1.2-1.85x measured). \
             The resident-cache layer removes the KV/indicator share of that traffic \
             (see the transfer table above / BENCH_transfer.json).",
            traffic as f64 / 1e6
        );
    }
    Ok(())
}
