//! Tables 7 & 8: main results on the Base checkpoints (early-training
//! snapshots standing in for LLaDA-8B-Base / Dream-7B-Base; see
//! DESIGN.md §1) — the method must stay effective on a less-converged
//! model with flatter confidence.

use esdllm::bench::{bench_archs, bench_n, Table};
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::runtime::Runtime;
use esdllm::workload::{paper_name, BENCHMARKS};

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let n = bench_n(16);

    for arch in bench_archs() {
        let table_no = if arch.starts_with("llada") { 7 } else { 8 };
        let mut table = Table::new(
            &format!("Table {table_no} analog: {arch}-Base, {n} samples/cell"),
            &["Benchmark", "Method", "TPS", "Speedup", "Score"],
        );
        for bench in BENCHMARKS {
            let mut base_tps = None;
            for method in [Method::Vanilla, Method::DualCache, Method::EsDllm] {
                let opts = EvalOpts {
                    checkpoint: Some("base".to_string()),
                    ..Default::default()
                };
                let r = evaluate(&rt, &arch, method, bench, n, &opts)?;
                let base = *base_tps.get_or_insert(r.tps);
                table.row(&[
                    paper_name(bench).to_string(),
                    method.label().to_string(),
                    format!("{:.2}", r.tps),
                    format!("{:.1}x", r.tps / base),
                    format!("{:.2}", r.score),
                ]);
            }
        }
        table.print();
        table.write_csv(&format!("artifacts/results/table{table_no}.csv"))?;
    }
    Ok(())
}
