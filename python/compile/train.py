"""Build-time pretraining of the nano diffusion-LM checkpoints.

LLaDA-style SFT objective: the prompt region is kept clean, each answer
token is masked i.i.d. with a ratio t ~ U(eps, 1) sampled per sequence,
and the cross-entropy on masked positions is weighted 1/t (the ELBO
weighting from Nie et al. 2025).

Two snapshots are written per architecture, mirroring the paper's
Instruct/Base pairs (Tables 1–2 vs 7–8):
  * ``base``     — an early, less-converged snapshot
  * ``instruct`` — the final checkpoint

Checkpoints are flat little-endian f32 records in the canonical parameter
order of `modelcfg.param_specs` (the Rust loader mmaps them by offset).
"""

import argparse
import os
import struct
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import tasks
from .modelcfg import ARCHS, ModelCfg, param_specs
from .model import Params, init_params, params_from_flat, params_to_flat, train_logits

BENCH_MIX = list(tasks.BENCHMARKS)


BLOCK_FOR_TRAIN = 8  # matches the default inference block


def make_batch(cfg: ModelCfg, rng: np.random.RandomState, batch):
    """Returns (tokens [B, ctx] with masks applied, targets [B, ctx],
    loss_w [B, ctx]).

    Two masking curricula, mixed 50/50:
      * uniform   — LLaDA's i.i.d. masking with ratio t ~ U (the standard
                    diffusion SFT objective; matches refresh passes where
                    arbitrary subsets are masked);
      * block     — the semi-autoregressive inference distribution: blocks
                    left of a pivot are clean, the pivot block is masked
                    with ratio t, everything right of it is fully masked.
                    This is exactly what block-wise decoding feeds the
                    model, which plain uniform masking under-trains.
    """
    toks = np.zeros((batch, cfg.ctx), np.int32)
    tgt = np.zeros((batch, cfg.ctx), np.int32)
    w = np.zeros((batch, cfg.ctx), np.float32)
    n_blocks = cfg.gen_len // BLOCK_FOR_TRAIN
    for i in range(batch):
        bench = BENCH_MIX[rng.randint(len(BENCH_MIX))]
        seed = tasks.TRAIN_SEED_BASE + rng.randint(1 << 30)
        p, a, _, _ = tasks.make_example(bench, seed, cfg.prompt_len, cfg.gen_len)
        seq = np.array(p + a, np.int32)
        t = rng.uniform(0.05, 1.0)
        m = np.zeros(cfg.gen_len, bool)
        if rng.randint(2) == 0:
            m = rng.uniform(size=cfg.gen_len) < t
        else:
            k = rng.randint(n_blocks)
            lo, hi = k * BLOCK_FOR_TRAIN, (k + 1) * BLOCK_FOR_TRAIN
            m[lo:hi] = rng.uniform(size=hi - lo) < t
            m[hi:] = True
        if not m.any():
            m[rng.randint(cfg.gen_len)] = True
        row = seq.copy()
        row[cfg.prompt_len:][m] = tasks.MASK
        toks[i] = row
        tgt[i] = seq
        # ELBO-style 1/ratio weighting using the realized mask ratio
        ratio = max(m.mean(), 1.0 / cfg.gen_len)
        wi = m.astype(np.float32) / ratio
        # EOS-fill targets dominate the region (~70% of positions) and are
        # trivial; down-weight them so content tokens carry the gradient
        # (without this, digit accuracy plateaus near chance while the
        # overall masked accuracy looks excellent)
        wi[np.array(a) == tasks.EOS] *= 0.1
        w[i, cfg.prompt_len:] = wi
    return toks, tgt, w


def loss_fn(cfg, params, toks, tgt, w):
    logits = train_logits(cfg, params, toks)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return z, z


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.98, eps=1e-8):
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1 - b1**step
    bc2 = 1 - b2**step
    params = jax.tree.map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
        params, m, v)
    return params, m, v


def write_checkpoint(path, cfg: ModelCfg, params: Params):
    flat = [np.asarray(t, np.float32) for t in params_to_flat(params)]
    specs = param_specs(cfg)
    assert len(flat) == len(specs)
    with open(path, "wb") as f:
        f.write(b"ESDW")                    # magic
        f.write(struct.pack("<I", 1))       # version
        f.write(struct.pack("<I", len(flat)))
        for t, (name, shape) in zip(flat, specs):
            assert t.shape == tuple(shape), (name, t.shape, shape)
            f.write(t.astype("<f4").tobytes())


def read_checkpoint(path, cfg: ModelCfg) -> Params:
    specs = param_specs(cfg)
    with open(path, "rb") as f:
        assert f.read(4) == b"ESDW"
        (ver,) = struct.unpack("<I", f.read(4))
        (n,) = struct.unpack("<I", f.read(4))
        assert ver == 1 and n == len(specs), (ver, n)
        flat = []
        for _, shape in specs:
            count = int(np.prod(shape)) if shape else 1
            t = np.frombuffer(f.read(4 * count), "<f4").reshape(shape)
            flat.append(jnp.asarray(t))
    return params_from_flat(cfg, flat)


def train(cfg: ModelCfg, out_dir, steps, base_step, batch, lr, seed=0,
          log_every=50, warm_start=None):
    rng = np.random.RandomState(seed)
    if warm_start and os.path.exists(warm_start):
        params = read_checkpoint(warm_start, cfg)
        print(f"[{cfg.name}] warm start from {warm_start}", flush=True)
    else:
        params = init_params(cfg, jax.random.PRNGKey(seed))
    m, v = adam_init(params)

    @jax.jit
    def train_step(params, m, v, toks, tgt, w, step, cur_lr):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, toks, tgt, w))(params)
        params, m, v = adam_update(params, grads, m, v, step, cur_lr)
        return params, m, v, loss

    warmup = max(1, steps // 10)
    t0 = time.time()
    for s in range(1, steps + 1):
        toks, tgt, w = make_batch(cfg, rng, batch)
        cur_lr = lr * min(1.0, s / warmup) * (0.1 ** (s / steps))
        params, m, v, loss = train_step(
            params, m, v, toks, tgt, w, jnp.asarray(s, jnp.float32),
            jnp.asarray(cur_lr, jnp.float32))
        if s % log_every == 0 or s == 1:
            print(f"[{cfg.name}] step {s}/{steps} loss {float(loss):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
        if s == base_step:
            path = os.path.join(out_dir, f"weights-{cfg.name}-base.bin")
            write_checkpoint(path, cfg, params)
            print(f"[{cfg.name}] wrote base snapshot -> {path}", flush=True)
        if s % 200 == 0:
            # rolling instruct checkpoint so downstream work is never
            # blocked on a full run
            path = os.path.join(out_dir, f"weights-{cfg.name}-instruct.bin")
            write_checkpoint(path, cfg, params)
    path = os.path.join(out_dir, f"weights-{cfg.name}-instruct.bin")
    write_checkpoint(path, cfg, params)
    print(f"[{cfg.name}] wrote instruct checkpoint -> {path}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--arch", choices=list(ARCHS) + ["all"], default="all")
    ap.add_argument("--steps", type=int, default=2200)
    ap.add_argument("--base-step", type=int, default=450)
    ap.add_argument("--batch", type=int, default=24)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--warm-start", action="store_true",
                    help="continue from the existing instruct checkpoint")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCHS.values()) if args.arch == "all" else [ARCHS[args.arch]]
    for cfg in archs:
        ws = (os.path.join(args.out, f"weights-{cfg.name}-instruct.bin")
              if args.warm_start else None)
        train(cfg, args.out, args.steps, args.base_step, args.batch, args.lr,
              warm_start=ws)


if __name__ == "__main__":
    main()
