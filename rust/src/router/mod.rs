//! Request router: the leader loop connecting the HTTP front end to
//! engine worker threads.
//!
//! PJRT objects are not `Send`, so each worker thread constructs its own
//! backend ([`Runtime`] + `PjrtBackend`, or the simulation backend) and
//! owns one [`GroupScheduler`]. Two scheduling modes:
//!
//!   * [`SchedMode::Continuous`] (default) — the worker keeps a fixed
//!     set of batch slots hot: finished sequences retire at block
//!     boundaries and queued requests are admitted into the freed slots
//!     mid-flight, so one slow sequence never holds finished slots
//!     hostage and arrivals don't wait for the group to drain;
//!   * [`SchedMode::RunToCompletion`] — the pre-refactor behavior
//!     (drain a batch, run it to completion), kept as the baseline the
//!     `serve_continuous` bench compares against.
//!
//! The scheduler's slot count is `batcher.max_batch`, fixed for the
//! worker's lifetime because the group caches and compiled executables
//! are shaped for one batch class ({1, 8}). That trades the old
//! lone-request b=1 fast path for always-hot slots; serve with
//! `max_batch = 1` to get the latency-optimal executables back on a
//! strictly sequential workload.
//!
//! Requests carry per-request parameters ([`SeqParams`]: `gen_len`,
//! temperature, parallel threshold) and replies carry true per-request
//! statistics ([`GenReply`]), not group-level aggregates. The shared
//! bounded queue provides backpressure: `try_submit` fails when the
//! queue is full → HTTP 503. Responses travel back through per-request
//! oneshot slots.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::batcher::{next_batch, BatcherCfg};
use crate::engine::EngineCfg;
use crate::metrics::Metrics;
use crate::runtime::Runtime;
use crate::scheduler::sim::{SimBackend, SimCfg};
use crate::scheduler::{
    GroupScheduler, PjrtBackend, SchedCfg, SeqInput, SeqParams, StepBackend,
};
use crate::threadpool::Channel;

pub struct GenRequest {
    pub prompt: String,
    pub params: SeqParams,
    pub submitted: Instant,
    reply: OneShot<Result<GenReply, String>>,
}

/// Per-request generation outcome (replaces the old group-level reply).
#[derive(Debug, Clone)]
pub struct GenReply {
    pub text: String,
    /// iterations THIS sequence was stepped
    pub iterations: usize,
    /// admission → completion
    pub wall_s: f64,
    /// submit → admission (time spent queued)
    pub queue_s: f64,
    /// positions decoded — content plus EOS fill (≤ requested gen_len
    /// on EOS-guard early exit)
    pub tokens: usize,
}

/// Minimal oneshot built on Mutex + Condvar.
pub struct OneShot<T>(Arc<(Mutex<Option<T>>, Condvar)>);

impl<T> Clone for OneShot<T> {
    fn clone(&self) -> Self {
        OneShot(self.0.clone())
    }
}

impl<T> OneShot<T> {
    pub fn new() -> Self {
        OneShot(Arc::new((Mutex::new(None), Condvar::new())))
    }

    pub fn put(&self, v: T) {
        *self.0 .0.lock().unwrap() = Some(v);
        self.0 .1.notify_all();
    }

    pub fn wait(&self) -> T {
        let mut g = self.0 .0.lock().unwrap();
        loop {
            if let Some(v) = g.take() {
                return v;
            }
            g = self.0 .1.wait(g).unwrap();
        }
    }
}

impl<T> Default for OneShot<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// slot scheduler with mid-flight admission at block boundaries
    Continuous,
    /// legacy drain-batch → run-to-completion (baseline for benches)
    RunToCompletion,
}

/// How a worker obtains its [`StepBackend`].
#[derive(Clone)]
pub enum WorkerBackend {
    /// load the PJRT runtime + compiled artifacts from `artifacts_dir`
    Pjrt,
    /// deterministic simulation backend (tests, scheduler benches)
    Sim(SimCfg),
}

#[derive(Clone)]
pub struct Router {
    queue: Channel<GenRequest>,
    pub metrics: Arc<Metrics>,
}

pub struct RouterCfg {
    pub engine: EngineCfg,
    pub batcher: BatcherCfg,
    pub queue_cap: usize,
    pub workers: usize,
    pub artifacts_dir: std::path::PathBuf,
    pub mode: SchedMode,
    pub backend: WorkerBackend,
}

impl RouterCfg {
    /// Continuous scheduling over the PJRT runtime with default batcher
    /// and queue settings; override fields as needed.
    pub fn new(engine: EngineCfg, artifacts_dir: std::path::PathBuf) -> RouterCfg {
        RouterCfg {
            engine,
            batcher: BatcherCfg::default(),
            queue_cap: 256,
            workers: 1,
            artifacts_dir,
            mode: SchedMode::Continuous,
            backend: WorkerBackend::Pjrt,
        }
    }
}

impl Router {
    /// Spawn worker threads and return the router handle. Each worker owns
    /// a full backend (PJRT client + compiled executables + params, or the
    /// simulation model) plus one slot scheduler.
    pub fn start(cfg: RouterCfg) -> Router {
        let queue: Channel<GenRequest> = Channel::bounded(cfg.queue_cap.max(1));
        let metrics = Arc::new(Metrics::default());
        metrics.start_clock();
        for w in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let engine_cfg = cfg.engine.clone();
            let batcher = cfg.batcher;
            let dir = cfg.artifacts_dir.clone();
            let mode = cfg.mode;
            let backend = cfg.backend.clone();
            std::thread::Builder::new()
                .name(format!("engine-{w}"))
                .spawn(move || worker_loop(queue, metrics, engine_cfg, batcher, dir, mode, backend))
                .expect("spawn engine worker");
        }
        Router { queue, metrics }
    }

    fn enqueue(
        &self,
        prompt: String,
        params: SeqParams,
        blocking: bool,
    ) -> Result<OneShot<Result<GenReply, String>>, ()> {
        let reply = OneShot::new();
        let req = GenRequest {
            prompt,
            params,
            submitted: Instant::now(),
            reply: reply.clone(),
        };
        let sent = if blocking {
            self.queue.send(req).map_err(|_| ())
        } else {
            self.queue.try_send(req).map_err(|_| ())
        };
        match sent {
            Ok(()) => {
                self.metrics.requests_total.inc();
                Ok(reply)
            }
            Err(()) => {
                if !blocking {
                    self.metrics.requests_rejected.inc();
                }
                Err(())
            }
        }
    }

    /// Enqueue a request; returns a oneshot to wait on, or Err when the
    /// queue is full (backpressure → HTTP 503).
    #[allow(clippy::result_unit_err)]
    pub fn try_submit(
        &self,
        prompt: String,
        params: SeqParams,
    ) -> Result<OneShot<Result<GenReply, String>>, ()> {
        self.enqueue(prompt, params, false)
    }

    /// Blocking submit (used by the load generator / tests).
    #[allow(clippy::result_unit_err)]
    pub fn submit(
        &self,
        prompt: String,
        params: SeqParams,
    ) -> Result<OneShot<Result<GenReply, String>>, ()> {
        self.enqueue(prompt, params, true)
    }

    pub fn shutdown(&self) {
        self.queue.close();
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

fn drain_with_error(queue: &Channel<GenRequest>, msg: &str) {
    while let Some(req) = queue.recv() {
        req.reply.put(Err(msg.to_string()));
    }
}

fn worker_loop(
    queue: Channel<GenRequest>,
    metrics: Arc<Metrics>,
    engine_cfg: EngineCfg,
    batcher: BatcherCfg,
    artifacts_dir: std::path::PathBuf,
    mode: SchedMode,
    backend_kind: WorkerBackend,
) {
    let slots = batcher.max_batch.max(1);
    // the runtime (when used) must outlive the backend borrowing it
    let mut rt_holder: Option<Runtime> = None;
    let backend: Box<dyn StepBackend + '_> = match backend_kind {
        WorkerBackend::Pjrt => {
            // the compiled artifacts exist only for batch classes {1, 8};
            // fail fast with a clear message instead of answering every
            // request with a confusing missing-executable error
            if slots != 1 && slots != 8 {
                let msg = format!(
                    "batcher.max_batch {slots} unsupported by the compiled \
                     executables (batch classes 1 and 8 only)"
                );
                log::error!("engine worker misconfigured: {msg}");
                drain_with_error(&queue, &msg);
                return;
            }
            let rt = match Runtime::load(&artifacts_dir) {
                Ok(rt) => rt,
                Err(e) => {
                    log::error!("engine worker failed to load runtime: {e:#}");
                    drain_with_error(&queue, &format!("runtime unavailable: {e}"));
                    return;
                }
            };
            let rt = rt_holder.insert(rt);
            match PjrtBackend::new(rt, engine_cfg.clone(), slots) {
                Ok(b) => Box::new(b),
                Err(e) => {
                    log::error!("engine worker failed to build backend: {e:#}");
                    drain_with_error(&queue, &format!("backend unavailable: {e}"));
                    return;
                }
            }
        }
        WorkerBackend::Sim(sim_cfg) => Box::new(SimBackend::new(sim_cfg)),
    };
    let sched = match GroupScheduler::new(backend, slots, SchedCfg::from_engine(&engine_cfg)) {
        Ok(s) => s,
        Err(e) => {
            log::error!("engine worker failed to build scheduler: {e:#}");
            drain_with_error(&queue, &format!("scheduler unavailable: {e}"));
            return;
        }
    };
    // additive: several workers contribute to one capacity gauge
    metrics.slots_total.add(slots as u64);
    match mode {
        SchedMode::Continuous => run_continuous(sched, queue, metrics),
        SchedMode::RunToCompletion => run_to_completion(sched, queue, metrics, batcher),
    }
}

/// Publish this worker's occupied-slot count as a delta against its
/// previous contribution, so workers sharing the `active_slots` gauge
/// never stomp each other.
fn sync_active_slots(metrics: &Metrics, last: &mut usize, now: usize) {
    if now > *last {
        metrics.active_slots.add((now - *last) as u64);
    } else {
        metrics.active_slots.sub((*last - now) as u64);
    }
    *last = now;
}

/// Shared per-tick bookkeeping: run one tick, update metrics, and answer
/// the retired sequences. Returns false after a backend error (all
/// resident sequences were failed and evicted).
fn tick_once(
    sched: &mut GroupScheduler<'_>,
    metrics: &Metrics,
    pending: &mut HashMap<u64, OneShot<Result<GenReply, String>>>,
    last_active: &mut usize,
) -> bool {
    let busy = sched.active();
    let before = (sched.n_prefill, sched.n_dual, sched.n_es);
    let tr_before = sched.transfer_stats();
    let t0 = Instant::now();
    let tick_result = sched.tick();
    // resident-cache transfer accounting: this tick's ledger delta.
    // Pumped on both arms — a failed tick may already have synced and
    // recorded bytes, and the next snapshot would silently swallow them.
    let tr = sched.transfer_stats().since(&tr_before);
    metrics.upload_bytes.add(tr.upload_bytes);
    metrics.upload_bytes_saved.add(tr.upload_bytes_saved);
    metrics
        .kv_upload_bytes
        .add(tr.kv_upload_bytes + tr.kv_sparse_upload_bytes);
    metrics.ind_upload_bytes.add(tr.ind_upload_bytes);
    metrics.conf_upload_bytes.add(tr.conf_upload_bytes);
    metrics.token_upload_bytes.add(tr.token_upload_bytes);
    metrics.full_kv_uploads.add(tr.full_kv_uploads);
    metrics.resident_reuses.add(tr.resident_reuses);
    metrics.retained_out_reuses.add(tr.retained_out_reuses);
    metrics.d2h_bytes_avoided.add(tr.d2h_bytes_avoided);
    metrics.ingraph_conf_steps.add(tr.ingraph_conf_steps);
    metrics.d2h_bytes_shipped.add(tr.d2h_bytes_shipped);
    metrics.d2h_bytes_saved.add(tr.d2h_bytes_saved);
    metrics.donated_execs.add(tr.donated_execs);
    match tick_result {
        Ok(finished) => {
            metrics.ticks_total.inc();
            metrics.slot_busy_seconds.add_secs(t0.elapsed().as_secs_f64() * busy as f64);
            metrics.prefill_steps.add((sched.n_prefill - before.0) as u64);
            metrics.dual_steps.add((sched.n_dual - before.1) as u64);
            metrics.es_steps.add((sched.n_es - before.2) as u64);
            // publish the gauge before answering clients: a client that
            // just received its reply must not observe its own sequence
            // still counted as active (retirement already freed the slot,
            // so sched.active() is final here)
            sync_active_slots(metrics, last_active, sched.active());
            for f in finished {
                metrics.retirements_total.inc();
                metrics.tokens_generated.add(f.tokens as u64);
                metrics.iterations_total.add(f.iterations as u64);
                metrics.request_latency.observe_secs(f.queue_s + f.gen_s);
                if let Some(reply) = pending.remove(&f.id) {
                    reply.put(Ok(GenReply {
                        text: f.text,
                        iterations: f.iterations,
                        wall_s: f.gen_s,
                        queue_s: f.queue_s,
                        tokens: f.tokens,
                    }));
                }
            }
            true
        }
        Err(e) => {
            log::error!("scheduler tick failed: {e:#}");
            for id in sched.active_ids() {
                if let Some(reply) = pending.remove(&id) {
                    reply.put(Err(format!("{e}")));
                }
            }
            sched.evict_all();
            sync_active_slots(metrics, last_active, 0);
            false
        }
    }
}

fn admit_request(
    sched: &mut GroupScheduler<'_>,
    metrics: &Metrics,
    pending: &mut HashMap<u64, OneShot<Result<GenReply, String>>>,
    id: u64,
    req: GenRequest,
) {
    metrics.queue_latency.observe_secs(req.submitted.elapsed().as_secs_f64());
    let input = SeqInput {
        id,
        prompt: req.prompt,
        params: req.params,
        submitted: req.submitted,
    };
    match sched.admit(input) {
        Ok(_) => {
            metrics.admissions_total.inc();
            pending.insert(id, req.reply);
        }
        Err(e) => req.reply.put(Err(format!("{e}"))),
    }
}

/// Continuous batching: keep the slots hot — admit from the queue into
/// any free slot (newly admitted sequences get their grounding prefill
/// on the next tick), retire at block boundaries, repeat.
fn run_continuous(
    mut sched: GroupScheduler<'_>,
    queue: Channel<GenRequest>,
    metrics: Arc<Metrics>,
) {
    let mut pending: HashMap<u64, OneShot<Result<GenReply, String>>> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut last_active = 0usize;
    loop {
        // admission: fill free slots; block for work only when idle.
        // (a failed admission — bad request — loops back into the
        // blocking recv, so the loop below always exits with work)
        while sched.free_slots() > 0 {
            let req = if sched.active() == 0 {
                match queue.recv() {
                    Some(r) => r,
                    None => return, // closed and drained
                }
            } else {
                match queue.try_recv() {
                    Some(r) => r,
                    None => break,
                }
            };
            let id = next_id;
            next_id += 1;
            admit_request(&mut sched, &metrics, &mut pending, id, req);
        }
        sync_active_slots(&metrics, &mut last_active, sched.active());
        tick_once(&mut sched, &metrics, &mut pending, &mut last_active);
    }
}

/// Legacy baseline: drain a batch from the queue, run the whole group to
/// completion with no mid-flight admission, reply, repeat.
fn run_to_completion(
    mut sched: GroupScheduler<'_>,
    queue: Channel<GenRequest>,
    metrics: Arc<Metrics>,
    batcher: BatcherCfg,
) {
    let mut next_id: u64 = 0;
    let mut last_active = 0usize;
    while let Some(batch) = next_batch(&queue, &batcher) {
        metrics.batches_total.inc();
        metrics.batch_occupancy_sum.add(batch.len() as u64);
        let mut pending: HashMap<u64, OneShot<Result<GenReply, String>>> = HashMap::new();
        for req in batch {
            let id = next_id;
            next_id += 1;
            admit_request(&mut sched, &metrics, &mut pending, id, req);
        }
        sync_active_slots(&metrics, &mut last_active, sched.active());
        while sched.active() > 0 {
            if !tick_once(&mut sched, &metrics, &mut pending, &mut last_active) {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oneshot_roundtrip() {
        let s: OneShot<u32> = OneShot::new();
        let s2 = s.clone();
        std::thread::spawn(move || s2.put(7));
        assert_eq!(s.wait(), 7);
    }

    fn sim_router(mode: SchedMode, slots: usize, queue_cap: usize) -> Router {
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", crate::engine::Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.backend = WorkerBackend::Sim(SimCfg::default());
        cfg.batcher = BatcherCfg { max_batch: slots, flush_ms: 2 };
        cfg.queue_cap = queue_cap;
        cfg.mode = mode;
        Router::start(cfg)
    }

    #[test]
    fn continuous_router_serves_requests_end_to_end() {
        let router = sim_router(SchedMode::Continuous, 2, 16);
        let slot = router.submit("1+2=".into(), SeqParams::default()).unwrap();
        let reply = slot.wait().expect("sim generation succeeds");
        assert_eq!(reply.text, "1+2=", "sim echoes the prompt");
        assert!(reply.iterations > 0);
        assert!(reply.tokens > 0);
        // the resident-cache ledger reached the serving metrics: one
        // residency seed, then steady-state steps reuse the device copy
        assert!(router.metrics.upload_bytes.get() > 0);
        assert_eq!(router.metrics.full_kv_uploads.get(), 1);
        assert!(router.metrics.upload_bytes_saved.get() > 0);
        assert!(router.metrics.resident_reuses.get() > 0);
        // device-apply accounting flows through per tick: steps chained
        // the retained kv/ind/conf outputs and computed conf in-graph
        assert!(router.metrics.retained_out_reuses.get() > 0);
        assert!(router.metrics.d2h_bytes_avoided.get() > 0);
        assert!(router.metrics.ingraph_conf_steps.get() > 0);
        // the sliced downlink + donation ledger flows through too: runs
        // downloaded gen-region logit rows (saving the prompt-region
        // slice) with their chained inputs donated in place
        assert!(router.metrics.d2h_bytes_shipped.get() > 0);
        assert!(router.metrics.d2h_bytes_saved.get() > 0);
        assert!(router.metrics.donated_execs.get() > 0);
        router.shutdown();
    }

    #[test]
    fn run_to_completion_router_still_works() {
        let router = sim_router(SchedMode::RunToCompletion, 2, 16);
        let a = router.submit("ab".into(), SeqParams::default()).unwrap();
        let b = router.submit("cdef".into(), SeqParams::default()).unwrap();
        assert_eq!(a.wait().unwrap().text, "ab");
        assert_eq!(b.wait().unwrap().text, "cdef");
        router.shutdown();
    }

    #[test]
    fn invalid_params_fail_the_request_not_the_worker() {
        let router = sim_router(SchedMode::Continuous, 1, 8);
        let bad = SeqParams { gen_len: Some(3), ..Default::default() };
        let err = router.submit("ab".into(), bad).unwrap().wait().unwrap_err();
        assert!(err.starts_with("bad request:"), "{err}");
        // the worker must still be alive for the next request
        let ok = router.submit("ok".into(), SeqParams::default()).unwrap();
        assert_eq!(ok.wait().unwrap().text, "ok");
        router.shutdown();
    }
}
