//! Serving metrics: counters, latency histograms, throughput accounting.
//! Exposed via the HTTP `/metrics` endpoint in a Prometheus-like text
//! format and consumed by the benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram over fixed log-spaced buckets (microseconds to
/// minutes), plus exact quantiles from a bounded reservoir.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    bounds_us: Vec<u64>,
    reservoir: Mutex<Vec<f64>>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

const RESERVOIR_CAP: usize = 4096;

impl Default for Histogram {
    fn default() -> Self {
        // 100us .. ~100s, ~x2.15 steps
        let bounds_us: Vec<u64> = (0..20)
            .map(|i| (100.0 * 2.15f64.powi(i)) as u64)
            .collect();
        Histogram {
            buckets: (0..bounds_us.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            bounds_us,
            reservoir: Mutex::new(Vec::with_capacity(RESERVOIR_CAP)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe_secs(&self, s: f64) {
        let us = (s * 1e6) as u64;
        let idx = self.bounds_us.partition_point(|b| *b < us);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        let n = self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        let mut r = self.reservoir.lock().unwrap();
        if r.len() < RESERVOIR_CAP {
            r.push(s);
        } else {
            // simple reservoir sampling keeps quantiles representative
            let j = (n as usize) % (RESERVOIR_CAP * 4);
            if j < RESERVOIR_CAP {
                r[j] = s;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
    }

    pub fn quantile(&self, q: f64) -> f64 {
        let mut r = self.reservoir.lock().unwrap().clone();
        if r.is_empty() {
            return 0.0;
        }
        // total_cmp: partial_cmp().unwrap() panics on NaN samples, and a
        // single poisoned observation must not take down /metrics
        r.sort_by(f64::total_cmp);
        r[((r.len() as f64 - 1.0) * q).round() as usize]
    }
}

/// A settable instantaneous value (e.g. currently occupied slots).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Delta updates so several workers can share one gauge without
    /// stomping each other's contribution.
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn sub(&self, v: u64) {
        // saturating: a racing read must never observe a wrapped value
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                Some(cur.saturating_sub(v))
            });
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Accumulated seconds stored as integer nanoseconds (atomic f64 sums
/// without a mutex on the scheduler hot path; nanosecond resolution so
/// sub-microsecond per-tick observations don't truncate to zero).
#[derive(Default)]
pub struct SecondsCounter(AtomicU64);

impl SecondsCounter {
    pub fn add_secs(&self, s: f64) {
        self.0.fetch_add((s * 1e9).round() as u64, Ordering::Relaxed);
    }

    pub fn get_secs(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e9
    }
}

/// Server-wide metrics registry.
#[derive(Default)]
pub struct Metrics {
    pub requests_total: Counter,
    pub requests_rejected: Counter,
    pub tokens_generated: Counter,
    pub iterations_total: Counter,
    pub prefill_steps: Counter,
    pub dual_steps: Counter,
    pub es_steps: Counter,
    pub batches_total: Counter,
    pub batch_occupancy_sum: Counter,
    // -- continuous-batching scheduler --
    /// sequences admitted into a slot / retired from one
    pub admissions_total: Counter,
    pub retirements_total: Counter,
    /// scheduler iterations executed
    pub ticks_total: Counter,
    /// currently occupied slots / configured slot count
    pub active_slots: Gauge,
    pub slots_total: Gauge,
    /// ∑ over ticks of (occupied slots × tick wall time): the denominator
    /// of the occupancy-weighted throughput
    pub slot_busy_seconds: SecondsCounter,
    // -- resident-cache transfer accounting (logical bytes from the
    //    scheduler backends' transfer ledgers) --
    /// bytes shipped host→device after dirty-delta planning
    pub upload_bytes: Counter,
    /// bytes avoided vs the clone-and-reupload baseline
    pub upload_bytes_saved: Counter,
    pub kv_upload_bytes: Counter,
    pub ind_upload_bytes: Counter,
    pub conf_upload_bytes: Counter,
    pub token_upload_bytes: Counter,
    /// syncs that shipped an entire KV tensor (the residency seed, plus
    /// any full invalidation)
    pub full_kv_uploads: Counter,
    /// input syncs served entirely from the resident device copy
    pub resident_reuses: Counter,
    /// executable inputs served by chaining a retained device output
    /// (device-apply mode: zero bytes in either direction)
    pub retained_out_reuses: Counter,
    /// D2H bytes avoided by retaining outputs on device instead of
    /// downloading them for a host-side scatter
    pub d2h_bytes_avoided: Counter,
    /// runs whose confidence was computed in-graph (no host round-trip)
    pub ingraph_conf_steps: Counter,
    /// sampler-bound D2H bytes actually downloaded by device-apply runs
    /// (gen-region logit slices + selected step rows with positions)
    pub d2h_bytes_shipped: Counter,
    /// logit downlink bytes saved vs the full-context [B, ctx, V]
    /// baseline download
    pub d2h_bytes_saved: Counter,
    /// device-apply executions whose chained inputs were donated in
    /// place by the compile-time input-output alias config
    pub donated_execs: Counter,
    // -- fused k-step dispatches --
    /// device executions that ran a k-iteration in-graph diffusion loop
    pub fused_execs: Counter,
    /// total inner iterations those fused executions advanced
    pub inner_iters_fused: Counter,
    /// host→device dispatches (and their round-trips) the fused runs
    /// eliminated vs issuing every iteration as its own execution
    pub dispatches_avoided: Counter,
    // -- pooled device residency (mirrored from the shared
    //    ResidencyPool's cumulative ledger each scheduler tick; gauges
    //    because several workers publish the same pool-wide values) --
    /// retained chains currently holding device state (live + parked)
    pub resident_chains: Gauge,
    /// batch-class switches the schedulers performed
    pub chain_switches: Gauge,
    /// chain checkouts that reused a parked seeded chain instead of a
    /// cold rebuild
    pub chain_rebuilds_avoided: Gauge,
    /// full-seed bytes those avoided rebuilds would have re-shipped
    pub reseed_bytes_saved: Gauge,
    // -- cross-request prefix KV cache (mirrored from the shared
    //    PrefixCache's cumulative ledger each scheduler tick; gauges for
    //    the same reason as the pool counters) --
    /// admissions that seeded prompt-region KV rows from a cached prefix
    pub prefix_hits: Gauge,
    /// admissions that probed the prefix cache and found nothing
    pub prefix_misses: Gauge,
    /// grounding-prefill KV bytes those hits did not regenerate
    pub prefill_bytes_saved: Gauge,
    /// bytes of prefix payloads currently cached
    pub prefix_cache_bytes: Gauge,
    /// prefix entries evicted to hold the cache's byte budget
    pub prefix_evictions: Gauge,
    // -- live-context decoding (mirrored from the backends' transfer
    //    ledgers each scheduler tick; gauges because the pumped values
    //    are cumulative ledger snapshots, not per-tick deltas) --
    /// ∑ over device execs of batch × live-context rows actually
    /// attended over (the tiered executables' working set)
    pub live_ctx_rows: Gauge,
    /// ∑ over device execs of batch × compiled-maximum context rows —
    /// the denominator `live_ctx_rows` is measured against
    pub full_ctx_rows: Gauge,
    /// fully-converged suffix blocks a pruned dispatch did not attend
    /// over (vs the compiled-maximum context)
    pub suffix_blocks_pruned: Gauge,
    /// trailing never-decoded blocks retired early on the EOS guard
    pub early_retired_blocks: Gauge,
    /// context-tier switches the schedulers performed (each one a
    /// forced grounding prefill at the new live length)
    pub tier_switches: Gauge,
    /// abstract attention-FLOPs units (batch × query rows × live keys)
    /// accumulated by device execs — the numerator of the per-tick
    /// FLOPs estimate
    pub flops_units: Gauge,
    // -- fault injection + recovery (mirrored from the backends'
    //    FaultStats ledgers each scheduler tick) --
    /// faults the deterministic injector actually fired
    pub faults_injected: Counter,
    /// ticks re-run after a recoverable fault
    pub ticks_retried: Counter,
    /// grounding prefills issued to rebuild device state after a fault
    pub chains_regrounded: Counter,
    /// fused-depth ladder steps (k → k/2) after divergent dispatches
    pub fused_k_demotions: Counter,
    /// device-apply quarantines to ApplyMode::Host
    pub host_demotions: Counter,
    /// requests failed after the retry budget (or on misconfiguration)
    pub requests_failed: Counter,
    /// sequences retired overdue with a structured timeout error
    pub timeouts_total: Counter,
    // -- SLO-aware serving (per-class latency, shed/preempt ledger) --
    /// time-to-first-token per service class (submission → first
    /// committed token), indexed in SloClass priority order:
    /// latency_sensitive, throughput, batch
    pub class_ttft: [Histogram; SLO_CLASSES],
    /// time-per-output-token per service class (generation time over
    /// decoded positions), same indexing
    pub class_tpot: [Histogram; SLO_CLASSES],
    /// requests answered with a structured shed instead of served:
    /// overload (`overloaded:` 429s) plus deadline sheds at admission
    /// and of parked victims (`timeout:` 504s)
    pub shed_total: Counter,
    /// sequences preempted off their slots at block boundaries
    /// (mirrored from the shared pool ledger, like the chain gauges)
    pub preemptions_total: Gauge,
    /// preempted sequences reseated after pressure dropped
    pub resumed_total: Gauge,
    /// victims currently parked off their slots
    pub victims_parked: Gauge,
    pub request_latency: Histogram,
    pub queue_latency: Histogram,
    started: Mutex<Option<std::time::Instant>>,
}

/// Number of service classes (`scheduler::SloClass` priority order:
/// latency_sensitive, throughput, batch). Kept as a local constant so
/// the metrics registry stays dependency-free.
pub const SLO_CLASSES: usize = 3;

/// Metric-label names of the service classes, in index order.
pub const SLO_CLASS_NAMES: [&str; SLO_CLASSES] = ["latency_sensitive", "throughput", "batch"];

impl Metrics {
    pub fn start_clock(&self) {
        *self.started.lock().unwrap() = Some(std::time::Instant::now());
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn tps(&self) -> f64 {
        let up = self.uptime_secs();
        if up <= 0.0 {
            return 0.0;
        }
        self.tokens_generated.get() as f64 / up
    }

    /// Mean fraction of slots occupied while the server has been up.
    pub fn slot_occupancy(&self) -> f64 {
        let denom = self.uptime_secs() * self.slots_total.get().max(1) as f64;
        if denom <= 0.0 {
            return 0.0;
        }
        (self.slot_busy_seconds.get_secs() / denom).min(1.0)
    }

    /// Occupancy-weighted throughput: tokens per second of *busy* slot
    /// time. Unlike `tps` this is insensitive to idle stretches, so it
    /// isolates how well the scheduler keeps admitted work dense.
    pub fn tps_per_busy_slot(&self) -> f64 {
        let busy = self.slot_busy_seconds.get_secs();
        if busy <= 0.0 {
            return 0.0;
        }
        self.tokens_generated.get() as f64 / busy
    }

    /// Prometheus-style exposition.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let kv = [
            ("esdllm_requests_total", self.requests_total.get()),
            ("esdllm_requests_rejected", self.requests_rejected.get()),
            ("esdllm_tokens_generated", self.tokens_generated.get()),
            ("esdllm_iterations_total", self.iterations_total.get()),
            ("esdllm_prefill_steps", self.prefill_steps.get()),
            ("esdllm_dual_steps", self.dual_steps.get()),
            ("esdllm_es_steps", self.es_steps.get()),
            ("esdllm_batches_total", self.batches_total.get()),
            ("esdllm_admissions_total", self.admissions_total.get()),
            ("esdllm_retirements_total", self.retirements_total.get()),
            ("esdllm_ticks_total", self.ticks_total.get()),
            ("esdllm_active_slots", self.active_slots.get()),
            ("esdllm_slots_total", self.slots_total.get()),
            ("esdllm_upload_bytes", self.upload_bytes.get()),
            ("esdllm_upload_bytes_saved", self.upload_bytes_saved.get()),
            ("esdllm_kv_upload_bytes", self.kv_upload_bytes.get()),
            ("esdllm_ind_upload_bytes", self.ind_upload_bytes.get()),
            ("esdllm_conf_upload_bytes", self.conf_upload_bytes.get()),
            ("esdllm_token_upload_bytes", self.token_upload_bytes.get()),
            ("esdllm_full_kv_uploads", self.full_kv_uploads.get()),
            ("esdllm_resident_reuses", self.resident_reuses.get()),
            ("esdllm_retained_out_reuses", self.retained_out_reuses.get()),
            ("esdllm_d2h_bytes_avoided", self.d2h_bytes_avoided.get()),
            ("esdllm_ingraph_conf_steps", self.ingraph_conf_steps.get()),
            ("esdllm_d2h_bytes_shipped", self.d2h_bytes_shipped.get()),
            ("esdllm_d2h_bytes_saved", self.d2h_bytes_saved.get()),
            ("esdllm_donated_execs", self.donated_execs.get()),
            ("esdllm_fused_execs", self.fused_execs.get()),
            ("esdllm_inner_iters_fused", self.inner_iters_fused.get()),
            ("esdllm_dispatches_avoided", self.dispatches_avoided.get()),
            ("esdllm_resident_chains", self.resident_chains.get()),
            ("esdllm_chain_switches", self.chain_switches.get()),
            ("esdllm_chain_rebuilds_avoided", self.chain_rebuilds_avoided.get()),
            ("esdllm_reseed_bytes_saved", self.reseed_bytes_saved.get()),
            ("esdllm_prefix_hits", self.prefix_hits.get()),
            ("esdllm_prefix_misses", self.prefix_misses.get()),
            ("esdllm_prefill_bytes_saved", self.prefill_bytes_saved.get()),
            ("esdllm_prefix_cache_bytes", self.prefix_cache_bytes.get()),
            ("esdllm_prefix_evictions", self.prefix_evictions.get()),
            ("esdllm_live_ctx_rows", self.live_ctx_rows.get()),
            ("esdllm_full_ctx_rows", self.full_ctx_rows.get()),
            ("esdllm_suffix_blocks_pruned", self.suffix_blocks_pruned.get()),
            ("esdllm_early_retired_blocks", self.early_retired_blocks.get()),
            ("esdllm_tier_switches", self.tier_switches.get()),
            ("esdllm_faults_injected", self.faults_injected.get()),
            ("esdllm_ticks_retried", self.ticks_retried.get()),
            ("esdllm_chains_regrounded", self.chains_regrounded.get()),
            ("esdllm_fused_k_demotions", self.fused_k_demotions.get()),
            ("esdllm_host_demotions", self.host_demotions.get()),
            ("esdllm_requests_failed", self.requests_failed.get()),
            ("esdllm_timeouts_total", self.timeouts_total.get()),
            ("esdllm_shed_total", self.shed_total.get()),
            ("esdllm_preemptions_total", self.preemptions_total.get()),
            ("esdllm_resumed_total", self.resumed_total.get()),
            ("esdllm_victims_parked", self.victims_parked.get()),
        ];
        for (k, v) in kv {
            out.push_str(&format!("{k} {v}\n"));
        }
        out.push_str(&format!("esdllm_throughput_tps {:.3}\n", self.tps()));
        out.push_str(&format!(
            "esdllm_request_latency_seconds_mean {:.6}\n",
            self.request_latency.mean_secs()
        ));
        for q in [0.5, 0.9, 0.99] {
            out.push_str(&format!(
                "esdllm_request_latency_seconds_p{} {:.6}\n",
                (q * 100.0) as u32,
                self.request_latency.quantile(q)
            ));
        }
        // per-class serving quality: TTFT and TPOT p50/p99 for every
        // service class (labels are plain text here — the exposition is
        // hand-rendered, no client library involved)
        for (i, name) in SLO_CLASS_NAMES.iter().enumerate() {
            out.push_str(&format!(
                "esdllm_ttft_seconds_count{{class=\"{name}\"}} {}\n",
                self.class_ttft[i].count()
            ));
            for q in [0.5, 0.99] {
                out.push_str(&format!(
                    "esdllm_ttft_seconds_p{}{{class=\"{name}\"}} {:.6}\n",
                    (q * 100.0) as u32,
                    self.class_ttft[i].quantile(q)
                ));
                out.push_str(&format!(
                    "esdllm_tpot_seconds_p{}{{class=\"{name}\"}} {:.6}\n",
                    (q * 100.0) as u32,
                    self.class_tpot[i].quantile(q)
                ));
            }
        }
        let batches = self.batches_total.get().max(1);
        out.push_str(&format!(
            "esdllm_batch_occupancy_mean {:.3}\n",
            self.batch_occupancy_sum.get() as f64 / batches as f64
        ));
        out.push_str(&format!(
            "esdllm_slot_busy_seconds {:.3}\n",
            self.slot_busy_seconds.get_secs()
        ));
        let ticks = self.ticks_total.get().max(1);
        out.push_str(&format!(
            "esdllm_upload_bytes_per_tick {:.1}\n",
            self.upload_bytes.get() as f64 / ticks as f64
        ));
        out.push_str(&format!(
            "esdllm_d2h_bytes_shipped_per_tick {:.1}\n",
            self.d2h_bytes_shipped.get() as f64 / ticks as f64
        ));
        // mean iterations a FUSED dispatch advanced (unfused step
        // dispatches are excluded from both sides — the name says so, a
        // deployment fusing 1% of its dispatches at k = 8 reports 8.0
        // here and reads the overall rate off `dispatches_avoided` /
        // ticks); 1.0 when nothing fused
        let fused = self.fused_execs.get();
        let avg_iters = if fused == 0 {
            1.0
        } else {
            self.inner_iters_fused.get() as f64 / fused as f64
        };
        out.push_str(&format!(
            "esdllm_avg_iters_per_fused_dispatch {avg_iters:.3}\n"
        ));
        // mean abstract attention-FLOPs per tick (batch × query rows ×
        // live keys, summed over device execs); with live-context
        // decoding off this tracks the full-context cost exactly
        out.push_str(&format!(
            "esdllm_flops_per_tick_est {:.1}\n",
            self.flops_units.get() as f64 / ticks as f64
        ));
        // fraction of the compiled-maximum context rows the tiered
        // executables actually attended over (1.0 = no pruning)
        let full_rows = self.full_ctx_rows.get();
        let live_frac = if full_rows == 0 {
            1.0
        } else {
            self.live_ctx_rows.get() as f64 / full_rows as f64
        };
        out.push_str(&format!("esdllm_live_ctx_fraction {live_frac:.4}\n"));
        out.push_str(&format!("esdllm_slot_occupancy {:.4}\n", self.slot_occupancy()));
        out.push_str(&format!(
            "esdllm_tps_per_busy_slot {:.3}\n",
            self.tps_per_busy_slot()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.observe_secs(i as f64 / 100.0);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        assert!(p50 <= p90);
        assert!((h.mean_secs() - 0.505).abs() < 0.02);
    }

    #[test]
    fn render_contains_counters() {
        let m = Metrics::default();
        m.start_clock();
        m.requests_total.inc();
        m.tokens_generated.add(32);
        m.upload_bytes.add(1024);
        m.upload_bytes_saved.add(4096);
        m.full_kv_uploads.inc();
        m.retained_out_reuses.add(3);
        m.d2h_bytes_avoided.add(2048);
        m.ingraph_conf_steps.inc();
        m.d2h_bytes_shipped.add(512);
        m.d2h_bytes_saved.add(768);
        m.donated_execs.add(2);
        m.fused_execs.add(2);
        m.inner_iters_fused.add(7);
        m.dispatches_avoided.add(5);
        m.resident_chains.set(2);
        m.chain_switches.set(3);
        m.chain_rebuilds_avoided.set(1);
        m.reseed_bytes_saved.set(4096);
        m.prefix_hits.set(5);
        m.prefix_misses.set(6);
        m.prefill_bytes_saved.set(8192);
        m.prefix_cache_bytes.set(2049);
        m.prefix_evictions.set(2);
        m.live_ctx_rows.set(640);
        m.full_ctx_rows.set(1280);
        m.suffix_blocks_pruned.set(12);
        m.early_retired_blocks.set(2);
        m.tier_switches.set(5);
        m.flops_units.set(4096);
        m.faults_injected.add(4);
        m.ticks_retried.add(3);
        m.chains_regrounded.add(3);
        m.fused_k_demotions.inc();
        m.host_demotions.inc();
        m.requests_failed.inc();
        m.timeouts_total.inc();
        m.shed_total.add(4);
        m.preemptions_total.set(3);
        m.resumed_total.set(2);
        m.victims_parked.set(1);
        m.class_ttft[0].observe_secs(0.010);
        m.class_tpot[0].observe_secs(0.002);
        let text = m.render();
        assert!(text.contains("esdllm_requests_total 1"));
        assert!(text.contains("esdllm_tokens_generated 32"));
        assert!(text.contains("esdllm_active_slots 0"));
        assert!(text.contains("esdllm_slot_occupancy"));
        assert!(text.contains("esdllm_upload_bytes 1024"));
        assert!(text.contains("esdllm_upload_bytes_saved 4096"));
        assert!(text.contains("esdllm_full_kv_uploads 1"));
        assert!(text.contains("esdllm_retained_out_reuses 3"));
        assert!(text.contains("esdllm_d2h_bytes_avoided 2048"));
        assert!(text.contains("esdllm_ingraph_conf_steps 1"));
        assert!(text.contains("esdllm_d2h_bytes_shipped 512"));
        assert!(text.contains("esdllm_d2h_bytes_saved 768"));
        assert!(text.contains("esdllm_donated_execs 2"));
        assert!(text.contains("esdllm_fused_execs 2"));
        assert!(text.contains("esdllm_inner_iters_fused 7"));
        assert!(text.contains("esdllm_dispatches_avoided 5"));
        assert!(text.contains("esdllm_avg_iters_per_fused_dispatch 3.500"));
        assert!(text.contains("esdllm_resident_chains 2"));
        assert!(text.contains("esdllm_chain_switches 3"));
        assert!(text.contains("esdllm_chain_rebuilds_avoided 1"));
        assert!(text.contains("esdllm_reseed_bytes_saved 4096"));
        assert!(text.contains("esdllm_prefix_hits 5"));
        assert!(text.contains("esdllm_prefix_misses 6"));
        assert!(text.contains("esdllm_prefill_bytes_saved 8192"));
        assert!(text.contains("esdllm_prefix_cache_bytes 2049"));
        assert!(text.contains("esdllm_prefix_evictions 2"));
        assert!(text.contains("esdllm_live_ctx_rows 640"));
        assert!(text.contains("esdllm_full_ctx_rows 1280"));
        assert!(text.contains("esdllm_suffix_blocks_pruned 12"));
        assert!(text.contains("esdllm_early_retired_blocks 2"));
        assert!(text.contains("esdllm_tier_switches 5"));
        assert!(text.contains("esdllm_live_ctx_fraction 0.5000"));
        assert!(text.contains("esdllm_flops_per_tick_est"));
        assert!(text.contains("esdllm_faults_injected 4"));
        assert!(text.contains("esdllm_ticks_retried 3"));
        assert!(text.contains("esdllm_chains_regrounded 3"));
        assert!(text.contains("esdllm_fused_k_demotions 1"));
        assert!(text.contains("esdllm_host_demotions 1"));
        assert!(text.contains("esdllm_requests_failed 1"));
        assert!(text.contains("esdllm_timeouts_total 1"));
        assert!(text.contains("esdllm_shed_total 4"));
        assert!(text.contains("esdllm_preemptions_total 3"));
        assert!(text.contains("esdllm_resumed_total 2"));
        assert!(text.contains("esdllm_victims_parked 1"));
        assert!(text.contains("esdllm_ttft_seconds_count{class=\"latency_sensitive\"} 1"));
        assert!(text.contains("esdllm_ttft_seconds_p99{class=\"latency_sensitive\"}"));
        assert!(text.contains("esdllm_tpot_seconds_p50{class=\"throughput\"}"));
        assert!(text.contains("esdllm_upload_bytes_per_tick"));
        assert!(text.contains("esdllm_d2h_bytes_shipped_per_tick"));
    }

    #[test]
    fn quantile_survives_nan_observation() {
        let h = Histogram::default();
        h.observe_secs(0.5);
        h.observe_secs(f64::NAN);
        h.observe_secs(0.1);
        // must not panic; NaN sorts last under total_cmp
        let p50 = h.quantile(0.5);
        assert!(p50 >= 0.1);
    }

    #[test]
    fn occupancy_weighted_tps() {
        let m = Metrics::default();
        m.start_clock();
        m.slots_total.set(8);
        m.slot_busy_seconds.add_secs(2.0);
        m.tokens_generated.add(64);
        assert!((m.tps_per_busy_slot() - 32.0).abs() < 1e-9);
        assert!(m.slot_occupancy() <= 1.0);
    }
}
