//! Reproduce the paper's §4 observations interactively: confidence
//! variation (Fig. 1) and intermediate-tensor variation (Fig. 2) during
//! generation, printed as ASCII distributions.
//!
//! Run: `cargo run --release --example observe_dynamics -- [--groups 2]`

use esdllm::analysis::{frac_above, histogram, observe_generation, PROBE_TENSORS};
use esdllm::cli::Args;
use esdllm::runtime::Runtime;

fn bar(count: usize, total: usize) -> String {
    let w = (60 * count + total / 2) / total.max(1);
    "#".repeat(w)
}

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let arch = args.str("arch", "llada-nano");
    let groups = args.usize("groups", 2);

    let rt = Runtime::load_default()?;
    println!("collecting dynamics over {groups} batches of 8 (vanilla decode)...");
    let stats = observe_generation(&rt, &arch, groups)?;

    // Fig 1b analog: distribution of |Δconfidence|
    let bins = [0.001f32, 0.005, 0.01, 0.05, 0.1, 0.3, 0.6];
    let all_conf: Vec<f32> =
        stats.records.iter().flat_map(|r| r.conf_delta.iter().cloned()).collect();
    let h = histogram(all_conf.iter().cloned(), &bins);
    let total: usize = h.iter().sum();
    println!("\n|Δconfidence| distribution ({} samples):", total);
    let mut lo = 0.0f32;
    for (i, c) in h.iter().enumerate() {
        let hi = bins.get(i).copied().unwrap_or(f32::INFINITY);
        println!("  [{lo:>6.3}, {hi:>6.3})  {:>7}  {}", c, bar(*c, total));
        lo = hi;
    }

    // Fig 1c analog: fraction of positions with Δconf > 0.05 per iteration
    let frac = frac_above(&stats, 0.05);
    println!("\nfraction of positions with |Δconf| > 0.05 by iteration:");
    for (i, f) in frac.iter().enumerate() {
        if i % 4 == 0 {
            println!("  iter {i:>3}: {:>5.1}%  {}", f * 100.0,
                     bar((f * 600.0) as usize, 600));
        }
    }

    // Fig 2b analog: hidden-state variation distribution at each probe layer
    for (pi, layer) in stats.probe_layers.iter().enumerate() {
        let vals: Vec<f32> = stats
            .records
            .iter()
            .flat_map(|r| r.var[pi][0].iter().cloned())
            .collect();
        let h = histogram(vals.iter().cloned(), &bins);
        let total: usize = h.iter().sum();
        let small = vals.iter().filter(|v| **v < 0.05).count();
        println!(
            "\nhidden-state variation, layer {layer}: {:.1}% of positions < 0.05",
            100.0 * small as f64 / vals.len().max(1) as f64
        );
        let mut lo = 0.0f32;
        for (i, c) in h.iter().enumerate() {
            let hi = bins.get(i).copied().unwrap_or(f32::INFINITY);
            println!("  [{lo:>6.3}, {hi:>6.3})  {:>7}  {}", c, bar(*c, total));
            lo = hi;
        }
    }

    // per-tensor summary (Fig 5 analog)
    println!("\nmean variation by probe tensor (layer {}):", stats.probe_layers[0]);
    for (ti, name) in PROBE_TENSORS.iter().enumerate() {
        let vals: Vec<f32> = stats
            .records
            .iter()
            .flat_map(|r| r.var[0][ti].iter().cloned())
            .collect();
        let mean: f64 =
            vals.iter().map(|v| *v as f64).sum::<f64>() / vals.len().max(1) as f64;
        println!("  {name:>6}: {mean:.4}");
    }
    Ok(())
}
