"""AOT compilation: lower every executable variant to HLO text and write
the artifact manifest the Rust runtime consumes.

Run via `make artifacts` (after training has produced the checkpoints; the
lowering itself is weight-free — parameters are runtime inputs in the
canonical `modelcfg.param_specs` order).

Artifact layout:

    artifacts/
      manifest.json            executable + parameter signatures
      vocab.json               tokenizer table
      weights-<arch>-<ckpt>.bin
      <arch>/<exe>.hlo.txt     HLO text per executable variant
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp

from . import tasks
from .modelcfg import (ARCHS, SKIP_CONFIGS, ModelCfg, cfg_to_json,
                       final_keep, param_specs)
from . import model as M
from .xlc import lower_to_hlo_text

CACHE_DT = "bf16"
OBSERVE_PROBES = [2, 5, 7]   # paper layers 10/20/30 of 32 → nano 8-layer map
SPARSE_KEEP_PROMPT = 24     # retention ratio 0.5 over the prompt region
# live-context tiers (absolute kv lengths, prompt + live gen blocks):
# the scheduler steps the batch class down these as the group's live
# frontier shrinks, so attention/KV-scatter/confidence only cover live
# rows. Every tier is a block-8 multiple past the prompt; the last tier
# is the full compiled context (the untiered executables).
CTX_TIER_GEN = (8, 16, 24)   # live gen lengths with dedicated variants


def sds(shape, dt):
    return jax.ShapeDtypeStruct(shape, dt)


def param_structs(cfg):
    return [sds(shape, jnp.float32) for _, shape in param_specs(cfg)]


def io_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _dt(dtype):
    return {
        jnp.float32.dtype: "f32",
        jnp.int32.dtype: "i32",
        jnp.bfloat16.dtype: "bf16",
    }[jnp.dtype(dtype)]


class Builder:
    def __init__(self, cfg: ModelCfg, out_dir: str, force: bool):
        self.cfg = cfg
        self.dir = os.path.join(out_dir, cfg.name)
        os.makedirs(self.dir, exist_ok=True)
        self.force = force
        self.executables = {}
        self.params = param_structs(cfg)
        self.param_io = [
            io_entry(name, shape, "f32") for name, shape in param_specs(cfg)
        ]

    def lower(self, exe_name, fn, extra_args, meta):
        """Lower fn(params..., *extra_args) and record the manifest entry."""
        cfg = self.cfg
        path = os.path.join(self.dir, f"{exe_name}.hlo.txt")
        rel = os.path.join(cfg.name, f"{exe_name}.hlo.txt")

        def wrapper(*flat):
            params = M.params_from_flat(cfg, flat[: len(self.params)])
            return fn(params, *flat[len(self.params):])

        t0 = time.time()
        if self.force or not os.path.exists(path):
            text = lower_to_hlo_text(wrapper, *self.params, *extra_args)
            with open(path, "w") as f:
                f.write(text)
            status = f"lowered in {time.time() - t0:.1f}s ({len(text)} chars)"
        else:
            status = "cached"

        # record output signature by abstract evaluation
        out = jax.eval_shape(wrapper, *self.params, *extra_args)
        outputs = [
            io_entry(f"out{i}", o.shape, _dt(o.dtype))
            for i, o in enumerate(jax.tree.leaves(out))
        ]
        inputs = list(self.param_io) + [
            io_entry(n, a.shape, _dt(a.dtype))
            for n, a in zip(meta["input_names"], extra_args)
        ]
        entry = dict(meta)
        entry.pop("input_names")
        entry.update({"file": rel, "inputs": inputs, "outputs": outputs})
        self.executables[exe_name] = entry
        print(f"  [{cfg.name}] {exe_name}: {status}", flush=True)


def build_arch(cfg: ModelCfg, out_dir: str, force: bool, full: bool):
    b = Builder(cfg, out_dir, force)
    ctx, gen, blk_cfgs = cfg.ctx, cfg.gen_len, (8, 32)
    L, Hkv, hd, d, V = (cfg.n_layers, cfg.n_kv_heads, cfg.head_dim,
                        cfg.d_model, cfg.vocab)

    def kv_s(batch, t):
        return sds((L, 2, batch, Hkv, t, hd), jnp.bfloat16)

    def ind_s(batch, n_ind, g=gen):
        return sds((n_ind, batch, g, d), jnp.bfloat16)

    # ---- prefill (vanilla step / cache init / every refresh) ----
    # The logit output is the gen-region slice (`logits_gen` [B, gen, V],
    # sliced in-graph): the runtime's merges only ever read the gen rows,
    # so the prompt-region rows of the Host-fallback full forwards stay
    # off the bus exactly like the device-apply prefill's. The new
    # signature name makes a stale runtime fail loudly at output lookup
    # instead of mis-slicing rows.
    for batch in (1, 8):
        b.lower(
            f"prefill_b{batch}",
            functools.partial(M.prefill, cfg, logits_gen=True),
            [sds((batch, ctx), jnp.int32)],
            {
                "kind": "prefill", "batch": batch, "block": None,
                "skip": [], "indicator": None, "kv_len": ctx,
                "input_names": ["tokens"],
                "output_names": ["logits_gen", "kv", "ind_h", "ind_q",
                                 "ind_k", "ind_v", "attn_mass"],
            },
        )

    # ---- vanilla step: full forward, logits only (the baseline never
    # reads caches, so don't make it pay for cache downloads — and its
    # downlink is gen-region-sliced like every other full forward) ----
    def vanilla_fn(params, tokens):
        logits_gen, _, _, _ = M.prefill(cfg, params, tokens, logits_gen=True)
        return (logits_gen,)

    for batch in (1, 8):
        b.lower(
            f"vanilla_b{batch}",
            vanilla_fn,
            [sds((batch, ctx), jnp.int32)],
            {
                "kind": "prefill", "batch": batch, "block": None,
                "skip": [], "indicator": None, "kv_len": ctx,
                "input_names": ["tokens"],
                "output_names": ["logits_gen"],
            },
        )

    # ---- observation forward (figures) ----
    b.lower(
        "observe_b8",
        functools.partial(M.observe, cfg, probe_layers=OBSERVE_PROBES),
        [sds((8, ctx), jnp.int32)],
        {
            "kind": "observe", "batch": 8, "block": None, "skip": [],
            "indicator": None, "kv_len": ctx, "probe_layers": OBSERVE_PROBES,
            "input_names": ["tokens"],
            "output_names": ["logits", "probes"],
        },
    )

    # ---- decode steps ----
    def step_variant(name, batch, block, skip, indicator, kv_len,
                     ind_layers=None):
        skip_layers = sorted(l for l, _ in skip)
        # DualCache/refresh variants (skip=[]) maintain the indicator cache
        # for ALL layers so any ES config sees fresh indicators after a
        # block refresh; ES variants maintain only their own skip layers.
        if ind_layers is None:
            ind_layers = skip_layers if skip else list(range(cfg.n_layers))
        n_ind = max(1, len(ind_layers))
        fn = functools.partial(
            M.step, cfg, block=block, skip=skip,
            indicator=indicator or "h", ind_layers=ind_layers, kv_len=kv_len)
        b.lower(
            name, fn,
            [
                sds((batch, block), jnp.int32),        # x_tok
                sds((), jnp.int32),                    # block_start
                kv_s(batch, kv_len),                   # kv cache
                ind_s(batch, n_ind),                   # indicator cache
                sds((batch, gen), jnp.float32),        # conf
                sds((), jnp.float32),                  # alpha
            ],
            {
                "kind": "step", "batch": batch, "block": block,
                "skip": [[l, r] for l, r in skip],
                "skip_layers": skip_layers,
                "ind_layers": ind_layers,
                "final_keep": final_keep(block, skip),
                "indicator": indicator or "h", "kv_len": kv_len,
                "input_names": ["x_tok", "block_start", "kv", "ind",
                                "conf", "alpha"],
                "output_names": ["logits", "pos", "kv_block", "ind_block"],
            },
        )

    # ---- device-apply variants: the executable scatters its own KV and
    # indicator updates into the resident cache tensors in-graph
    # (dynamic-update-slice), merges confidence computed from its logits,
    # and takes the occupancy mask as a batch-bit input. The Rust runtime
    # retains the kv/ind/conf outputs on device and feeds them back as the
    # next call's inputs (manifest `retained_outputs`), so in steady state
    # only block tokens go up and gen-region logit rows come down.
    # `"alias": true` additionally declares the chain as a PJRT
    # input-output alias: the runtime configures donation at compile time
    # so the cache update is genuinely in-place on device (one live copy
    # per chained tensor, no transient second buffer during execution). ----
    CHAINED = [
        {"output": n, "input": n, "alias": True} for n in ("kv", "ind", "conf")
    ]

    def tier_meta(gen_live):
        """Manifest fields of a live-context tier variant: gen_live < gen
        marks a suffix-pruned executable whose chained state covers only
        prompt + gen_live rows (kv_len = prompt + gen_live)."""
        if gen_live == gen:
            return {"kv_len": ctx}
        return {"kv_len": cfg.prompt_len + gen_live, "gen_live": gen_live}

    def tier_suffix(gen_live):
        return "" if gen_live == gen else f"_ctx{cfg.prompt_len + gen_live}"

    def prefill_apply_variant(batch, gen_live=gen):
        t = cfg.prompt_len + gen_live

        def fn(params, tokens, kv_prev, ind_prev, conf_prev, refresh):
            return M.prefill_apply(cfg, params, tokens, kv_prev, ind_prev,
                                   conf_prev, refresh, indicator="h")

        b.lower(
            f"prefill_apply_b{batch}{tier_suffix(gen_live)}",
            fn,
            [
                sds((batch, t), jnp.int32),            # tokens (live rows)
                kv_s(batch, t),                        # kv (chained)
                ind_s(batch, L, gen_live),             # ind "h" (chained)
                sds((batch, gen_live), jnp.float32),   # conf (chained)
                sds((batch,), jnp.int32),              # refresh mask
            ],
            {
                "kind": "prefill_apply", "batch": batch, "block": None,
                "skip": [], "indicator": "h", **tier_meta(gen_live),
                "retained_outputs": CHAINED,
                "input_names": ["tokens", "kv", "ind", "conf", "refresh"],
                # logits_gen, not logits: the output is the [B, gen, V]
                # gen-region slice — a new signature name so a runtime
                # built against the full-context contract fails loudly
                # at output_index() instead of mis-slicing rows
                "output_names": ["logits_gen", "kv", "ind", "conf"],
            },
        )

    def prefill_apply_blk_variant(batch, block, gen_live=gen):
        t = cfg.prompt_len + gen_live

        def fn(params, tokens, kv_prev, ind_prev, conf_prev, refresh,
               blk_start, _block=block):
            return M.prefill_apply_blk(cfg, params, tokens, kv_prev,
                                       ind_prev, conf_prev, refresh,
                                       blk_start, block=_block,
                                       indicator="h")

        b.lower(
            f"prefill_apply_blk{block}_b{batch}{tier_suffix(gen_live)}",
            fn,
            [
                sds((batch, t), jnp.int32),            # tokens (live rows)
                kv_s(batch, t),                        # kv (chained)
                ind_s(batch, L, gen_live),             # ind "h" (chained)
                sds((batch, gen_live), jnp.float32),   # conf (chained)
                sds((batch,), jnp.int32),              # refresh mask
                sds((batch,), jnp.int32),              # per-slot blk start
            ],
            {
                "kind": "prefill_apply", "batch": batch, "block": block,
                "skip": [], "indicator": "h", **tier_meta(gen_live),
                "retained_outputs": CHAINED,
                "input_names": ["tokens", "kv", "ind", "conf", "refresh",
                                "blk_start"],
                # logits_blk: each slot's current [block, V] window only
                # (gathered in-graph from the per-slot blk_start input) —
                # block/gen of the logits_gen downlink per grounding
                # prefill
                "output_names": ["logits_blk", "kv", "ind", "conf"],
            },
        )

    def step_apply_variant(name, batch, block, skip, gen_live=gen):
        skip_layers = sorted(l for l, _ in skip)
        ind_layers = skip_layers if skip else list(range(cfg.n_layers))
        t = cfg.prompt_len + gen_live

        def fn(params, x_tok, block_start, kv, ind, conf, occ, alpha,
               _skip=skip, _ind_layers=ind_layers, _block=block, _t=t):
            return M.step(cfg, params, x_tok, block_start, kv, ind, conf,
                          alpha, block=_block, skip=_skip, indicator="h",
                          ind_layers=_ind_layers, kv_len=_t, apply=True,
                          occ=occ)

        b.lower(
            name,
            fn,
            [
                sds((batch, block), jnp.int32),        # x_tok
                sds((), jnp.int32),                    # block_start
                kv_s(batch, t),                        # kv cache (chained)
                ind_s(batch, L, gen_live),             # full ind (chained)
                sds((batch, gen_live), jnp.float32),   # conf (chained)
                sds((batch,), jnp.int32),              # occupancy mask
                sds((), jnp.float32),                  # alpha
            ],
            {
                "kind": "step_apply", "batch": batch, "block": block,
                "skip": [[l, r] for l, r in skip],
                "skip_layers": skip_layers,
                "ind_layers": ind_layers,
                "final_keep": final_keep(block, skip),
                "indicator": "h", **tier_meta(gen_live),
                "retained_outputs": CHAINED,
                "input_names": ["x_tok", "block_start", "kv", "ind",
                                "conf", "occ", "alpha"],
                "output_names": ["logits", "pos", "kv", "ind", "conf"],
            },
        )

    def step_applyk_variant(name, batch, block, skip, k, gen_live=gen):
        skip_layers = sorted(l for l, _ in skip)
        ind_layers = skip_layers if skip else list(range(cfg.n_layers))
        t = cfg.prompt_len + gen_live

        def fn(params, x_tok, block_start, kv, ind, conf, occ, alpha,
               threshold, tok_seed, _skip=skip, _ind_layers=ind_layers,
               _block=block, _k=k):
            return M.step_k(cfg, params, x_tok, block_start, kv, ind,
                            conf, occ, alpha, threshold, tok_seed, k=_k,
                            block=_block, skip=_skip, mask_id=tasks.MASK,
                            eos_id=tasks.EOS, indicator="h",
                            ind_layers=_ind_layers)

        b.lower(
            name,
            fn,
            [
                sds((batch, block), jnp.int32),        # x_tok
                sds((), jnp.int32),                    # block_start
                kv_s(batch, t),                        # kv cache (chained)
                ind_s(batch, L, gen_live),             # full ind (chained)
                sds((batch, gen_live), jnp.float32),   # conf (chained)
                sds((batch,), jnp.int32),              # occupancy mask
                sds((), jnp.float32),                  # alpha
                sds((), jnp.float32),                  # threshold
                sds((2, batch, block), jnp.int32),     # tok_seed
            ],
            {
                "kind": "step_apply_k", "batch": batch, "block": block,
                "k": k,
                "skip": [[l, r] for l, r in skip],
                "skip_layers": skip_layers,
                "ind_layers": ind_layers,
                "final_keep": final_keep(block, skip),
                "indicator": "h", **tier_meta(gen_live),
                "retained_outputs": CHAINED,
                "input_names": ["x_tok", "block_start", "kv", "ind",
                                "conf", "occ", "alpha", "threshold",
                                "tok_seed"],
                "output_names": ["logits", "pos", "kv", "ind", "conf",
                                 "committed", "commit_pos", "commit_tok"],
            },
        )

    default_skip = SKIP_CONFIGS["default"]
    sparse_len = SPARSE_KEEP_PROMPT + gen

    # DualCache baseline + ES default, dense (host-apply and device-apply)
    for blk in blk_cfgs:
        for batch in ((1, 8) if blk == 8 else (8,)):
            step_variant(f"dual_blk{blk}_b{batch}", batch, blk, [], None, ctx)
            step_variant(f"es_blk{blk}_b{batch}", batch, blk,
                         default_skip, "h", ctx)
            step_apply_variant(f"dual_apply_blk{blk}_b{batch}", batch, blk, [])
            step_apply_variant(f"es_apply_blk{blk}_b{batch}", batch, blk,
                               default_skip)
    # fused k-step ES variants: k consecutive early-skip iterations
    # unrolled in-graph, greedy/threshold unmask between inner
    # iterations; one dispatch replaces k (the scheduler floors its
    # fused depth to one of these compiled ks)
    for kk in (2, 4, 8):
        for blk in blk_cfgs:
            for batch in ((1, 8) if blk == 8 else (8,)):
                step_applyk_variant(f"es_applyk{kk}_blk{blk}_b{batch}",
                                    batch, blk, default_skip, kk)
    for batch in (1, 8):
        prefill_apply_variant(batch)
        prefill_apply_blk_variant(batch, 8)

    # ---- live-context tier family: the same device-apply executables
    # lowered at kv_len = prompt + gen_live for each tier, so a batch
    # class whose live frontier has shrunk runs attention/scatter/conf
    # over live rows only. Block-8 only (the live frontier moves in
    # block-8 steps); fused variants at the serving batch. ----
    for gl in CTX_TIER_GEN:
        for batch in (1, 8):
            prefill_apply_variant(batch, gen_live=gl)
            prefill_apply_blk_variant(batch, 8, gen_live=gl)
            sfx = tier_suffix(gl)
            step_apply_variant(f"dual_apply_blk8_b{batch}{sfx}", batch, 8,
                               [], gen_live=gl)
            step_apply_variant(f"es_apply_blk8_b{batch}{sfx}", batch, 8,
                               default_skip, gen_live=gl)
        for kk in (2, 4, 8):
            step_applyk_variant(f"es_applyk{kk}_blk8_b8{tier_suffix(gl)}",
                                8, 8, default_skip, kk, gen_live=gl)

    # sparse-attention variants (pruned prompt KV)
    for blk in blk_cfgs:
        step_variant(f"dual_sp_blk{blk}_b8", 8, blk, [], None, sparse_len)
        step_variant(f"es_sp_blk{blk}_b8", 8, blk, default_skip, "h",
                     sparse_len)

    if full:
        # skip ratio / position ablations (Tables 9 & 10) — llada only
        for name in ("r2_only_25", "r2_only_50", "r2_only_75", "r0_only_50",
                     "r1_only_50", "r4_only_50", "r1_only_70", "triple_405"):
            step_variant(f"es_{name}_blk32_b8", 8, 32,
                         SKIP_CONFIGS[name], "h", ctx)
        for name in ("r1_only_70", "triple_405"):
            step_variant(f"es_{name}_blk8_b8", 8, 8,
                         SKIP_CONFIGS[name], "h", ctx)
        # variation-indicator ablation (Figure 4b): ES variants plus the
        # matching block-refresh (dual) variants keeping that indicator's
        # cache fresh
        for ind in ("q", "k", "v"):
            step_variant(f"es_ind_{ind}_blk8_b8", 8, 8,
                         default_skip, ind, ctx)
            step_variant(f"dual_ind_{ind}_blk8_b8", 8, 8, [], ind, ctx)

    return {
        "dims": cfg_to_json(cfg),
        "checkpoints": {
            ck: f"weights-{cfg.name}-{ck}.bin" for ck in ("instruct", "base")
        },
        "params": [
            {"name": n, "shape": list(s)} for n, s in param_specs(cfg)
        ],
        "executables": b.executables,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--arch", choices=list(ARCHS) + ["all"], default="all")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    tasks.write_vocab_json(os.path.join(args.out, "vocab.json"))

    manifest = {
        "version": 1,
        "generation": {
            "prompt_len": 48, "gen_len": 32, "ctx": 80,
            "vocab": tasks.VOCAB,
            "pad": tasks.PAD, "mask": tasks.MASK,
            "eos": tasks.EOS, "bos": tasks.BOS,
            "sparse_keep_prompt": SPARSE_KEEP_PROMPT,
            "observe_probe_layers": OBSERVE_PROBES,
            # live-context tiers (absolute kv lengths, ascending; the
            # last tier is the full compiled context). The scheduler
            # picks the smallest tier covering the group's live frontier.
            "ctx_tiers": sorted(48 + g for g in CTX_TIER_GEN) + [80],
        },
        "archs": {},
    }
    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    for name in archs:
        cfg = ARCHS[name]
        # the ablation grid only exists for the llada arch (paper §6.3)
        manifest["archs"][name] = build_arch(
            cfg, args.out, args.force, full=(name == "llada-nano"))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with "
          f"{sum(len(a['executables']) for a in manifest['archs'].values())} "
          f"executables", flush=True)


if __name__ == "__main__":
    main()
