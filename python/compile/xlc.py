"""stablehlo → HLO-text conversion helpers.

HLO *text* is the interchange format between the build path (jax) and the
request path (the Rust `xla` crate on xla_extension 0.5.1):

  * jax ≥ 0.5 serialized HloModuleProtos carry 64-bit instruction ids the
    0.5.1 runtime rejects (`proto.id() <= INT_MAX`); the text parser
    reassigns ids and round-trips cleanly.
  * `jax.lax.top_k` lowers to a `topk(...), largest=true` op the 0.5.1
    text parser cannot parse — the model uses argsort-based top-k instead
    (see model.argsort_topk).  This module asserts no `topk(` leaks in.
"""

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, *example_args) -> str:
    """Lower a jax-jittable fn at the example argument shapes to HLO text
    (root tupled — the Rust side decomposes the result tuple)."""
    # keep_unused: the manifest promises a fixed input signature; variants
    # that ignore an input (e.g. DualCache ignores conf/alpha) must still
    # accept it
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    if " topk(" in text:
        raise RuntimeError(
            "lowered HLO contains a `topk` op which xla_extension 0.5.1 "
            "cannot parse; use model.argsort_topk instead of jax.lax.top_k"
        )
    return text
