//! `log` facade backend: timestamped stderr logger with env-controlled
//! level (`ESDLLM_LOG=debug|info|warn|error`, default info).

use log::{Level, LevelFilter, Metadata, Record};
use std::time::{SystemTime, UNIX_EPOCH};

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
        eprintln!(
            "[{:>10.3} {:5} {}] {}",
            t.as_secs_f64() % 100_000.0,
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent: returns false if one is already set).
pub fn init() -> bool {
    let level = match std::env::var("ESDLLM_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let ok = log::set_boxed_logger(Box::new(StderrLogger { level })).is_ok();
    if ok {
        log::set_max_level(LevelFilter::Trace);
    }
    ok
}
