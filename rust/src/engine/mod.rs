//! The decode engine: the run-to-completion façade over the slot
//! scheduler.
//!
//! One [`Engine`] drives one batched sequence group through the masked-
//! diffusion denoising loop. Since the continuous-batching refactor the
//! per-iteration machinery lives in [`crate::scheduler`]: the engine
//! builds a [`crate::scheduler::PjrtBackend`] over the compiled
//! executables, admits every prompt into a
//! [`crate::scheduler::GroupScheduler`], and ticks the group until all
//! sequences retire. Each iteration the scheduler chooses per sequence
//! between:
//!
//!   * `Prefill`  — full forward (vanilla step / prompt refresh / block
//!                  grounding); refreshes the requesting slots' caches,
//!   * `DualStep` — full-block step against cached outside-KV (DualCache's
//!                  per-iteration op; ES-dLLM's block refresh),
//!   * `EsStep`   — the early-skip step (Algorithm 1): the executable
//!                  computes importance scores in-graph, returns logits
//!                  only for the surviving positions, and the backend
//!                  merges them into the latest-logits state (skipped
//!                  positions keep their previous logits/confidence).
//!
//! Sampling (low-confidence remask / maskgit-plus), parallel decoding,
//! the EOS guard, sparse-KV selection, and all cache plumbing sit behind
//! the scheduler. Unlike the pre-refactor engine, a sequence whose
//! output is fully determined (EOS guard) retires at the next block
//! boundary instead of riding along until the whole group drains.
//! Python is never on this path.

use anyhow::{anyhow, Result};

use crate::cache::{RefreshPolicy, StepPlan};
use crate::fault::FaultPlan;
use crate::runtime::Runtime;
use crate::sampler::SamplerCfg;
use crate::scheduler::{FinishedSeq, GroupScheduler, PjrtBackend, SchedCfg, SeqInput, SeqParams};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// full recomputation every iteration (the LLaDA/Dream baseline)
    Vanilla,
    /// Fast-dLLM DualCache: cached outside-KV, full block per iteration
    DualCache,
    /// this paper: DualCache + early-skipping inside the block
    EsDllm,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Vanilla => "vanilla",
            Method::DualCache => "DualCache",
            Method::EsDllm => "ES-dLLM",
        }
    }
}

#[derive(Debug, Clone)]
pub struct EngineCfg {
    pub arch: String,
    pub checkpoint: String,
    pub method: Method,
    pub block: usize,
    pub refresh: RefreshPolicy,
    /// Eq. 1 mixing weight
    pub alpha: f32,
    pub sampler: SamplerCfg,
    /// prompt-KV pruning (Sparse-dLLM integration)
    pub sparse: bool,
    /// variation indicator: "h" | "q" | "k" | "v"
    pub indicator: String,
    /// override the ES step executable (ablation variants)
    pub es_exe_override: Option<String>,
    /// adaptive skip ratio (paper §7 future work): pick the skip-ratio
    /// variant each iteration from the observed confidence drift —
    /// aggressive skipping while the iterate is quiescent, conservative
    /// when it is moving. Requires the ratio-variant executables
    /// (compiled for llada-nano at block 32).
    pub adaptive: bool,
    /// fused k-step dispatch depth: when > 1 (and the config is
    /// device-apply eligible with a greedy sampler), runs of consecutive
    /// ES iterations are dispatched as one `step_apply_k` execution that
    /// unrolls up to `fused_k` diffusion iterations in-graph. 1 = one
    /// execution per iteration (the unfused baseline). EOS retirement
    /// and block-boundary admission are host-side checks, so they happen
    /// every fused run rather than every iteration — larger k amortizes
    /// more dispatch latency but coarsens that cadence.
    pub fused_k: usize,
    pub seed: u64,
    /// deterministic fault-injection schedule (`--fault-plan`; empty =
    /// no faults). Drives the backend's [`crate::fault::FaultInjector`]
    /// so every recovery path is testable offline — see
    /// [`crate::fault`].
    pub fault_plan: FaultPlan,
}

impl EngineCfg {
    pub fn new(arch: &str, method: Method) -> EngineCfg {
        EngineCfg {
            arch: arch.to_string(),
            checkpoint: "instruct".to_string(),
            method,
            block: 8,
            refresh: RefreshPolicy { prompt_period: 16, block_period: 4 },
            alpha: 0.5,
            sampler: if arch.starts_with("dream") {
                SamplerCfg::dream()
            } else {
                SamplerCfg::llada()
            },
            sparse: false,
            indicator: "h".to_string(),
            es_exe_override: None,
            adaptive: false,
            fused_k: 1,
            seed: 0,
            fault_plan: FaultPlan::default(),
        }
    }
}

/// Adaptive-ratio policy (future-work extension): map the mean
/// |Δconfidence| observed at the last computed iteration to a compiled
/// skip-ratio variant. Quiescent iterate → skip harder.
pub fn adaptive_es_exe(block: usize, batch: usize, mean_conf_delta: f32) -> String {
    let variant = if mean_conf_delta < 0.01 {
        "es_r2_only_75" // aggressive: keep only 25% past layer 2
    } else if mean_conf_delta < 0.05 {
        return format!("es_blk{block}_b{batch}"); // default r1=r2=0.5
    } else {
        "es_r2_only_25" // conservative: keep 75%
    };
    format!("{variant}_blk{block}_b{batch}")
}

/// Outcome of one batched group generation.
#[derive(Debug, Clone)]
pub struct GroupResult {
    pub texts: Vec<String>,
    /// scheduler ticks (group iterations) this generation took
    pub iterations: usize,
    pub tokens_generated: usize,
    pub wall_s: f64,
    /// executable-run counts by plan, for FLOPs accounting
    pub n_prefill: usize,
    pub n_dual: usize,
    pub n_es: usize,
}

/// Name of the step executable for `cfg` and the given plan at batch
/// `batch`. `conf_drift` selects the adaptive skip-ratio variant (pass
/// anything when `cfg.adaptive` is off).
pub fn step_exe_name(cfg: &EngineCfg, plan: StepPlan, batch: usize, conf_drift: f32) -> String {
    let blk = cfg.block;
    let ind = cfg.indicator.as_str();
    match plan {
        StepPlan::Prefill => unreachable!("prefill executables are not step plans"),
        StepPlan::DualStep => {
            if cfg.sparse {
                format!("dual_sp_blk{blk}_b{batch}")
            } else if ind != "h" {
                format!("dual_ind_{ind}_blk{blk}_b{batch}")
            } else {
                format!("dual_blk{blk}_b{batch}")
            }
        }
        StepPlan::EsStep => {
            if let Some(name) = &cfg.es_exe_override {
                name.clone()
            } else if cfg.adaptive {
                adaptive_es_exe(blk, batch, conf_drift)
            } else if cfg.sparse {
                format!("es_sp_blk{blk}_b{batch}")
            } else if ind != "h" {
                format!("es_ind_{ind}_blk{blk}_b{batch}")
            } else {
                format!("es_blk{blk}_b{batch}")
            }
        }
    }
}

/// Name of the device-apply step executable for (plan, block, batch) —
/// the in-graph-scatter variants compiled alongside the dense dual/es
/// steps.
pub fn apply_step_exe_name(plan: StepPlan, block: usize, batch: usize) -> String {
    match plan {
        StepPlan::Prefill => unreachable!("prefill executables are not step plans"),
        StepPlan::DualStep => format!("dual_apply_blk{block}_b{batch}"),
        StepPlan::EsStep => format!("es_apply_blk{block}_b{batch}"),
    }
}

/// Name of the device-apply prefill executable at `batch`.
pub fn prefill_apply_exe_name(batch: usize) -> String {
    format!("prefill_apply_b{batch}")
}

/// Name of the block-sliced device-apply prefill executable: takes a
/// per-slot block-index input and downloads `[B, block, V]` logit
/// windows instead of the whole gen region.
pub fn prefill_apply_blk_exe_name(block: usize, batch: usize) -> String {
    format!("prefill_apply_blk{block}_b{batch}")
}

/// Name of the fused k-step executable (`step_apply_k` kind) that runs
/// `k` ES iterations in one device execution. The compile pipeline
/// emits k ∈ {2, 4, 8} alongside the single-step apply variants.
pub fn fused_step_exe_name(k: usize, block: usize, batch: usize) -> String {
    format!("es_applyk{k}_blk{block}_b{batch}")
}

/// The unroll depths the compile pipeline emits fused variants for,
/// largest first (the backend picks the deepest one that fits a run).
pub const FUSED_KS: [usize; 3] = [8, 4, 2];

/// Whether this configuration can run the device-apply decode path:
/// the default dense ES/DualCache pipeline with the "h" indicator. The
/// fallbacks (sparse attention, indicator ablations, adaptive skip
/// ratios, executable overrides, the cache-free vanilla baseline) have
/// no compiled apply variants and stay on the Host-apply path.
pub fn device_apply_eligible(cfg: &EngineCfg) -> bool {
    cfg.method != Method::Vanilla
        && !cfg.sparse
        && !cfg.adaptive
        && cfg.indicator == "h"
        && cfg.es_exe_override.is_none()
}

pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub cfg: EngineCfg,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: EngineCfg) -> Engine<'rt> {
        Engine { rt, cfg }
    }

    /// Compile every executable this configuration can touch at batch
    /// size `batch`, so the first timed generation doesn't pay PJRT
    /// compilation (5–7 s per module) inside the measurement window.
    pub fn precompile(&mut self, batch: usize) -> Result<()> {
        let arch = self.rt.arch(&self.cfg.arch)?.clone();
        let mut names = vec![format!("prefill_b{batch}")];
        if self.cfg.method == Method::Vanilla {
            names = vec![format!("vanilla_b{batch}")];
        } else {
            names.push(step_exe_name(&self.cfg, StepPlan::DualStep, batch, 1.0));
            if self.cfg.method == Method::EsDllm {
                if self.cfg.adaptive {
                    for drift in [0.001f32, 0.02, 0.2] {
                        names.push(adaptive_es_exe(self.cfg.block, batch, drift));
                    }
                } else {
                    names.push(step_exe_name(&self.cfg, StepPlan::EsStep, batch, 1.0));
                }
            }
        }
        for name in names {
            let exe = arch.exe(&name)?;
            self.rt.executable(&arch, exe)?;
        }
        // the device-apply chain variants, when this config is eligible
        // and the artifacts carry them (older artifact sets may not)
        if device_apply_eligible(&self.cfg) {
            let mut apply_names = vec![
                prefill_apply_exe_name(batch),
                apply_step_exe_name(StepPlan::DualStep, self.cfg.block, batch),
                apply_step_exe_name(StepPlan::EsStep, self.cfg.block, batch),
            ];
            if self.cfg.fused_k > 1 {
                apply_names.extend(
                    FUSED_KS
                        .iter()
                        .filter(|&&k| k <= self.cfg.fused_k)
                        .map(|&k| fused_step_exe_name(k, self.cfg.block, batch)),
                );
            }
            for name in apply_names {
                if let Ok(exe) = arch.exe(&name) {
                    self.rt.executable(&arch, exe)?;
                }
            }
        }
        self.rt.checkpoint_params(&arch, &self.cfg.checkpoint)?;
        Ok(())
    }

    /// Generate completions for up to `batch` prompts: admit every
    /// prompt into a slot scheduler and tick the group until all
    /// sequences retire. Sequences that finish early (EOS guard) retire
    /// at their block boundary instead of riding until the group drains.
    pub fn generate(&mut self, prompts: &[String]) -> Result<GroupResult> {
        let arch = self.rt.arch(&self.cfg.arch)?.clone();
        let gen = arch.dims.gen_len;
        let block = self.cfg.block;
        if block == 0 || gen % block != 0 {
            return Err(anyhow!("gen_len {gen} not divisible by block {block}"));
        }
        // batch-size class: the core executables exist for b in {1, 8};
        // sparse / indicator / ablation variants are compiled at b=8 only
        let b1_ok = !self.cfg.sparse
            && self.cfg.indicator == "h"
            && self.cfg.es_exe_override.is_none();
        let batch = if prompts.len() <= 1 && b1_ok { 1 } else { 8 };
        if prompts.len() > batch {
            return Err(anyhow!("group of {} exceeds max batch {batch}", prompts.len()));
        }

        let backend = PjrtBackend::new(self.rt, self.cfg.clone(), batch)?;
        let mut sched =
            GroupScheduler::new(Box::new(backend), batch, SchedCfg::from_engine(&self.cfg))?;
        let t0 = std::time::Instant::now();
        for (i, prompt) in prompts.iter().enumerate() {
            sched.admit(SeqInput {
                id: i as u64,
                prompt: prompt.clone(),
                params: SeqParams::default(),
                submitted: t0,
            })?;
        }
        let mut done: Vec<Option<FinishedSeq>> = vec![None; prompts.len()];
        while sched.active() > 0 {
            for f in sched.tick()? {
                done[f.id as usize] = Some(f);
            }
        }
        let mut result = GroupResult {
            texts: Vec::with_capacity(prompts.len()),
            iterations: sched.ticks,
            tokens_generated: 0,
            wall_s: t0.elapsed().as_secs_f64(),
            n_prefill: sched.n_prefill,
            n_dual: sched.n_dual,
            n_es: sched.n_es,
        };
        for f in done {
            let f = f.expect("every admitted sequence retires");
            result.tokens_generated += f.tokens;
            result.texts.push(f.text);
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_labels() {
        assert_eq!(Method::Vanilla.label(), "vanilla");
        assert_eq!(Method::DualCache.label(), "DualCache");
        assert_eq!(Method::EsDllm.label(), "ES-dLLM");
    }

    #[test]
    fn default_cfg_matches_arch_family() {
        let l = EngineCfg::new("llada-nano", Method::EsDllm);
        assert!(matches!(
            l.sampler.strategy,
            crate::sampler::Strategy::LowConfidence
        ));
        let d = EngineCfg::new("dream-nano", Method::EsDllm);
        assert!(matches!(
            d.sampler.strategy,
            crate::sampler::Strategy::MaskgitPlus { .. }
        ));
        assert_eq!(l.alpha, 0.5);
        assert_eq!(l.block, 8);
    }

    #[test]
    fn step_exe_names_cover_variants() {
        let mut cfg = EngineCfg::new("llada-nano", Method::EsDllm);
        assert_eq!(step_exe_name(&cfg, StepPlan::EsStep, 8, 1.0), "es_blk8_b8");
        assert_eq!(step_exe_name(&cfg, StepPlan::DualStep, 1, 1.0), "dual_blk8_b1");
        cfg.sparse = true;
        assert_eq!(step_exe_name(&cfg, StepPlan::EsStep, 8, 1.0), "es_sp_blk8_b8");
        cfg.sparse = false;
        cfg.indicator = "q".into();
        assert_eq!(step_exe_name(&cfg, StepPlan::EsStep, 8, 1.0), "es_ind_q_blk8_b8");
        cfg.indicator = "h".into();
        cfg.es_exe_override = Some("es_r1_only_50_blk8_b8".into());
        assert_eq!(
            step_exe_name(&cfg, StepPlan::EsStep, 8, 1.0),
            "es_r1_only_50_blk8_b8"
        );
    }

    #[test]
    fn adaptive_exe_thresholds() {
        // quiescent → aggressive variant
        assert_eq!(adaptive_es_exe(32, 8, 0.001), "es_r2_only_75_blk32_b8");
        // moderate drift → default
        assert_eq!(adaptive_es_exe(32, 8, 0.02), "es_blk32_b8");
        // large drift → conservative
        assert_eq!(adaptive_es_exe(32, 8, 0.2), "es_r2_only_25_blk32_b8");
    }
}
