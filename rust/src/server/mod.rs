//! HTTP serving front end: /generate, /healthz, /metrics on the in-tree
//! HTTP substrate, dispatching to the router.

use std::sync::Arc;

use crate::httpd::{Handler, Request, Response, Server};
use crate::json::{self, Json};
use crate::router::Router;

pub struct ServeCfg {
    pub bind: String,
    pub http_threads: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { bind: "127.0.0.1:0".into(), http_threads: 4 }
    }
}

/// Start the HTTP server over an already-running router.
pub fn serve(cfg: &ServeCfg, router: Router) -> std::io::Result<Server> {
    let handler: Handler = Arc::new(move |req: &Request| route(req, &router));
    Server::start(&cfg.bind, cfg.http_threads, handler)
}

fn route(req: &Request, router: &Router) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::text(200, "ok"),
        ("GET", "/metrics") => Response::text(200, router.metrics.render()),
        ("POST", "/generate") => generate(req, router),
        _ => Response::not_found(),
    }
}

fn generate(req: &Request, router: &Router) -> Response {
    let body = match Json::parse(req.body_str()) {
        Ok(b) => b,
        Err(e) => {
            return Response::json(
                400,
                json::obj(vec![("error", json::s(format!("bad json: {e}")))]).to_string(),
            )
        }
    };
    let prompt = match body.get("prompt").as_str() {
        Some(p) => p.to_string(),
        None => {
            return Response::json(
                400,
                json::obj(vec![("error", json::s("missing 'prompt'"))]).to_string(),
            )
        }
    };
    let slot = match router.try_submit(prompt) {
        Ok(s) => s,
        Err(()) => {
            return Response::json(
                429,
                json::obj(vec![("error", json::s("queue full"))]).to_string(),
            )
        }
    };
    match slot.wait() {
        Ok(reply) => Response::json(
            200,
            json::obj(vec![
                ("text", json::s(reply.text)),
                ("iterations", json::num(reply.iterations as f64)),
                ("wall_s", json::num(reply.wall_s)),
            ])
            .to_string(),
        ),
        Err(e) => Response::json(
            500,
            json::obj(vec![("error", json::s(e))]).to_string(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bad_json_is_400() {
        // route() without a live worker: only /generate parse errors and
        // static endpoints are testable here (full-stack test lives in
        // rust/tests/integration_server.rs)
        let router = Router::start(crate::router::RouterCfg {
            engine: crate::engine::EngineCfg::new("llada-nano", crate::engine::Method::EsDllm),
            batcher: Default::default(),
            queue_cap: 2,
            workers: 1,
            artifacts_dir: std::path::PathBuf::from("/nonexistent"),
        });
        let req = Request {
            method: "POST".into(),
            path: "/generate".into(),
            headers: vec![],
            body: b"not-json".to_vec(),
        };
        assert_eq!(route(&req, &router).status, 400);
        let req2 = Request {
            method: "GET".into(),
            path: "/healthz".into(),
            headers: vec![],
            body: vec![],
        };
        assert_eq!(route(&req2, &router).status, 200);
        router.shutdown();
    }
}
