//! Thread-pool + channel substrate (tokio is unavailable offline).
//!
//! A fixed pool of workers pulling boxed jobs from an MPMC queue built on
//! `Mutex<VecDeque>` + `Condvar`, plus a tiny oneshot-style `JoinHandle`.
//! The serving front end uses this for connection handling; the router
//! uses a dedicated pool for engine workers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let sh = shared.clone();
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.available.notify_one();
    }

    /// Enqueue a job returning a value retrievable via the handle.
    pub fn submit<T: Send + 'static, F: FnOnce() -> T + Send + 'static>(
        &self,
        f: F,
    ) -> JoinHandle<T> {
        let slot = Arc::new((Mutex::new(None), Condvar::new()));
        let slot2 = slot.clone();
        self.execute(move || {
            let v = f();
            *slot2.0.lock().unwrap() = Some(v);
            slot2.1.notify_all();
        });
        JoinHandle { slot }
    }

    /// Block until the queue is empty and no job is running.
    pub fn wait_idle(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !q.is_empty() || self.shared.in_flight.load(Ordering::SeqCst) > 0 {
            q = self.shared.done.wait(q).unwrap();
        }
    }

    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        job();
        if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _q = sh.queue.lock().unwrap();
            sh.done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

pub struct JoinHandle<T> {
    slot: Arc<(Mutex<Option<T>>, Condvar)>,
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> T {
        let mut guard = self.slot.0.lock().unwrap();
        loop {
            if let Some(v) = guard.take() {
                return v;
            }
            guard = self.slot.1.wait(guard).unwrap();
        }
    }

    pub fn try_join(&self) -> Option<T> {
        self.slot.0.lock().unwrap().take()
    }
}

// ---------------------------------------------------------------------------
// simple bounded MPSC channel for request queues (backpressure-aware)
// ---------------------------------------------------------------------------

pub struct Channel<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    queue: Mutex<VecDeque<T>>,
    cap: usize,
    not_empty: Condvar,
    not_full: Condvar,
    closed: AtomicBool,
}

impl<T> Clone for Channel<T> {
    fn clone(&self) -> Self {
        Channel { inner: self.inner.clone() }
    }
}

impl<T> Channel<T> {
    pub fn bounded(cap: usize) -> Self {
        Channel {
            inner: Arc::new(ChannelInner {
                queue: Mutex::new(VecDeque::new()),
                cap: cap.max(1),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                closed: AtomicBool::new(false),
            }),
        }
    }

    /// Blocking send; returns Err(item) if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut q = self.inner.queue.lock().unwrap();
        while q.len() >= self.inner.cap {
            if self.inner.closed.load(Ordering::SeqCst) {
                return Err(item);
            }
            q = self.inner.not_full.wait(q).unwrap();
        }
        if self.inner.closed.load(Ordering::SeqCst) {
            return Err(item);
        }
        q.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking send (backpressure signal for the router).
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut q = self.inner.queue.lock().unwrap();
        if q.len() >= self.inner.cap || self.inner.closed.load(Ordering::SeqCst) {
            return Err(item);
        }
        q.push_back(item);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; None when closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(v) = q.pop_front() {
                self.inner.not_full.notify_one();
                return Some(v);
            }
            if self.inner.closed.load(Ordering::SeqCst) {
                return None;
            }
            q = self.inner.not_empty.wait(q).unwrap();
        }
    }

    /// Non-blocking receive: None when the queue is currently empty (the
    /// continuous scheduler uses this for mid-flight admission polls).
    pub fn try_recv(&self) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        let v = q.pop_front();
        if v.is_some() {
            self.inner.not_full.notify_one();
        }
        v
    }

    /// Receive with a timeout; Ok(None) on timeout.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + dur;
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(v) = q.pop_front() {
                self.inner.not_full.notify_one();
                return Some(v);
            }
            if self.inner.closed.load(Ordering::SeqCst) {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) =
                self.inner.not_empty.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if res.timed_out() && q.is_empty() {
                return None;
            }
        }
    }

    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_value() {
        let pool = ThreadPool::new(2, "t");
        let h = pool.submit(|| 21 * 2);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn channel_backpressure() {
        let ch = Channel::bounded(2);
        ch.try_send(1).unwrap();
        ch.try_send(2).unwrap();
        assert!(ch.try_send(3).is_err());
        assert_eq!(ch.recv(), Some(1));
        ch.try_send(3).unwrap();
    }

    #[test]
    fn channel_close_drains() {
        let ch = Channel::bounded(8);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        ch.close();
        assert!(ch.send(3).is_err());
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn channel_try_recv_nonblocking() {
        let ch: Channel<u32> = Channel::bounded(2);
        assert_eq!(ch.try_recv(), None);
        ch.try_send(5).unwrap();
        assert_eq!(ch.try_recv(), Some(5));
        assert_eq!(ch.try_recv(), None);
        // try_recv frees capacity for blocked senders
        ch.try_send(1).unwrap();
        ch.try_send(2).unwrap();
        assert!(ch.try_send(3).is_err());
        assert_eq!(ch.try_recv(), Some(1));
        ch.try_send(3).unwrap();
    }

    #[test]
    fn channel_recv_timeout() {
        let ch: Channel<u32> = Channel::bounded(1);
        let t0 = std::time::Instant::now();
        assert_eq!(ch.recv_timeout(std::time::Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25));
    }

    #[test]
    fn cross_thread_channel() {
        let ch = Channel::bounded(4);
        let ch2 = ch.clone();
        let t = thread::spawn(move || {
            for i in 0..50u32 {
                ch2.send(i).unwrap();
            }
            ch2.close();
        });
        let mut got = vec![];
        while let Some(v) = ch.recv() {
            got.push(v);
        }
        t.join().unwrap();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }
}
