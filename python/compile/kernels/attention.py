"""Layer-1 Pallas kernel: cached-KV block attention with online softmax.

This is the compute hot-spot of every decode iteration: the (possibly
early-skipped) active query set attends to the *full* cached K/V of the
sequence (Algorithm 1, line 5).

Hardware adaptation (paper targets an H200; see DESIGN.md §7): the CUDA
implementation's threadblock-per-(batch,head) tiling with shared-memory
staging becomes a Pallas grid over (batch·head, kv-tiles) whose BlockSpecs
express the HBM→VMEM schedule.  The softmax is computed online
(flash-style running max/denominator in the revisited output blocks) so
VMEM holds O(S·hd + T_tile·hd) instead of O(T·hd):

    grid = (B·Hq, ceil(T / kv_tile))
    per step VMEM:  q    [S, hd]        (revisited, read-only)
                    k,v  [kv_tile, hd]  (streamed)
                    acc  [S, hd] + m,l [S]  (revisited accumulators)

GQA is handled in the *index map* — query head h reads kv head
h // group — so grouped K/V are never materialized.

`interpret=True` is mandatory here: real-TPU lowering emits a Mosaic
custom-call which the CPU PJRT plugin cannot execute; interpret mode
lowers to plain HLO that runs everywhere (numerics are identical).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_KV_TILE = 64
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale,
                 kv_tiles, group):
    """One grid step: fold one KV tile into the online-softmax state, for
    ALL (batch, head) rows at once.

    §Perf iteration 1 (see EXPERIMENTS.md): the first kernel used
    grid=(B·Hq, kv_tiles) — the direct analog of CUDA's
    threadblock-per-(batch,head). Interpret-mode lowering serializes grid
    steps into an HLO while loop whose per-step overhead dominated at
    B·H=64 (≈0.4 ms/step ⇒ ~50 ms/layer-stack). The grid is now only the
    *streaming* dimension (KV tiles — the HBM→VMEM schedule, which is the
    paper-relevant part) and the batch/head dimension became a batched dot
    inside the block; on a real TPU this corresponds to assigning whole
    q-row batches to one core's MXU queue.

    Block shapes:
      q_ref [BHq, S, hd]        — revisited for every kv tile
      k_ref/v_ref [BHkv, T_t, hd] — the streamed tile
      o_ref [BHq, S, hd], m_ref/l_ref [BHq, S] — accumulators (same block
        across all kv tiles, so values persist between grid steps)
    """
    t = pl.program_id(0)

    q = q_ref[...]                    # [BHq, S, hd]
    k = k_ref[...]                    # [BHkv, T_t, hd]
    v = v_ref[...]
    if group > 1:                     # GQA: expand kv heads to q heads
        bhkv, tt, hd = k.shape
        # [B·Hkv, T, hd] -> [B·Hkv, group, T, hd] -> [B·Hq, T, hd]
        k = jnp.repeat(k.reshape(bhkv, 1, tt, hd), group, axis=1)
        k = k.reshape(bhkv * group, tt, hd)
        v = jnp.repeat(v.reshape(bhkv, 1, tt, hd), group, axis=1)
        v = v.reshape(bhkv * group, tt, hd)

    s = jnp.einsum("bsd,btd->bst", q, k) * scale   # [BHq, S, T_t]
    m_tile = jnp.max(s, axis=-1)                   # [BHq, S]

    @pl.when(t == 0)
    def _init():
        p = jnp.exp(s - m_tile[..., None])
        m_ref[...] = m_tile
        l_ref[...] = jnp.sum(p, axis=-1)
        o_ref[...] = jnp.einsum("bst,btd->bsd", p, v)

    @pl.when(t > 0)
    def _fold():
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, m_tile)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
        o_ref[...] = o_ref[...] * corr[..., None] + jnp.einsum(
            "bst,btd->bsd", p, v)

    @pl.when(t == kv_tiles - 1)
    def _finalize():
        o_ref[...] = o_ref[...] / l_ref[...][..., None]


def attention(q, k, v, *, kv_tile=DEFAULT_KV_TILE, interpret=True):
    """Cached-KV attention via the Pallas kernel.

    q: [B, Hq, S, hd]; k, v: [B, Hkv, T, hd] -> [B, Hq, S, hd]
    """
    b, hq, s, hd = q.shape
    _, hkv, t, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    # largest divisor of t not exceeding the requested tile, so any cache
    # length (80 dense, 56 pruned, ...) tiles cleanly
    kv_tile = min(kv_tile, t)
    while t % kv_tile != 0:
        kv_tile -= 1
    kv_tiles = t // kv_tile
    scale = 1.0 / (hd**0.5)

    # GQA note: kv heads are repeated inside the kernel body; the reshape
    # here keeps the batch dim adjacent to heads so the in-kernel repeat
    # aligns query row b*Hq+h with kv row b*Hkv+h//group.
    qf = q.reshape(b * hq, s, hd)
    kf = k.reshape(b * hkv, t, hd)
    vf = v.reshape(b * hkv, t, hd)

    kernel = functools.partial(
        _attn_kernel, scale=scale, kv_tiles=kv_tiles, group=group)
    out, _m, _l = pl.pallas_call(
        kernel,
        grid=(kv_tiles,),
        in_specs=[
            pl.BlockSpec((b * hq, s, hd), lambda tt: (0, 0, 0)),
            pl.BlockSpec((b * hkv, kv_tile, hd), lambda tt: (0, tt, 0)),
            pl.BlockSpec((b * hkv, kv_tile, hd), lambda tt: (0, tt, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b * hq, s, hd), lambda tt: (0, 0, 0)),
            pl.BlockSpec((b * hq, s), lambda tt: (0, 0)),
            pl.BlockSpec((b * hq, s), lambda tt: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hq, s, hd), q.dtype),
            jax.ShapeDtypeStruct((b * hq, s), q.dtype),
            jax.ShapeDtypeStruct((b * hq, s), q.dtype),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, hd)


def vmem_bytes(s, hd, kv_tile, dtype_bytes=2):
    """Estimated VMEM residency per grid step (for §Perf reporting):
    q + acc [S, hd] ×2, k + v [kv_tile, hd] ×2, m + l [S] ×2."""
    return dtype_bytes * (2 * s * hd + 2 * kv_tile * hd + 2 * s)
