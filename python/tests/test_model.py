"""L2 model correctness: shapes, cache semantics, ES/Dual equivalences."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.modelcfg import LLADA_NANO, DREAM_NANO, SKIP_CONFIGS, final_keep
from compile import model as M


@pytest.fixture(scope="module", params=["llada-nano", "dream-nano"])
def setup(request):
    cfg = LLADA_NANO if request.param == "llada-nano" else DREAM_NANO
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(4, 60, (2, cfg.ctx)), jnp.int32)
    logits, kv, ind, mass = M.prefill(cfg, params, toks, use_pallas=False)
    return cfg, params, toks, logits, kv, ind, mass


def _step(cfg, params, toks, kv, ind_h, conf, *, skip, block=8, alpha=0.5,
          ind_layers=None, indicator="h"):
    x_tok = toks[:, cfg.prompt_len:cfg.prompt_len + block]
    return M.step(cfg, params, x_tok, jnp.int32(cfg.prompt_len), kv, ind_h,
                  conf, jnp.float32(alpha), block=block, skip=skip,
                  ind_layers=ind_layers, indicator=indicator,
                  use_pallas=False)


def test_prefill_logits_gen_is_the_gen_region_slice(setup):
    # the Host-fallback executables (`vanilla_b*` / `prefill_b*`) are
    # lowered with logits_gen=True: the output must be exactly the
    # gen-region rows of the full-context forward, nothing resampled
    cfg, params, toks, logits, kv, ind, mass = setup
    lg, kv2, ind2, mass2 = M.prefill(cfg, params, toks, use_pallas=False,
                                     logits_gen=True)
    assert lg.shape == (toks.shape[0], cfg.gen_len, cfg.vocab)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits[:, cfg.prompt_len:]),
                               rtol=0, atol=0)
    # the cache outputs are untouched by the slice
    np.testing.assert_array_equal(np.asarray(kv2.astype(jnp.float32)),
                                  np.asarray(kv.astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(mass2), np.asarray(mass))


def test_prefill_shapes(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    assert logits.shape == (B, cfg.ctx, cfg.vocab)
    assert kv.shape == (cfg.n_layers, 2, B, cfg.n_kv_heads, cfg.ctx,
                        cfg.head_dim)
    assert kv.dtype == jnp.bfloat16
    for t in "hqkv":
        assert ind[t].shape == (cfg.n_layers, B, cfg.gen_len, cfg.d_model)
    assert mass.shape == (B, cfg.ctx)
    # attention mass over positions sums to ~1 per sequence
    np.testing.assert_allclose(np.asarray(mass.sum(-1)), 1.0, rtol=1e-4)


def test_step_shapes_and_dtypes(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.zeros((B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    sl = [1, 2]
    out = _step(cfg, params, toks, kv, ind["h"][jnp.asarray(sl)], conf, skip=skip)
    k_f = final_keep(8, skip)
    assert out[0].shape == (B, k_f, cfg.vocab)
    assert out[1].shape == (B, k_f)
    assert out[2].shape == (cfg.n_layers, 2, B, cfg.n_kv_heads, 8,
                            cfg.head_dim)
    assert out[3].shape == (len(sl), B, 8, cfg.d_model)
    assert out[2].dtype == jnp.bfloat16


def test_es_zero_ratio_equals_dual_mod_permutation(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.asarray(np.random.RandomState(1).rand(B, cfg.gen_len),
                       jnp.float32)
    all_layers = list(range(cfg.n_layers))
    dual = _step(cfg, params, toks, kv, ind["h"], conf, skip=[],
                 ind_layers=all_layers)
    es0 = _step(cfg, params, toks, kv, ind["h"], conf,
                skip=[(1, 0.0), (2, 0.0)], ind_layers=all_layers)
    order = jnp.argsort(es0[1], axis=1)
    el = jnp.take_along_axis(es0[0], order[..., None], axis=1)
    ep = jnp.take_along_axis(es0[1], order, axis=1)
    assert bool(jnp.all(ep == dual[1]))
    np.testing.assert_allclose(np.asarray(el), np.asarray(dual[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(es0[2].astype(jnp.float32)),
        np.asarray(dual[2].astype(jnp.float32)))


def test_dual_step_matches_prefill_logits(setup):
    """After prefill the caches are exact, so a dual step over the first
    block must reproduce the prefill logits up to bf16 cache rounding."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.zeros((B, cfg.gen_len), jnp.float32)
    dual = _step(cfg, params, toks, kv, ind["h"], conf, skip=[],
                 ind_layers=list(range(cfg.n_layers)))
    want = logits[:, cfg.prompt_len:cfg.prompt_len + 8]
    err = float(jnp.max(jnp.abs(dual[0] - want)))
    assert err < 0.15, err  # bf16 cache round-trip tolerance


def test_alpha_extremes_change_selection(setup):
    """α=1 ranks purely by confidence, α=0 purely by variation — with
    adversarial inputs the surviving sets must differ."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    rng = np.random.RandomState(3)
    conf = jnp.asarray(rng.rand(B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    sl = [1, 2]
    # perturb the indicator cache so variation is adversarial to confidence
    ind_h = ind["h"][jnp.asarray(sl)] + jnp.asarray(
        rng.standard_normal(ind["h"][jnp.asarray(sl)].shape) * 0.5, jnp.bfloat16)
    a1 = _step(cfg, params, toks, kv, ind_h, conf, skip=skip, alpha=1.0)
    a0 = _step(cfg, params, toks, kv, ind_h, conf, skip=skip, alpha=0.0)
    assert not bool(jnp.all(a1[1] == a0[1]))


def test_skip_positions_are_subset_of_block(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.zeros((B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    out = _step(cfg, params, toks, kv, ind["h"][jnp.asarray([1, 2])], conf, skip=skip)
    pos = np.asarray(out[1])
    assert ((pos >= cfg.prompt_len) & (pos < cfg.prompt_len + 8)).all()
    # positions unique per row
    for b in range(B):
        assert len(set(pos[b].tolist())) == pos.shape[1]


def test_sparse_kv_layout_step(setup):
    """Step against a pruned cache (retained prompt rows + gen region)
    equals the dense step when the pruned rows carry the same data and
    attention ignores... (smoke: shapes + runs)."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    keep = 24
    kv_np = np.asarray(kv.astype(jnp.float32))
    pruned = np.concatenate(
        [kv_np[:, :, :, :, :keep], kv_np[:, :, :, :, cfg.prompt_len:]], axis=4)
    conf = jnp.zeros((B, cfg.gen_len), jnp.float32)
    x_tok = toks[:, cfg.prompt_len:cfg.prompt_len + 8]
    out = M.step(cfg, params, x_tok, jnp.int32(cfg.prompt_len),
                 jnp.asarray(pruned, jnp.bfloat16), ind["h"][jnp.asarray([1, 2])], conf,
                 jnp.float32(0.5), block=8, skip=[(1, 0.5), (2, 0.5)],
                 kv_len=keep + cfg.gen_len, use_pallas=False)
    assert out[2].shape[4] == 8


def test_observe_probe_shapes(setup):
    cfg, params, toks, *_ = setup
    B = toks.shape[0]
    logits, probes = M.observe(cfg, params, toks, probe_layers=[2, 5, 7],
                               use_pallas=False)
    assert probes.shape == (3, 4, B, cfg.gen_len, cfg.d_model)
    assert logits.shape == (B, cfg.ctx, cfg.vocab)


def test_pallas_and_ref_paths_agree_on_step():
    cfg = LLADA_NANO
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(5)
    toks = jnp.asarray(rng.randint(4, 60, (1, cfg.ctx)), jnp.int32)
    _, kv, ind, _ = M.prefill(cfg, params, toks, use_pallas=False)
    conf = jnp.asarray(rng.rand(1, cfg.gen_len), jnp.float32)
    args = (cfg, params, toks[:, cfg.prompt_len:cfg.prompt_len + 8],
            jnp.int32(cfg.prompt_len), kv, ind["h"][jnp.asarray([1, 2])], conf,
            jnp.float32(0.5))
    kw = dict(block=8, skip=[(1, 0.5), (2, 0.5)])
    a = M.step(*args, **kw, use_pallas=True)
    b = M.step(*args, **kw, use_pallas=False)
    assert bool(jnp.all(a[1] == b[1]))
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               rtol=2e-4, atol=2e-4)


def test_step_apply_matches_block_step(setup):
    """Device-apply step with all rows occupied must produce the same
    logits/pos as the block-output step, and its in-graph cache updates
    must equal the host-side scatter of the block outputs."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    rng = np.random.RandomState(7)
    conf = jnp.asarray(rng.rand(B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    sl = [1, 2]
    blk = _step(cfg, params, toks, kv, ind["h"][jnp.asarray(sl)], conf,
                skip=skip)
    x_tok = toks[:, cfg.prompt_len:cfg.prompt_len + 8]
    occ = jnp.ones((B,), jnp.int32)
    app = M.step(cfg, params, x_tok, jnp.int32(cfg.prompt_len), kv,
                 ind["h"], conf, jnp.float32(0.5), block=8, skip=skip,
                 ind_layers=sl, use_pallas=False, apply=True, occ=occ)
    # identical selection and logits
    assert bool(jnp.all(app[1] == blk[1]))
    np.testing.assert_allclose(np.asarray(app[0]), np.asarray(blk[0]),
                               rtol=1e-5, atol=1e-5)
    # the in-graph KV scatter equals the host scatter of the block slice
    kv_host = np.asarray(kv.astype(jnp.float32)).copy()
    kv_host[:, :, :, :, cfg.prompt_len:cfg.prompt_len + 8] = np.asarray(
        blk[2].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(app[2].astype(jnp.float32)),
                               kv_host)
    # full shapes: kv/ind/conf are the resident tensors, not slices
    assert app[2].shape == kv.shape
    assert app[3].shape == ind["h"].shape
    assert app[4].shape == (B, cfg.gen_len)
    # the maintained indicator layers carry the block update; others
    # pass through
    ih = np.asarray(ind["h"].astype(jnp.float32))
    ia = np.asarray(app[3].astype(jnp.float32))
    np.testing.assert_allclose(ia[0], ih[0])  # layer 0 not maintained
    assert not np.allclose(ia[1, :, :8], ih[1, :, :8])
    # in-graph confidence: computed positions hold the max softmax prob
    probs = np.asarray(jax.nn.softmax(app[0], axis=-1).max(-1))
    pos = np.asarray(app[1]) - cfg.prompt_len
    conf_np = np.asarray(app[4])
    for bi in range(B):
        for j, p in enumerate(pos[bi]):
            np.testing.assert_allclose(conf_np[bi, p], probs[bi, j],
                                       rtol=1e-5)


def test_step_apply_passes_vacant_rows_through(setup):
    """Rows with occ = 0 keep their cache and confidence unchanged."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.asarray(np.random.RandomState(8).rand(B, cfg.gen_len),
                       jnp.float32)
    x_tok = toks[:, cfg.prompt_len:cfg.prompt_len + 8]
    occ = jnp.asarray([1] + [0] * (B - 1), jnp.int32)
    app = M.step(cfg, params, x_tok, jnp.int32(cfg.prompt_len), kv,
                 ind["h"], conf, jnp.float32(0.5), block=8,
                 skip=[(1, 0.5), (2, 0.5)], ind_layers=[1, 2],
                 use_pallas=False, apply=True, occ=occ)
    kv0 = np.asarray(kv.astype(jnp.float32))
    kva = np.asarray(app[2].astype(jnp.float32))
    # spectator rows (batch dim 2 of kv layout) untouched, stepped row not
    np.testing.assert_allclose(kva[:, :, 1:], kv0[:, :, 1:])
    assert not np.allclose(kva[:, :, :1, :, cfg.prompt_len:cfg.prompt_len + 8],
                           kv0[:, :, :1, :, cfg.prompt_len:cfg.prompt_len + 8])
    np.testing.assert_allclose(np.asarray(app[4])[1:],
                               np.asarray(conf)[1:])
    ia = np.asarray(app[3].astype(jnp.float32))
    ih = np.asarray(ind["h"].astype(jnp.float32))
    np.testing.assert_allclose(ia[:, 1:], ih[:, 1:])


def _np_greedy_commit(x_row, conf_row, hat_row, noeos_row, mask, eos):
    """The host sampler's greedy decision for one row in numpy: the
    highest-confidence masked position wins (LAST max on ties, like
    Rust's `max_by`); EOS is suppressed while non-EOS content sits to
    the position's right (§B.2 guard)."""
    masked = np.where(x_row == mask)[0]
    vals = conf_row[masked]
    best = int(masked[len(vals) - 1 - int(np.argmax(vals[::-1]))])
    content = (x_row != mask) & (x_row != eos)
    tok = noeos_row[best] if content[best + 1:].any() else hat_row[best]
    return best, int(tok)


def test_step_k_chains_commits_between_inner_iterations(setup):
    """A fused k=2 run must equal: one apply-step, the HOST greedy
    commit rule (highest-confidence masked block position by the
    chained confidence, argmax caches refreshed at the surviving rows),
    then a second apply-step on the advanced tokens. The downlinked
    `commit_pos`/`commit_tok` must name exactly the replayed commits —
    the host applies them directly — and the committed count must be
    one per inner iteration per occupied row when the threshold
    disables parallel commits."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    rs = np.random.RandomState(11)
    conf = jnp.asarray(rs.rand(B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    sl = [1, 2]
    MASK, EOS = 1, 2
    x0 = jnp.full((B, 8), MASK, jnp.int32)
    occ = jnp.asarray([1] + [0] * (B - 1), jnp.int32)
    seed = rs.randint(4, 60, (2, B, 8)).astype(np.int32)
    fused = M.step_k(cfg, params, x0, jnp.int32(cfg.prompt_len), kv,
                     ind["h"], conf, occ, jnp.float32(0.5),
                     jnp.float32(2.0), jnp.asarray(seed), k=2, block=8,
                     skip=skip, mask_id=MASK, eos_id=EOS, ind_layers=sl,
                     use_pallas=False)
    # threshold 2.0 > any confidence → greedy only: one commit per
    # inner iteration for the occupied row, none for the vacant row
    np.testing.assert_array_equal(np.asarray(fused[5]),
                                  [2] + [0] * (B - 1))
    # manual replay: k=1 apply-steps + the host commit rule in numpy
    hat, noeos = seed[0].copy(), seed[1].copy()
    x = np.full((B, 8), MASK, np.int32)
    kv_c, ind_c, conf_c = kv, ind["h"], conf
    commits = []
    st = None
    for _ in range(2):
        st = M.step(cfg, params, jnp.asarray(x), jnp.int32(cfg.prompt_len),
                    kv_c, ind_c, conf_c, jnp.float32(0.5), block=8,
                    skip=skip, ind_layers=sl, use_pallas=False,
                    apply=True, occ=occ)
        kv_c, ind_c, conf_c = st[2], st[3], st[4]
        lg, pos = np.asarray(st[0]), np.asarray(st[1])
        lg_m = lg.copy()
        lg_m[:, :, MASK] = -np.inf
        lg_me = lg_m.copy()
        lg_me[:, :, EOS] = -np.inf
        rel = pos[0] - cfg.prompt_len
        hat[0, rel] = lg_m[0].argmax(-1)
        noeos[0, rel] = lg_me[0].argmax(-1)
        conf_blk = np.asarray(conf_c)[0, :8]
        p, t = _np_greedy_commit(x[0], conf_blk, hat[0], noeos[0],
                                 MASK, EOS)
        x[0, p] = t
        commits.append((p, t))
    # the downlinked per-iteration commits are exactly the replayed ones
    np.testing.assert_array_equal(np.asarray(fused[6])[0],
                                  [p for p, _ in commits])
    np.testing.assert_array_equal(np.asarray(fused[7])[0],
                                  [t for _, t in commits])
    # the fused downlink is the final iteration's logits/pos, and the
    # chained caches equal the replayed second step's
    np.testing.assert_array_equal(np.asarray(fused[1]), np.asarray(st[1]))
    np.testing.assert_allclose(np.asarray(fused[0]), np.asarray(st[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(fused[2].astype(jnp.float32)),
        np.asarray(st[2].astype(jnp.float32)))
    np.testing.assert_allclose(
        np.asarray(fused[3].astype(jnp.float32)),
        np.asarray(st[3].astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(fused[4]), np.asarray(st[4]),
                               rtol=1e-5)


def test_commit_unmask_eos_guard_and_argmax_caches():
    """Pure-function check of the in-graph commit rule: EOS is banned
    at a position while non-EOS content sits to its right (the host
    sampler's §B.2 guard), tail EOS stays allowed, and a position the
    skip chain dropped this iteration commits from the seeded argmax
    caches — the host logits mirror's token, not a replayed one."""
    B, blk, V = 1, 4, 8
    MASK, EOS = 1, 2
    x = jnp.asarray([[MASK, MASK, 5, MASK]], jnp.int32)  # content at 2
    # surviving rows: block positions 0 and 3; EOS argmax, 4 second
    logits = np.zeros((B, 2, V), np.float32)
    logits[0, :, EOS] = 9.0
    logits[0, :, 4] = 5.0
    pos = jnp.asarray([[10, 13]], jnp.int32)             # block_start 10
    seed = jnp.full((B, blk), 7, jnp.int32)
    occ = jnp.asarray([True])
    args = (x, jnp.asarray(logits), pos, jnp.int32(10))
    tail = (occ, jnp.float32(2.0), MASK, EOS)
    # position 0 wins; content at 2 is to its right → EOS suppressed
    conf = jnp.asarray([[0.9, 0.8, 0.0, 0.1]], jnp.float32)
    x_new, hat, noeos, n, g_rel, g_tok = M._commit_unmask(
        *args, conf, seed, seed, *tail)
    assert (int(g_rel[0]), int(g_tok[0]), int(n[0])) == (0, 4, 1)
    np.testing.assert_array_equal(np.asarray(x_new), [[4, MASK, 5, MASK]])
    # argmax caches: surviving rows refreshed, dropped rows keep seed
    np.testing.assert_array_equal(np.asarray(hat), [[EOS, 7, 7, EOS]])
    np.testing.assert_array_equal(np.asarray(noeos), [[4, 7, 7, 4]])
    # tail position wins → nothing to its right → EOS fill allowed
    conf = jnp.asarray([[0.1, 0.2, 0.0, 0.9]], jnp.float32)
    _, _, _, _, g_rel, g_tok = M._commit_unmask(*args, conf, seed, seed,
                                                *tail)
    assert (int(g_rel[0]), int(g_tok[0])) == (3, EOS)
    # a dropped masked row wins → token comes from the seeded cache
    # (guarded: content at 2 sits to position 1's right)
    conf = jnp.asarray([[0.1, 0.9, 0.0, 0.2]], jnp.float32)
    _, _, _, _, g_rel, g_tok = M._commit_unmask(*args, conf, seed, seed,
                                                *tail)
    assert (int(g_rel[0]), int(g_tok[0])) == (1, 7)


def test_prefill_apply_refreshes_only_masked_rows(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    rng = np.random.RandomState(9)
    kv_prev = jnp.asarray(rng.standard_normal(kv.shape), jnp.bfloat16)
    ind_prev = jnp.asarray(rng.standard_normal(ind["h"].shape), jnp.bfloat16)
    conf_prev = jnp.asarray(rng.rand(B, cfg.gen_len), jnp.float32)
    refresh = jnp.asarray([1] + [0] * (B - 1), jnp.int32)
    out = M.prefill_apply(cfg, params, toks, kv_prev, ind_prev, conf_prev,
                          refresh, use_pallas=False)
    lg_gen, kv_new, ind_new, conf_new = out
    # refreshed row matches a fresh prefill; spectator rows pass through
    np.testing.assert_allclose(
        np.asarray(kv_new.astype(jnp.float32))[:, :, 0],
        np.asarray(kv.astype(jnp.float32))[:, :, 0])
    np.testing.assert_allclose(
        np.asarray(kv_new.astype(jnp.float32))[:, :, 1:],
        np.asarray(kv_prev.astype(jnp.float32))[:, :, 1:])
    np.testing.assert_allclose(np.asarray(ind_new.astype(jnp.float32))[:, 1:],
                               np.asarray(ind_prev.astype(jnp.float32))[:, 1:])
    np.testing.assert_allclose(np.asarray(conf_new)[1:],
                               np.asarray(conf_prev)[1:])
    # in-graph confidence of the refreshed row = max softmax of its
    # gen-region logits
    want = np.asarray(jax.nn.softmax(lg_gen, axis=-1).max(-1))
    np.testing.assert_allclose(np.asarray(conf_new)[0], want[0], rtol=1e-5)
    # the logit output is the gen-region slice, not the full context:
    # the prompt rows never cross the bus
    assert lg_gen.shape == (B, cfg.gen_len, cfg.vocab)
    full = M.prefill(cfg, params, toks, use_pallas=False)[0]
    np.testing.assert_allclose(np.asarray(lg_gen),
                               np.asarray(full[:, cfg.prompt_len:]),
                               rtol=1e-5, atol=1e-6)
