//! HTTP/1.1 substrate: a small threaded server and a blocking client
//! (hyper/axum/reqwest are unavailable offline).
//!
//! Supports the subset the serving front end needs: GET/POST, fixed
//! `Content-Length` bodies, keep-alive, JSON payloads. One handler
//! function serves all routes; connections are dispatched on a
//! [`crate::threadpool::ThreadPool`].

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::threadpool::ThreadPool;

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Self {
        Response {
            status,
            content_type: "application/json".into(),
            body: body.into().into_bytes(),
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Response {
            status,
            content_type: "text/plain".into(),
            body: body.into().into_bytes(),
        }
    }

    pub fn not_found() -> Self {
        Self::text(404, "not found")
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync + 'static>;

pub struct Server {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind (port 0 = ephemeral) and serve on `threads` pooled workers.
    pub fn start(bind: &str, threads: usize, handler: Handler) -> std::io::Result<Server> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("httpd-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(threads, "httpd");
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = handler.clone();
                            pool.execute(move || {
                                let _ = serve_conn(stream, h);
                            });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_conn(stream: TcpStream, handler: Handler) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30))).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    loop {
        let req = match read_request(&mut reader)? {
            Some(r) => r,
            None => return Ok(()), // client closed
        };
        let keep_alive = !matches!(
            req.header("connection").map(|s| s.to_ascii_lowercase()),
            Some(ref c) if c == "close"
        );
        let resp = handler(&req);
        write_response(&mut stream, &resp, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

fn read_request<R: BufRead>(r: &mut R) -> std::io::Result<Option<Request>> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    if method.is_empty() {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad request line"));
    }
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        if r.read_line(&mut h)? == 0 {
            return Ok(None);
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    let len: usize = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    if len > 0 {
        r.read_exact(&mut body)?;
    }
    Ok(Some(Request { method, path, headers, body }))
}

fn write_response(w: &mut impl Write, resp: &Response, keep_alive: bool) -> std::io::Result<()> {
    let reason = match resp.status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    };
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        reason,
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    w.write_all(&resp.body)?;
    w.flush()
}

// ---------------------------------------------------------------------------
// blocking client (used by examples / integration tests / load generator)
// ---------------------------------------------------------------------------

pub struct Client {
    addr: SocketAddr,
    conn: Option<BufReader<TcpStream>>,
}

impl Client {
    pub fn new(addr: SocketAddr) -> Client {
        Client { addr, conn: None }
    }

    fn ensure_conn(&mut self) -> std::io::Result<()> {
        if self.conn.is_none() {
            let s = TcpStream::connect(self.addr)?;
            s.set_nodelay(true).ok();
            self.conn = Some(BufReader::new(s));
        }
        Ok(())
    }

    pub fn get(&mut self, path: &str) -> std::io::Result<(u16, Vec<u8>)> {
        self.request("GET", path, b"")
    }

    pub fn post(&mut self, path: &str, body: &[u8]) -> std::io::Result<(u16, Vec<u8>)> {
        self.request("POST", path, body)
    }

    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        self.ensure_conn()?;
        let result = self.request_inner(method, path, body);
        if result.is_err() {
            // retry once on a fresh connection (server may have dropped a
            // kept-alive socket)
            self.conn = None;
            self.ensure_conn()?;
            return self.request_inner(method, path, body);
        }
        result
    }

    fn request_inner(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> std::io::Result<(u16, Vec<u8>)> {
        let reader = self.conn.as_mut().unwrap();
        {
            let stream = reader.get_mut();
            write!(
                stream,
                "{method} {path} HTTP/1.1\r\nHost: esdllm\r\nContent-Length: {}\r\n\r\n",
                body.len()
            )?;
            stream.write_all(body)?;
            stream.flush()?;
        }
        let mut status_line = String::new();
        reader.read_line(&mut status_line)?;
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status"))?;
        let mut len = 0usize;
        let mut close = false;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h)?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let lower = h.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                len = v.trim().parse().unwrap_or(0);
            }
            if lower.starts_with("connection:") && lower.contains("close") {
                close = true;
            }
        }
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        if close {
            self.conn = None;
        }
        Ok((status, buf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        let handler: Handler = Arc::new(|req: &Request| match req.path.as_str() {
            "/healthz" => Response::text(200, "ok"),
            "/echo" => Response::json(200, req.body_str().to_string()),
            _ => Response::not_found(),
        });
        Server::start("127.0.0.1:0", 2, handler).unwrap()
    }

    #[test]
    fn get_and_post_roundtrip() {
        let server = echo_server();
        let mut c = Client::new(server.addr);
        let (st, body) = c.get("/healthz").unwrap();
        assert_eq!((st, body.as_slice()), (200, b"ok".as_slice()));
        let (st, body) = c.post("/echo", br#"{"x":1}"#).unwrap();
        assert_eq!(st, 200);
        assert_eq!(body, br#"{"x":1}"#);
    }

    #[test]
    fn keep_alive_multiple_requests() {
        let server = echo_server();
        let mut c = Client::new(server.addr);
        for i in 0..10 {
            let payload = format!("req{i}");
            let (st, body) = c.post("/echo", payload.as_bytes()).unwrap();
            assert_eq!(st, 200);
            assert_eq!(body, payload.as_bytes());
        }
    }

    #[test]
    fn unknown_route_404() {
        let server = echo_server();
        let mut c = Client::new(server.addr);
        let (st, _) = c.get("/nope").unwrap();
        assert_eq!(st, 404);
    }

    #[test]
    fn concurrent_clients() {
        let server = echo_server();
        let addr = server.addr;
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = Client::new(addr);
                    for i in 0..20 {
                        let p = format!("t{t}-{i}");
                        let (st, body) = c.post("/echo", p.as_bytes()).unwrap();
                        assert_eq!(st, 200);
                        assert_eq!(body, p.as_bytes());
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }
}
