//! Fault-injection + recovery acceptance tests: a transient injected
//! fault (exec / transfer) never changes decoded output — the failed
//! tick leaves the host trajectory untouched, so a re-ground + retry is
//! token-identical to the fault-free run; a divergent fused dispatch
//! steps the fused depth down one ladder rung and recovers the same
//! way; an allocation fault on chain seed/checkout evicts the pool's
//! LRU parked chain and re-seeds exactly that chain; and the
//! [`esdllm::fault::FaultStats`] ledger is count-exact between the sim
//! backend and a replay of the call cadence the PJRT backend's fault
//! wrappers make (one exec + one transfer event per run, one alloc
//! event per chain seed/checkout, one divergence event per accepted
//! fused dispatch). Everything runs over the sim backend — no PJRT
//! artifacts required.

use std::time::Instant;

use esdllm::cache::RefreshPolicy;
use esdllm::engine::Method;
use esdllm::fault::{classify, FaultInjector, FaultKind, FaultPlan, TickErrorClass};
use esdllm::sampler::SamplerCfg;
use esdllm::scheduler::sim::{SimBackend, SimCfg};
use esdllm::scheduler::{FinishedSeq, GroupScheduler, SchedCfg, SeqInput, SeqParams};

fn sched_cfg(block: usize, k: usize) -> SchedCfg {
    SchedCfg {
        method: Method::EsDllm,
        block,
        refresh: RefreshPolicy { prompt_period: 16, block_period: if block == 8 { 4 } else { 2 } },
        sampler: SamplerCfg::llada(),
        seed: 0,
        k,
        hysteresis: None,
    }
}

fn sched_with_plan(n_slots: usize, block: usize, k: usize, plan: &str) -> GroupScheduler<'static> {
    let plan = FaultPlan::parse(plan).expect("valid fault plan");
    let backend = SimBackend::new(SimCfg::default().with_faults(plan));
    GroupScheduler::new(Box::new(backend), n_slots, sched_cfg(block, k)).unwrap()
}

fn input(id: u64, prompt: &str) -> SeqInput {
    SeqInput {
        id,
        prompt: prompt.to_string(),
        params: SeqParams::default(),
        submitted: Instant::now(),
    }
}

fn drain(s: &mut GroupScheduler<'_>) -> Vec<FinishedSeq> {
    let mut finished = Vec::new();
    let mut guard = 0;
    while s.active() > 0 {
        finished.append(&mut s.tick().unwrap());
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
    }
    finished
}

/// The router's recovery loop, distilled: classify a failed tick,
/// demote the fused depth on a poisoned chain, re-ground, retry.
/// Returns the retirements plus the number of retried ticks.
fn drain_recovering(s: &mut GroupScheduler<'_>) -> (Vec<FinishedSeq>, u32) {
    let inj = s.fault_injector().expect("sim backend carries an injector");
    let mut finished = Vec::new();
    let mut retries = 0u32;
    let mut guard = 0;
    while s.active() > 0 {
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain under faults");
        match s.tick() {
            Ok(mut f) => finished.append(&mut f),
            Err(e) => match classify(&e) {
                TickErrorClass::Misconfig => panic!("unexpected misconfiguration: {e:#}"),
                class => {
                    if class == TickErrorClass::Poisoned && s.demote_fused_k().is_some() {
                        inj.note_fused_k_demotion();
                    }
                    s.reground_active().expect("re-ground after transient fault");
                    inj.note_tick_retried();
                    inj.note_chain_regrounded();
                    retries += 1;
                }
            },
        }
    }
    (finished, retries)
}

fn texts_by_id(mut finished: Vec<FinishedSeq>) -> Vec<(u64, String, usize)> {
    finished.sort_by_key(|f| f.id);
    finished
        .into_iter()
        .map(|f| {
            assert!(f.error.is_none(), "recovered sequence must not carry an error");
            (f.id, f.text, f.tokens)
        })
        .collect()
}

/// Acceptance: under injected exec and transfer faults, every sequence
/// — the one whose tick faulted and its groupmates — completes with
/// output token-identical to the fault-free run, and nobody sees an
/// error.
#[test]
fn exec_and_transfer_faults_recover_token_identical() {
    let mut clean = sched_with_plan(2, 4, 1, "");
    clean.admit(input(1, "abc")).unwrap();
    clean.admit(input(2, "defg")).unwrap();
    let want = texts_by_id(drain(&mut clean));

    // exec event 3 faults a step run; transfer event 6 faults a later
    // downlink — both strictly after the grounding prefill, mid-decode
    let mut s = sched_with_plan(2, 4, 1, "exec@3,transfer@6");
    s.admit(input(1, "abc")).unwrap();
    s.admit(input(2, "defg")).unwrap();
    let (finished, retries) = drain_recovering(&mut s);
    let got = texts_by_id(finished);
    assert_eq!(got, want, "recovery must be token-identical");
    assert_eq!(retries, 2, "each injected fault cost exactly one retry");
    let stats = s.fault_injector().unwrap().stats();
    assert_eq!(stats.faults_injected, 2);
    assert_eq!(stats.ticks_retried, 2);
    assert_eq!(stats.chains_regrounded, 2);
    assert_eq!(stats.requests_failed, 0, "no sequence saw the faults");
}

/// Acceptance: a fused committed-count divergence classifies as a
/// poisoned chain, demotes the fused dispatch depth one rung
/// (k → k/2), and the re-grounded retry still produces the fault-free
/// output.
#[test]
fn fused_divergence_demotes_depth_and_recovers_token_identical() {
    let mut clean = sched_with_plan(2, 8, 8, "");
    clean.admit(input(1, "abc")).unwrap();
    let want = texts_by_id(drain(&mut clean));
    assert_eq!(clean.fused_k(), 8, "fault-free run keeps its depth");

    let mut s = sched_with_plan(2, 8, 8, "diverge@1");
    s.admit(input(1, "abc")).unwrap();
    let (finished, retries) = drain_recovering(&mut s);
    assert_eq!(texts_by_id(finished), want);
    assert_eq!(retries, 1);
    assert_eq!(s.fused_k(), 4, "one ladder rung down");
    let stats = s.fault_injector().unwrap().stats();
    assert_eq!(stats.faults_injected, 1);
    assert_eq!(stats.fused_k_demotions, 1);
    assert_eq!(stats.requests_failed, 0);
}

/// Acceptance: an allocation fault during chain checkout evicts the
/// pool's LRU parked chain (the degradation ladder's first rung) —
/// the switch itself succeeds, and exactly the evicted chain pays a
/// fresh full-KV seed while untouched parked chains resume free.
#[test]
fn alloc_fault_evicts_lru_and_reseeds_exactly_the_evicted_chain() {
    // alloc events: 1 = class-2 seed, 2 = class-1 seed at the first
    // downshift, 3 = the class-2 checkout on the way back (faulted),
    // 4 = the final class-1 resume
    let plan = FaultPlan::parse("alloc@3").unwrap();
    let backend = SimBackend::new(SimCfg::default().with_faults(plan));
    let mut s =
        GroupScheduler::with_classes(Box::new(backend), &[1, 2], sched_cfg(4, 1)).unwrap();

    // seed the full class (the initial active class is the largest)
    s.admit(input(1, "ab")).unwrap();
    drain(&mut s);
    assert_eq!(s.transfer_stats().full_kv_uploads, 1);

    // downshift parks the class-2 chain and seeds class 1
    s.maybe_switch_class(1).unwrap();
    s.admit(input(2, "cd")).unwrap();
    drain(&mut s);
    assert_eq!(s.transfer_stats().full_kv_uploads, 2);

    // upshift: the checkout's allocation event faults; the ladder
    // evicts the LRU parked chain — which is class 2's own, parked
    // first — and the switch still succeeds
    s.maybe_switch_class(2).unwrap();
    let stats = s.fault_injector().unwrap().stats();
    assert_eq!(stats.faults_injected, 1, "the alloc fault fired");
    s.admit(input(3, "ef")).unwrap();
    drain(&mut s);
    assert_eq!(
        s.transfer_stats().full_kv_uploads,
        3,
        "exactly the evicted chain re-seeded"
    );

    // the class-1 chain was NOT evicted: coming back resumes it with
    // zero reseed traffic
    s.maybe_switch_class(1).unwrap();
    s.admit(input(4, "gh")).unwrap();
    drain(&mut s);
    assert_eq!(s.transfer_stats().full_kv_uploads, 3, "no reseed on resume");
    assert!(s.pool_stats().chain_rebuilds_avoided >= 1);
}

/// Count-exact FaultStats parity: the sim backend's injector, driven
/// through a faulted scheduler run, must land on the identical ledger
/// as a replay of the event cadence the PJRT backend's fault wrappers
/// make for the same workload — one alloc event per chain
/// seed/checkout (skipped while registered), one exec + one transfer
/// event per run wrapper (transfer unreached when exec faults), plus
/// the recovery notes the router credits.
#[test]
fn fault_stats_parity_sim_vs_pjrt_wrapper_cadence() {
    // sim side: "abc" at block 4 runs [Prefill, Es, Dual, Es]; exec
    // event 2 faults the first step run, recovery re-grounds + retries
    let mut s = sched_with_plan(2, 4, 1, "exec@2");
    s.admit(input(1, "abc")).unwrap();
    let (_, retries) = drain_recovering(&mut s);
    assert_eq!(retries, 1);
    let sim_stats = s.fault_injector().unwrap().stats();

    // PJRT wrapper replay with the same plan:
    let inj = FaultInjector::new(FaultPlan::parse("exec@2").unwrap());
    // grounding prefill: fresh activation (alloc), then run wrapper
    inj.check(FaultKind::Alloc).unwrap();
    inj.check(FaultKind::Exec).unwrap();
    inj.check(FaultKind::Transfer).unwrap();
    // first ES step: class already registered (no alloc event); the
    // exec check faults before the transfer check is reached
    assert!(inj.check(FaultKind::Exec).is_err());
    // recovery: the faulted run invalidated the chain, so the
    // re-ground prefill re-activates (alloc) and runs clean
    inj.check(FaultKind::Alloc).unwrap();
    inj.check(FaultKind::Exec).unwrap();
    inj.check(FaultKind::Transfer).unwrap();
    inj.note_tick_retried();
    inj.note_chain_regrounded();
    // retried ES step, dual step, final ES step
    for _ in 0..3 {
        inj.check(FaultKind::Exec).unwrap();
        inj.check(FaultKind::Transfer).unwrap();
    }
    assert_eq!(
        inj.stats(),
        sim_stats,
        "sim and PJRT-cadence ledgers must be count-exact"
    );
}
