//! Minimal JSON substrate (parser + writer).
//!
//! The cargo registry is unreachable in this environment, so `serde_json`
//! cannot be used; this module provides the subset of JSON the stack needs:
//! full RFC 8259 parsing into a dynamic [`Json`] value, typed accessors,
//! and a compact writer. Numbers are kept as f64 (adequate for manifests,
//! configs and metrics payloads).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `Json::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("control char in string"));
                    }
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hx = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").idx(2).get("b").as_str(), Some("x"));
        assert!(j.get("c").is_null());
        assert!(j.get("missing").is_null());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse("[3, 3.5, -2]").unwrap();
        assert_eq!(j.idx(0).as_usize(), Some(3));
        assert_eq!(j.idx(1).as_usize(), None);
        assert_eq!(j.idx(2).as_i64(), Some(-2));
        assert_eq!(j.idx(2).as_usize(), None);
    }
}
