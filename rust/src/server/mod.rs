//! HTTP serving front end: /generate, /healthz, /metrics on the in-tree
//! HTTP substrate, dispatching to the router.
//!
//! `/generate` accepts per-request generation parameters alongside the
//! prompt:
//!
//! ```json
//! {"prompt": "1+2=", "gen_len": 8, "temperature": 0.0, "threshold": 0.9}
//! ```
//!
//! and replies with true per-request statistics (iterations, queue and
//! generation time, emitted tokens).
//!
//! Accepted `/generate` parameters:
//!
//!   * `prompt` (string, required) — the input text.
//!   * `gen_len` (int) — generation length; must be a multiple of the
//!     configured block size (else 400).
//!   * `temperature` (float) — sampling temperature; `0.0` is greedy.
//!   * `threshold` (float) — parallel-unmask confidence threshold;
//!     omit for one-token-per-iteration low-confidence decoding.
//!   * `timeout_ms` (int, ≥ 1) — per-request deadline, measured from
//!     submission (queue time included). A request whose budget already
//!     burned away while queued is shed at admission, before any
//!     prefill; an overdue in-flight sequence is retired at its next
//!     block boundary. Both answer the structured timeout error (HTTP
//!     504, counted in `esdllm_timeouts_total`) — never a 500, and
//!     never mid-block. A sequence that *completes* at the same
//!     boundary delivers its result even if overdue.
//!   * `slo` (string) — service class: `"latency_sensitive"` (or
//!     `"latency"`), `"throughput"` (the default), or `"batch"`. The
//!     class picks the priority-queue lane, the load-shed order under
//!     overload (lowest class first), and preemption rank: a
//!     latency-sensitive arrival may preempt a seated lower-class
//!     sequence at a block boundary — the victim parks trajectory-exact
//!     and resumes when pressure drops (see [`crate::router`]).
//!     A present-but-unknown class is a 400, not a silent default.
//!
//! # Error taxonomy
//!
//! Worker-side failures map onto distinct statuses so clients can tell
//! what to do next:
//!
//!   * **400** — invalid parameters (`bad request:`): fix the request.
//!   * **429** — `overloaded:`: the bounded queue is full and the
//!     SLO-aware overload controller shed this request (it outranked
//!     nothing queued) or a queued lower-class victim. Back off and
//!     retry; counted in `esdllm_shed_total`.
//!   * **503** — plain queue-full backpressure under the FIFO baseline
//!     policy (no shedding there), or router shutdown.
//!   * **504** — `timeout:`: the deadline passed, either while queued
//!     (shed at admission), in flight (retired at a block boundary), or
//!     parked as a preemption victim.
//!   * **500** — engine faults that exhausted the router's recovery
//!     ladder — transient injected or device faults are retried and
//!     re-grounded transparently (see [`crate::router`]) and never
//!     surface here — and the handler's own reply bound:
//!     [`ServeCfg::reply_timeout_ms`] caps how long a connection waits
//!     on its oneshot ([`crate::router::OneShot::wait_timeout`]), so a
//!     wedged worker yields a structured `engine worker unresponsive`
//!     error instead of hanging the client forever.
//!
//! There is deliberately NO per-request fused-`k` parameter: the fused
//! k-step dispatch depth is a server-level deployment knob
//! ([`crate::engine::EngineCfg::fused_k`], CLI `--fused-k`) because it
//! changes the *service's* latency cadence, not a request's output.
//! With `fused_k = k`, runs of consecutive early-skip iterations
//! execute as one device dispatch, so host-side checks — EOS
//! retirement, block-boundary admission of queued requests, batch-class
//! switching — happen once per fused run instead of once per
//! iteration. Larger `k` amortizes more dispatch latency (fewer host
//! round-trips per decoded token) but coarsens that cadence: a queued
//! request may wait up to `k − 1` extra iterations for its admission
//! boundary, and an EOS-retired sequence holds its slot up to `k − 1`
//! iterations longer. Decoded text is unaffected — fused runs are
//! trajectory-exact (greedy-eligible requests only; requests with
//! `temperature > 0` or a `threshold` simply decode unfused). The
//! amortization is visible in `/metrics` via `esdllm_fused_execs`,
//! `esdllm_inner_iters_fused`, `esdllm_dispatches_avoided`, and
//! `esdllm_avg_iters_per_fused_dispatch`.

use std::sync::Arc;
use std::time::Duration;

use crate::httpd::{Handler, Request, Response, Server};
use crate::json::{self, Json};
use crate::router::Router;
use crate::scheduler::{SeqParams, SloClass};

pub struct ServeCfg {
    pub bind: String,
    pub http_threads: usize,
    /// Upper bound on how long a `/generate` connection waits for its
    /// reply oneshot. A wedged worker (deadlocked backend, dead thread)
    /// then yields a structured 500 instead of hanging the client
    /// forever. Generous by default — ten minutes — because a legitimate
    /// batch-class request can sit parked or queued for a long time.
    pub reply_timeout_ms: u64,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            bind: "127.0.0.1:0".into(),
            http_threads: 4,
            reply_timeout_ms: 600_000,
        }
    }
}

/// Start the HTTP server over an already-running router.
pub fn serve(cfg: &ServeCfg, router: Router) -> std::io::Result<Server> {
    let reply_timeout = Duration::from_millis(cfg.reply_timeout_ms.max(1));
    let handler: Handler = Arc::new(move |req: &Request| route(req, &router, reply_timeout));
    Server::start(&cfg.bind, cfg.http_threads, handler)
}

fn route(req: &Request, router: &Router, reply_timeout: Duration) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::text(200, "ok"),
        ("GET", "/metrics") => Response::text(200, router.metrics.render()),
        ("POST", "/generate") => generate(req, router, reply_timeout),
        _ => Response::not_found(),
    }
}

fn error_response(status: u16, msg: impl Into<String>) -> Response {
    Response::json(
        status,
        json::obj(vec![("error", json::s(msg.into()))]).to_string(),
    )
}

/// A present-but-malformed field is a client error, not a silent
/// fall-back to the server default; only an absent (or null) key means
/// "use the default".
fn opt_usize(body: &Json, key: &str) -> Result<Option<usize>, String> {
    let v = body.get(key);
    if v.is_null() {
        return Ok(None);
    }
    v.as_usize()
        .map(Some)
        .ok_or_else(|| format!("'{key}' must be a non-negative integer"))
}

fn opt_f32(body: &Json, key: &str) -> Result<Option<f32>, String> {
    let v = body.get(key);
    if v.is_null() {
        return Ok(None);
    }
    v.as_f64()
        .map(|x| Some(x as f32))
        .ok_or_else(|| format!("'{key}' must be a number"))
}

fn opt_u64(body: &Json, key: &str) -> Result<Option<u64>, String> {
    let v = body.get(key);
    if v.is_null() {
        return Ok(None);
    }
    v.as_usize()
        .map(|x| Some(x as u64))
        .ok_or_else(|| format!("'{key}' must be a non-negative integer"))
}

fn opt_slo(body: &Json) -> Result<SloClass, String> {
    let v = body.get("slo");
    if v.is_null() {
        return Ok(SloClass::default());
    }
    v.as_str().and_then(SloClass::parse).ok_or_else(|| {
        "'slo' must be \"latency_sensitive\", \"throughput\", or \"batch\"".to_string()
    })
}

fn generate(req: &Request, router: &Router, reply_timeout: Duration) -> Response {
    let body = match Json::parse(req.body_str()) {
        Ok(b) => b,
        Err(e) => return error_response(400, format!("bad json: {e}")),
    };
    let prompt = match body.get("prompt").as_str() {
        Some(p) => p.to_string(),
        None => return error_response(400, "missing 'prompt'"),
    };
    let parse_params = || -> Result<SeqParams, String> {
        Ok(SeqParams {
            gen_len: opt_usize(&body, "gen_len")?,
            temperature: opt_f32(&body, "temperature")?,
            parallel_threshold: opt_f32(&body, "threshold")?,
            timeout_ms: opt_u64(&body, "timeout_ms")?,
            slo: opt_slo(&body)?,
        })
    };
    let params = match parse_params() {
        Ok(p) => p,
        Err(e) => return error_response(400, e),
    };
    let slot = match router.try_submit(prompt, params) {
        Ok(s) => s,
        // plain queue-full backpressure (FIFO policy) or shutdown; the
        // SLO-aware policy answers overload through the oneshot instead
        Err(()) => return error_response(503, "queue full"),
    };
    // bounded wait: a wedged worker yields a structured error, never a
    // hung connection (replies normally arrive long before this bound)
    let Some(outcome) = slot.wait_timeout(reply_timeout) else {
        return error_response(500, "engine worker unresponsive: reply timed out");
    };
    match outcome {
        Ok(reply) => Response::json(
            200,
            json::obj(vec![
                ("text", json::s(reply.text)),
                ("iterations", json::num(reply.iterations as f64)),
                ("wall_s", json::num(reply.wall_s)),
                ("queue_s", json::num(reply.queue_s)),
                ("tokens", json::num(reply.tokens as f64)),
            ])
            .to_string(),
        ),
        // per-request validation failures surface as client errors
        Err(e) if e.starts_with("bad request:") => error_response(400, e),
        // deadline overruns are a structured gateway-timeout, not a 500
        Err(e) if e.starts_with("timeout:") => error_response(504, e),
        // SLO-aware load shedding: explicit too-many-requests
        Err(e) if e.starts_with("overloaded:") => error_response(429, e),
        Err(e) => error_response(500, e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batcher::BatcherCfg;
    use crate::engine::{EngineCfg, Method};
    use crate::router::{RouterCfg, SchedMode, WorkerBackend};
    use crate::scheduler::sim::SimCfg;

    fn sim_router() -> Router {
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.backend = WorkerBackend::Sim(SimCfg::default());
        cfg.batcher = BatcherCfg { max_batch: 2, flush_ms: 2 };
        cfg.queue_cap = 4;
        cfg.mode = SchedMode::Continuous;
        Router::start(cfg)
    }

    fn post(router: &Router, body: &[u8]) -> Response {
        let req = Request {
            method: "POST".into(),
            path: "/generate".into(),
            headers: vec![],
            body: body.to_vec(),
        };
        route(&req, router, Duration::from_secs(60))
    }

    #[test]
    fn bad_json_is_400() {
        let router = sim_router();
        assert_eq!(post(&router, b"not-json").status, 400);
        let req2 = Request {
            method: "GET".into(),
            path: "/healthz".into(),
            headers: vec![],
            body: vec![],
        };
        assert_eq!(route(&req2, &router, Duration::from_secs(60)).status, 200);
        router.shutdown();
    }

    #[test]
    fn generate_round_trip_with_params() {
        let router = sim_router();
        let resp = post(&router, br#"{"prompt": "7*6=42", "gen_len": 8}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("text").as_str(), Some("7*6=42"));
        assert!(j.get("iterations").as_usize().unwrap() > 0);
        assert!(j.get("tokens").as_usize().unwrap() > 0);
        router.shutdown();
    }

    #[test]
    fn overdue_request_is_a_504_gateway_timeout() {
        // slow sim: the first block boundary lands well past the 1 ms
        // deadline, so the sequence retires with the structured timeout
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(2000, 1000, 1000));
        cfg.batcher = BatcherCfg { max_batch: 1, flush_ms: 2 };
        cfg.queue_cap = 4;
        cfg.mode = SchedMode::Continuous;
        let router = Router::start(cfg);
        let resp = post(&router, br#"{"prompt": "abcdefgh", "timeout_ms": 1}"#);
        assert_eq!(resp.status, 504, "{}", String::from_utf8_lossy(&resp.body));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(j.get("error").as_str().unwrap().starts_with("timeout:"));
        assert_eq!(router.metrics.timeouts_total.get(), 1);
        // timeout_ms = 0 can never be met: a client error, not a 504
        assert_eq!(post(&router, br#"{"prompt": "ab", "timeout_ms": 0}"#).status, 400);
        router.shutdown();
    }

    #[test]
    fn invalid_gen_len_is_400() {
        let router = sim_router();
        // integer but not a block multiple → rejected by the scheduler
        assert_eq!(post(&router, br#"{"prompt": "1+1=", "gen_len": 3}"#).status, 400);
        // present but malformed must be 400, not a silent default
        for body in [
            br#"{"prompt": "1+1=", "gen_len": -8}"#.as_slice(),
            br#"{"prompt": "1+1=", "gen_len": 8.5}"#.as_slice(),
            br#"{"prompt": "1+1=", "temperature": "hot"}"#.as_slice(),
        ] {
            let resp = post(&router, body);
            assert_eq!(resp.status, 400, "{}", String::from_utf8_lossy(&resp.body));
        }
        router.shutdown();
    }

    #[test]
    fn slo_class_field_round_trips_and_validates() {
        let router = sim_router();
        // every accepted spelling serves normally
        for body in [
            br#"{"prompt": "ab", "slo": "latency_sensitive"}"#.as_slice(),
            br#"{"prompt": "ab", "slo": "latency"}"#.as_slice(),
            br#"{"prompt": "ab", "slo": "throughput"}"#.as_slice(),
            br#"{"prompt": "ab", "slo": "batch"}"#.as_slice(),
        ] {
            let resp = post(&router, body);
            assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        }
        // present-but-unknown is a client error, not a silent default
        for body in [
            br#"{"prompt": "ab", "slo": "urgent"}"#.as_slice(),
            br#"{"prompt": "ab", "slo": 3}"#.as_slice(),
        ] {
            let resp = post(&router, body);
            assert_eq!(resp.status, 400, "{}", String::from_utf8_lossy(&resp.body));
        }
        router.shutdown();
    }

    #[test]
    fn overload_shed_is_a_structured_429() {
        // one slot + queue capacity one, slow sim: the first request
        // holds the slot, the second fills the queue, and a third of the
        // same class outranks nothing → the overload controller sheds it
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(2000, 1000, 1000));
        cfg.batcher = BatcherCfg { max_batch: 1, flush_ms: 2 };
        cfg.queue_cap = 1;
        cfg.mode = SchedMode::Continuous;
        let router = Router::start(cfg);
        let r1 = router.clone();
        let t1 = std::thread::spawn(move || post(&r1, br#"{"prompt": "abcdefgh"}"#));
        std::thread::sleep(Duration::from_millis(10));
        let r2 = router.clone();
        let t2 = std::thread::spawn(move || post(&r2, br#"{"prompt": "cdef"}"#));
        std::thread::sleep(Duration::from_millis(10));
        let resp = post(&router, br#"{"prompt": "xy"}"#);
        assert_eq!(resp.status, 429, "{}", String::from_utf8_lossy(&resp.body));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(j.get("error").as_str().unwrap().starts_with("overloaded:"));
        assert!(router.metrics.shed_total.get() >= 1);
        // the in-flight requests are unaffected by the shed
        assert_eq!(t1.join().unwrap().status, 200);
        assert_eq!(t2.join().unwrap().status, 200);
        router.shutdown();
    }

    #[test]
    fn wedged_worker_yields_a_structured_error_not_a_hang() {
        // regression for OneShot::wait_timeout: with a reply bound far
        // below the (slow) generation time, the handler must answer with
        // a structured 500 instead of blocking the connection until the
        // worker gets around to replying
        let mut cfg = RouterCfg::new(
            EngineCfg::new("llada-nano", Method::EsDllm),
            std::path::PathBuf::from("/nonexistent"),
        );
        cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(2000, 1000, 1000));
        cfg.batcher = BatcherCfg { max_batch: 1, flush_ms: 2 };
        cfg.queue_cap = 4;
        cfg.mode = SchedMode::Continuous;
        let router = Router::start(cfg);
        let req = Request {
            method: "POST".into(),
            path: "/generate".into(),
            headers: vec![],
            body: br#"{"prompt": "abcdefgh"}"#.to_vec(),
        };
        let t0 = std::time::Instant::now();
        let resp = route(&req, &router, Duration::from_millis(5));
        assert!(t0.elapsed() < Duration::from_secs(5), "bounded, not a hang");
        assert_eq!(resp.status, 500, "{}", String::from_utf8_lossy(&resp.body));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert!(j.get("error").as_str().unwrap().contains("unresponsive"));
        router.shutdown();
    }
}
