//! PJRT runtime: loads HLO-text artifacts, compiles them on the CPU PJRT
//! client, keeps model parameters resident as device buffers, and runs
//! decode-step executables with [`HostTensor`] I/O.
//!
//! Transfer discipline — the two halves of the decode-loop downlink/
//! uplink contract this layer enforces:
//!
//!   * **Sliced downlink.** Executions download only the outputs the
//!     host actually reads. [`Runtime::run_retained`] leaves
//!     device-chained outputs (KV/indicator/confidence under the
//!     device-apply path) on the device entirely, and the compile
//!     pipeline slices the remaining logit output to the gen region
//!     (`logits_gen`, `[B, gen, V]`) or the selected step rows
//!     (`[B, k, V]`) in-graph — the prompt-region rows of a grounding
//!     prefill never cross the bus. The resident planner
//!     ([`resident::TransferStats`]) accounts the shipped and saved
//!     bytes (`d2h_bytes_shipped` / `d2h_bytes_saved`).
//!   * **Donation (input-output aliasing).** For executables whose
//!     manifest marks retained-chaining signatures with `alias`,
//!     [`Runtime::executable`] declares a PJRT input-output alias config
//!     at compile time ([`xla::PjRtClient::compile_with_io_aliases`]):
//!     the chained cache update then writes its input's device buffer in
//!     place instead of materializing a second copy, so device memory
//!     for a chained tensor is bounded at ONE live allocation even
//!     during execution. Donation invalidates the donated argument
//!     buffer — callers must replace their handle with the new output
//!     after every run, which is exactly what the chain code in
//!     [`crate::scheduler::PjrtBackend`] does (and what
//!     [`resident::DeviceGroupCaches::invalidate`] unwinds on failure).
//!   * **Context-tier executables.** The manifest's
//!     `generation.ctx_tiers` ladder names a family of step variants
//!     compiled at shorter key lengths (`es_apply_b8` →
//!     `es_apply_b8_ctx64`, resolved per dispatch via
//!     [`crate::manifest::Manifest::tier_exe_name`]): same program,
//!     `kv_len`-/`gen_live`-shaped cache and confidence operands, so a
//!     decode step whose live context fits a lower tier runs — and
//!     transfers — at that tier's shapes instead of the compiled
//!     maximum. The scheduler picks the tier from the group's live
//!     frontier; this layer just compiles, caches, and runs whichever
//!     family member the dispatch names (block-sliced prefill variants
//!     with their `blk_start` operand and `logits_blk` output
//!     included). Tier switches reuse nothing across shapes: the
//!     grounding prefill at the new tier reseeds the chain, exactly
//!     like a batch-class switch.
//!
//! Threading model: PJRT wrapper types hold raw pointers and are not
//! `Send`/`Sync`; each engine worker thread owns its own `Runtime`
//! (the CPU client is cheap). The coordinator communicates with workers
//! over channels, never sharing runtime objects. The pooled-residency
//! layer respects the same boundary: a checked-out
//! [`resident::ResidentChain`]'s device handles (donated buffers
//! included) never cross threads — only the `Send` host-side
//! [`resident::ChainPlan`] travels through the shared
//! [`resident::ResidencyPool`], and PJRT workers key their pooled
//! entries by a per-thread owner id so no other worker can resume a
//! chain whose buffers it cannot touch. Note donation makes parked
//! handles *single-owner by construction*: a donated input buffer was
//! consumed in place by the execution that produced the retained
//! output, so there is never a second live copy another worker could
//! have safely aliased anyway.

pub mod resident;
pub mod tensor;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use crate::manifest::{ArchSpec, DType, ExeSpec, Manifest};
use crate::tokenizer::Tokenizer;
use crate::weights::Checkpoint;
use resident::TransferStats;
use tensor::{HostTensor, TensorView};

pub struct Runtime {
    pub manifest: Manifest,
    pub tokenizer: Tokenizer,
    client: xla::PjRtClient,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    params: RefCell<HashMap<String, Rc<Vec<xla::PjRtBuffer>>>>,
    /// cumulative counters for §Perf accounting
    pub stats: RefCell<RuntimeStats>,
}

#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    /// bytes physically uploaded through this runtime (the PJRT CPU
    /// client re-ships a whole buffer whenever any of it changed)
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub exec_seconds: f64,
    pub transfer_seconds: f64,
    /// logical per-kind ledger from the resident-cache planner: what a
    /// delta-capable transport ships, and what residency saved vs the
    /// clone-and-reupload baseline (see [`resident::TransferStats`])
    pub transfer: TransferStats,
}

impl Runtime {
    pub fn load(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir: PathBuf = artifacts_dir.into();
        let manifest = Manifest::load(&dir)?;
        let tokenizer = Tokenizer::load(&dir.join("vocab.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;
        Ok(Runtime {
            manifest,
            tokenizer,
            client,
            exes: RefCell::new(HashMap::new()),
            params: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchSpec> {
        self.manifest.arch(name)
    }

    /// Compile (and cache) an executable by `(arch, exe)` name. When the
    /// manifest marks retained-chaining signatures with `alias`, the
    /// input-output alias pairs are declared to PJRT here, at compile
    /// time — execution then donates those argument buffers, updating
    /// the chained cache tensors in place (callers must replace their
    /// handles with the retained outputs after every run).
    pub fn executable(
        &self,
        arch: &ArchSpec,
        exe: &ExeSpec,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}/{}", arch.name, exe.name);
        if let Some(e) = self.exes.borrow().get(&key) {
            return Ok(e.clone());
        }
        let path = self.manifest.root.join(&exe.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let aliases = exe.alias_pairs(arch.params.len());
        let compiled = if aliases.is_empty() {
            self.client.compile(&comp)
        } else {
            self.client.compile_with_io_aliases(&comp, &aliases)
        }
        .map_err(|e| anyhow!("compiling {}: {e}", exe.name))?;
        log::info!(
            "compiled {key} in {:.2}s ({} donated input-output aliases)",
            t0.elapsed().as_secs_f64(),
            aliases.len()
        );
        let rc = Rc::new(compiled);
        self.exes.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Load (and cache) a checkpoint's parameters as resident device
    /// buffers, keyed by `(arch, checkpoint)`.
    pub fn checkpoint_params(
        &self,
        arch: &ArchSpec,
        checkpoint: &str,
    ) -> Result<Rc<Vec<xla::PjRtBuffer>>> {
        let key = format!("{}/{checkpoint}", arch.name);
        if let Some(p) = self.params.borrow().get(&key) {
            return Ok(p.clone());
        }
        let file = arch
            .checkpoints
            .get(checkpoint)
            .ok_or_else(|| anyhow!("arch {} has no checkpoint {checkpoint}", arch.name))?;
        let ck = Checkpoint::load(&self.manifest.root.join(file), arch)?;
        let mut buffers = Vec::with_capacity(ck.tensors.len());
        for (name, shape, data) in &ck.tensors {
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(data, shape, None)
                .map_err(|e| anyhow!("uploading param {name}: {e}"))?;
            buffers.push(buf);
        }
        log::info!(
            "loaded checkpoint {key}: {} tensors, {} params",
            ck.tensors.len(),
            ck.total_params()
        );
        let rc = Rc::new(buffers);
        self.params.borrow_mut().insert(key, rc.clone());
        Ok(rc)
    }

    /// Upload a host tensor. For bf16 the returned [`xla::Literal`] MUST be
    /// kept alive until the buffer has been consumed: PJRT's
    /// `BufferFromHostLiteral` copies asynchronously, so dropping the
    /// literal early is a use-after-free (manifests as nondeterministic
    /// `shape_util.cc` CHECK failures / segfaults).
    ///
    /// (The direct raw-bytes route is unusable here: the xla crate's
    /// `buffer_from_host_raw_bytes` passes `ElementType as i32` where the C
    /// API expects a `PrimitiveType`, mapping Bf16 → F32.)
    pub fn upload_tensor(
        &self,
        t: &HostTensor,
    ) -> Result<(xla::PjRtBuffer, Option<xla::Literal>)> {
        self.upload_tensor_view(&t.view())
    }

    /// Borrowed-view upload: streams straight from the caller's storage
    /// (a cache vector or a pooled scratch buffer) with no host-side
    /// clone. Counts the physical bytes and time into [`RuntimeStats`].
    pub fn upload_tensor_view(
        &self,
        t: &TensorView<'_>,
    ) -> Result<(xla::PjRtBuffer, Option<xla::Literal>)> {
        let t0 = std::time::Instant::now();
        let dims = t.shape();
        let out = match t {
            TensorView::F32 { data, .. } => {
                let buf = self
                    .client
                    .buffer_from_host_buffer::<f32>(data, dims, None)
                    .map_err(|e| anyhow!("upload: {e}"))?;
                (buf, None)
            }
            TensorView::I32 { data, .. } => {
                let buf = self
                    .client
                    .buffer_from_host_buffer::<i32>(data, dims, None)
                    .map_err(|e| anyhow!("upload: {e}"))?;
                (buf, None)
            }
            TensorView::Bf16 { data, .. } => {
                // bf16 bits travel as raw little-endian bytes. On an LE
                // host the u16 buffer already IS that byte sequence, so
                // reinterpret in place — re-materializing the bytes here
                // would silently reintroduce the full-tensor copy the
                // resident-cache layer exists to remove.
                #[cfg(target_endian = "little")]
                let lit = {
                    // SAFETY: u8 has alignment 1 and no validity
                    // invariants, so viewing a u16 slice's memory as
                    // bytes is always sound; with an align-1 target the
                    // prefix/suffix returned by align_to are empty.
                    let bytes: &[u8] = unsafe { data.align_to::<u8>().1 };
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::Bf16,
                        dims,
                        bytes,
                    )
                    .map_err(|e| anyhow!("bf16 literal: {e}"))?
                };
                #[cfg(target_endian = "big")]
                let lit = {
                    let mut bytes = Vec::with_capacity(data.len() * 2);
                    for v in data.iter() {
                        bytes.extend_from_slice(&v.to_le_bytes());
                    }
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::Bf16,
                        dims,
                        &bytes,
                    )
                    .map_err(|e| anyhow!("bf16 literal: {e}"))?
                };
                let buf = self
                    .client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e| anyhow!("upload: {e}"))?;
                (buf, Some(lit))
            }
        };
        let mut st = self.stats.borrow_mut();
        st.upload_bytes += t.byte_len() as u64;
        st.transfer_seconds += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn literal_to_host(&self, lit: &xla::Literal, sig_dtype: DType) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("output shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        Ok(match sig_dtype {
            DType::F32 => HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?,
            },
            DType::I32 => HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?,
            },
            DType::Bf16 => {
                // no typed bf16 host access in the xla crate: convert to f32
                // on the literal then re-narrow (exact — values are bf16)
                let as_f32 = lit
                    .convert(xla::PrimitiveType::F32)
                    .map_err(|e| anyhow!("bf16->f32: {e}"))?;
                let data = as_f32.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
                HostTensor::Bf16 { shape: dims, data: tensor::f32s_to_bf16(&data) }
            }
        })
    }

    /// Execute `(arch, exe, checkpoint)` with non-parameter inputs
    /// `inputs` (order per the manifest). Returns host tensors for every
    /// output in manifest order.
    pub fn run(
        &self,
        arch: &ArchSpec,
        exe: &ExeSpec,
        checkpoint: &str,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let args: Vec<ExecArg<'_>> = inputs.iter().map(|t| ExecArg::Host(t.view())).collect();
        self.run_args(arch, exe, checkpoint, &args)
    }

    /// Lower-level execution entry: each argument is either a borrowed
    /// host view uploaded this call, or a device buffer retained from an
    /// earlier upload by the resident-cache layer (zero host↔device
    /// traffic). The step hot path uses this to avoid the historical
    /// full-tensor host clones and re-uploads. Every output is
    /// downloaded — the retain-nothing case of [`Runtime::run_retained`].
    pub fn run_args(
        &self,
        arch: &ArchSpec,
        exe: &ExeSpec,
        checkpoint: &str,
        args: &[ExecArg<'_>],
    ) -> Result<Vec<HostTensor>> {
        let retain = vec![false; exe.outputs.len()];
        let out = self.run_retained(arch, exe, checkpoint, args, &retain)?;
        Ok(out
            .host
            .into_iter()
            .map(|t| t.expect("nothing retained, every output downloaded"))
            .collect())
    }

    /// Execute with per-output retention: outputs whose `retain` flag is
    /// set stay on the device as [`xla::PjRtBuffer`]s (never downloaded —
    /// the device-apply cache chain feeds them back as
    /// [`ExecArg::Device`] inputs on the next call); the rest are
    /// downloaded as host tensors. This is the entry point that removes
    /// the per-tick D2H/H2D cache bounce: a retained KV block never
    /// crosses the PCIe bus mid-flight.
    ///
    /// For executables compiled with an input-output alias config
    /// (manifest `alias` on the retained signatures — see
    /// [`Runtime::executable`]), execution additionally *donates* the
    /// chained [`ExecArg::Device`] arguments: the retained output IS the
    /// input allocation, updated in place, so there is no transient
    /// second copy during execution and the donated argument buffer must
    /// not be used again. The chain code replaces its handles with the
    /// retained outputs unconditionally, which satisfies that contract
    /// for aliased and unaliased builds alike.
    pub fn run_retained(
        &self,
        arch: &ArchSpec,
        exe: &ExeSpec,
        checkpoint: &str,
        args: &[ExecArg<'_>],
        retain: &[bool],
    ) -> Result<RunOutputs> {
        if retain.len() != exe.outputs.len() {
            return Err(anyhow!(
                "{}: retain flags for {} outputs, manifest says {}",
                exe.name,
                retain.len(),
                exe.outputs.len()
            ));
        }
        self.check_args(exe, args)?;
        let compiled = self.executable(arch, exe)?;
        let params = self.checkpoint_params(arch, checkpoint)?;

        // keep bf16 literals alive until after execution (async H2D copy)
        let mut fresh: Vec<Option<(xla::PjRtBuffer, Option<xla::Literal>)>> =
            Vec::with_capacity(args.len());
        for a in args {
            fresh.push(match a {
                ExecArg::Host(v) => Some(self.upload_tensor_view(v)?),
                ExecArg::Device(_) => None,
            });
        }
        let mut argrefs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(params.len() + args.len());
        argrefs.extend(params.iter());
        for (a, f) in args.iter().zip(&fresh) {
            argrefs.push(match a {
                ExecArg::Device(buf) => *buf,
                ExecArg::Host(_) => &f.as_ref().expect("host arg uploaded").0,
            });
        }

        let t_exec = std::time::Instant::now();
        let out = compiled
            .execute_untupled::<&xla::PjRtBuffer>(&argrefs)
            .map_err(|e| anyhow!("execute {}: {e}", exe.name))?;
        let exec_s = t_exec.elapsed().as_secs_f64();
        let buffers = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output device", exe.name))?;
        if buffers.len() != exe.outputs.len() {
            return Err(anyhow!(
                "{}: got {} outputs, manifest says {}",
                exe.name,
                buffers.len(),
                exe.outputs.len()
            ));
        }

        let t_down = std::time::Instant::now();
        let mut host: Vec<Option<HostTensor>> = Vec::with_capacity(buffers.len());
        let mut retained: Vec<Option<xla::PjRtBuffer>> =
            Vec::with_capacity(buffers.len());
        let mut down_bytes = 0u64;
        for ((buf, sig), &keep) in buffers.into_iter().zip(&exe.outputs).zip(retain) {
            if keep {
                host.push(None);
                retained.push(Some(buf));
            } else {
                let lit = buf
                    .to_literal_sync()
                    .map_err(|e| anyhow!("download {}: {e}", exe.name))?;
                let t = self.literal_to_host(&lit, sig.dtype)?;
                down_bytes += (t.elements() * t.dtype().bytes()) as u64;
                host.push(Some(t));
                retained.push(None);
            }
        }
        let download_s = t_down.elapsed().as_secs_f64();

        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.download_bytes += down_bytes;
        st.exec_seconds += exec_s;
        st.transfer_seconds += download_s;
        Ok(RunOutputs { host, retained })
    }

    /// Input count + host-view shape/dtype validation shared by the
    /// execution entry points.
    fn check_args(&self, exe: &ExeSpec, args: &[ExecArg<'_>]) -> Result<()> {
        if args.len() != exe.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                exe.name,
                exe.inputs.len(),
                args.len()
            ));
        }
        for (a, sig) in args.iter().zip(&exe.inputs) {
            // resident device buffers carry no host-side shape to check;
            // the planner that retained them is responsible for key match
            if let ExecArg::Host(v) = a {
                if v.shape() != sig.shape.as_slice() || v.dtype() != sig.dtype {
                    return Err(anyhow!(
                        "{}: input {} shape/dtype mismatch: got {:?} {:?}, want {:?} {:?}",
                        exe.name, sig.name, v.shape(), v.dtype(), sig.shape, sig.dtype
                    ));
                }
            }
        }
        Ok(())
    }

    /// Merge a resident-planner ledger delta into this runtime's stats
    /// (so `take_stats` reports the logical transfer picture alongside
    /// the physical byte counters).
    pub fn note_transfer(&self, delta: &TransferStats) {
        self.stats.borrow_mut().transfer.merge(delta);
    }

    pub fn take_stats(&self) -> RuntimeStats {
        std::mem::take(&mut *self.stats.borrow_mut())
    }
}

/// One executable input: a borrowed host view (uploaded this call) or a
/// device buffer retained by the resident-cache layer.
pub enum ExecArg<'a> {
    Host(TensorView<'a>),
    Device(&'a xla::PjRtBuffer),
}

/// Result of [`Runtime::run_retained`]: per manifest output position,
/// exactly one of `host` (downloaded) or `retained` (left on device for
/// chaining into the next call) is populated.
pub struct RunOutputs {
    pub host: Vec<Option<HostTensor>>,
    pub retained: Vec<Option<xla::PjRtBuffer>>,
}

impl RunOutputs {
    /// The downloaded tensor at output position `i` (errors if that
    /// output was retained on device — a signature/flags mismatch).
    pub fn host_at(&self, i: usize, what: &str) -> Result<&HostTensor> {
        self.host
            .get(i)
            .and_then(|t| t.as_ref())
            .ok_or_else(|| anyhow!("output {i} ({what}) was not downloaded"))
    }

    /// Take ownership of the retained device buffer at output position
    /// `i` (errors if that output was downloaded).
    pub fn take_retained(&mut self, i: usize, what: &str) -> Result<xla::PjRtBuffer> {
        self.retained
            .get_mut(i)
            .and_then(|b| b.take())
            .ok_or_else(|| anyhow!("output {i} ({what}) was not retained on device"))
    }
}

/// Locate the artifacts directory: $ESDLLM_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("ESDLLM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

impl Runtime {
    /// Context for error messages when artifacts are missing.
    pub fn load_default() -> Result<Runtime> {
        let dir = default_artifacts_dir();
        Self::load(&dir).with_context(|| {
            format!(
                "loading artifacts from {} (run `make artifacts` first, or set \
                 ESDLLM_ARTIFACTS)",
                dir.display()
            )
        })
    }
}

impl Runtime {
    /// Debug helper: compile without the Rc cache.
    pub fn client_compile(&self, exe: &ExeSpec) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.root.join(&exe.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow!("compiling: {e}"))
    }
}
