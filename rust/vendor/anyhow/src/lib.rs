//! Minimal in-tree substitute for the `anyhow` crate, providing the
//! subset this workspace uses: [`Error`], [`Result`], the [`anyhow!`]
//! macro, and the [`Context`] extension trait. Vendored so the build
//! needs no registry access; the API mirrors upstream so the real crate
//! can be swapped back in without source changes.

use std::fmt;

/// A string-backed error with an optional context chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: ctx.to_string(), source: Some(Box::new(self)) }
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole context chain, like upstream
            self.write_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(src) = self.source.as_deref() {
            write!(f, "\n\nCaused by:\n    ")?;
            src.write_chain(f)?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // preserve the std source chain as context entries
        let mut msgs = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut source = None;
        for msg in msgs.into_iter().rev() {
            source = Some(Box::new(Error { msg, source }));
        }
        Error { msg: e.to_string(), source }
    }
}

/// Construct an [`Error`] from a format string (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: ctx.to_string(), source: Some(Box::new(Error::msg(e))) })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: f().to_string(), source: Some(Box::new(Error::msg(e))) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
    }

    #[test]
    fn context_chain_renders_alternate() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.with_context(|| "loading file").unwrap_err();
        assert_eq!(format!("{e}"), "loading file");
        assert_eq!(format!("{e:#}"), "loading file: missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(inner().is_err());
    }
}
