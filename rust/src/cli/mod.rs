//! CLI argument-parsing substrate (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    spec: Vec<(String, String, String)>, // (name, default, help)
}

impl Args {
    /// Parse from an explicit arg list (first element = argv[1]).
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn parse() -> Result<Args, String> {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Declare an option for usage output (returns self for chaining).
    pub fn declare(mut self, name: &str, default: &str, help: &str) -> Self {
        self.spec.push((name.into(), default.into(), help.into()));
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut u = format!("usage: {prog} [options]\n");
        for (n, d, h) in &self.spec {
            u.push_str(&format!("  --{n:<24} {h} (default: {d})\n"));
        }
        u
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn kv_forms() {
        // note: a bare `--flag` consumes a following non-flag token as its
        // value; boolean flags go last or use `--flag=true`
        let a = parse(&["--x", "1", "--y=2", "pos", "--flag"]);
        assert_eq!(a.usize("x", 0), 1);
        assert_eq!(a.usize("y", 0), 2);
        assert!(a.bool("flag"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.str("model", "llada"), "llada");
        assert_eq!(a.f64("alpha", 0.5), 0.5);
        assert!(!a.bool("nothing"));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }
}
