//! Benchmark harness substrate (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with robust statistics, and a
//! table printer that renders the paper-style rows the `rust/benches/*`
//! binaries emit. `cargo bench` runs these via `harness = false`.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        // NaN samples used to panic the sort (partial_cmp().unwrap() —
        // the same bug class as the Histogram::quantiles fix) and would
        // silently poison mean/median if merely sorted last; a bench run
        // must survive a poisoned timing AND report honest finite
        // statistics, so NaN observations are dropped up front
        samples.retain(|x| !x.is_nan());
        if samples.is_empty() {
            return Stats {
                n: 0,
                mean_s: f64::NAN,
                median_s: f64::NAN,
                p10_s: f64::NAN,
                p90_s: f64::NAN,
                min_s: f64::NAN,
                max_s: f64::NAN,
                std_s: f64::NAN,
            };
        }
        samples.sort_by(f64::total_cmp);
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2) as f64;
        let pct = |p: f64| samples[((n as f64 - 1.0) * p).round() as usize];
        Stats {
            n,
            mean_s: mean,
            median_s: pct(0.5),
            p10_s: pct(0.1),
            p90_s: pct(0.9),
            min_s: samples[0],
            max_s: samples[n - 1],
            std_s: var.sqrt(),
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Time a single run of `f`, returning (result, seconds).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64())
}

// ---------------------------------------------------------------------------
// paper-style table printer
// ---------------------------------------------------------------------------

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("\n== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Also write a CSV next to stdout output for figure pipelines.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 10.0);
        assert!(s.p10_s <= s.median_s && s.median_s <= s.p90_s);
        assert_eq!(s.n, 4);
    }

    #[test]
    fn from_samples_survives_nan() {
        // regression: partial_cmp().unwrap() panicked here on any NaN
        // sample. Poisoned timings are dropped, so the remaining
        // statistics are finite and honest — not NaN-skewed.
        let s = Stats::from_samples(vec![3.0, f64::NAN, 1.0]);
        assert_eq!(s.n, 2, "the NaN observation is dropped");
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 3.0);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!(s.median_s.is_finite());

        // all-NaN input: no panic, explicitly empty stats
        let e = Stats::from_samples(vec![f64::NAN, f64::NAN]);
        assert_eq!(e.n, 0);
        assert!(e.mean_s.is_nan() && e.median_s.is_nan());
    }

    #[test]
    fn bench_counts_iterations() {
        let mut calls = 0;
        let s = bench(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("333"));
        assert!(r.contains("== T =="));
    }
}

/// Per-cell sample count for table benches: `ESDLLM_BENCH_N` overrides
/// (the default keeps full `cargo bench` under the single-core budget).
pub fn bench_n(default: usize) -> usize {
    std::env::var("ESDLLM_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Arch list filter: `ESDLLM_BENCH_ARCH=llada-nano` restricts multi-arch
/// benches.
pub fn bench_archs() -> Vec<String> {
    match std::env::var("ESDLLM_BENCH_ARCH") {
        Ok(a) if !a.is_empty() => vec![a],
        _ => vec!["llada-nano".to_string(), "dream-nano".to_string()],
    }
}
