//! Deterministic simulation backend for the scheduler: a model-free
//! [`StepBackend`](super::StepBackend) whose "model" echoes the prompt
//! and then EOS-fills, with confidence decreasing along the gen region
//! (so greedy low-confidence remasking decodes left to right).
//!
//! Because the completion length equals the prompt length, a mixed
//! workload naturally produces sequences that finish after different
//! block counts — exactly the divergence continuous batching exploits.
//! Per-plan costs are simulated with configurable sleeps so scheduler
//! benchmarks measure realistic occupancy effects without PJRT.
//! Everything here is exercised by `cargo test` / `cargo bench` on
//! machines with no artifacts and no PJRT library.
//!
//! The sim also carries a [`DeviceGroupCaches`] resident layer —
//! by default in [`ApplyMode::Device`], routed through the **same**
//! composite planner calls
//! ([`DeviceGroupCaches::sync_prefill_device`] /
//! [`DeviceGroupCaches::sync_step_device`] /
//! [`DeviceGroupCaches::sync_step_device_k`] for fused k-step
//! dispatches, which model k inner iterations per sync) as the PJRT
//! device-apply backend, so the two transfer ledgers are byte-exact by
//! construction
//! (asserted in `tests/transfer_accounting.rs`): after the one-time
//! seed, steady-state steps ship only block tokens and the batch-bit
//! occupancy mask, with KV, indicator, and confidence all chained on
//! device (donated in place under the alias config) and the downlink
//! sliced to gen-region logit rows — `[B, gen, V]` per grounding
//! prefill, the `final_keep` selected rows + positions per step
//! ([`SimCfg::n_sel`]: the whole block for dual, the default-skip
//! survivors for ES), never the `[B, ctx, V]` full context. [`SimCfg::apply`] can flip the layer to [`ApplyMode::Host`]
//! to model the stateless-executable fallback (outputs scattered
//! host-side, dirty rows re-shipped as deltas) — the comparison the
//! `perf_hotpath` Host-vs-Device apply section measures.
//!
//! Pooled residency: the sim keeps one resident layer per batch class
//! and parks/resumes chain plans through a shared
//! [`ResidencyPool`] under the shared owner `None` — no real device
//! buffers exist, so a chain parked by one worker is genuinely
//! resumable by any other. That makes the sim the reference model for
//! true cross-worker device sharing (the PJRT backend, pinned by the
//! non-`Send` constraint, shares only within a worker), while its
//! planner calls stay byte-exact with the PJRT ledger.
//!
//! Fault model: [`SimCfg::fault_plan`] arms a deterministic
//! [`FaultInjector`]. Every run consumes one `exec` and one `transfer`
//! event *after* the planner sync and *before* any host logits are
//! written, every chain seed/checkout consumes one `alloc` event, and
//! every fused dispatch additionally consumes one `diverge` event — so
//! a faulted tick never mutates the host trajectory and is safely
//! re-plannable after a re-ground. An injected allocation fault first
//! evicts the pool's LRU parked entry (the modeled free-device-memory
//! rung) and only surfaces when the pool is empty.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::cache::{GroupCaches, StepPlan};
use crate::fault::{FaultInjector, FaultKind, FaultPlan};
use crate::manifest::Dims;
use crate::rng::SplitMix;
use crate::runtime::resident::{
    chain_seed_bytes, ApplyMode, DeviceGroupCaches, PoolStats, PrefixCache, PrefixStats,
    ResidencyPool, TransferStats,
};
use crate::sampler::{decide_unmask, SamplerCfg, UnmaskInput};
use crate::tokenizer::Tokenizer;

use super::{FusedCommits, StepBackend};

/// Geometry + per-plan simulated latency + apply-mode selection.
#[derive(Debug, Clone)]
pub struct SimCfg {
    pub dims: Dims,
    pub prefill_cost: Duration,
    pub dual_cost: Duration,
    pub es_cost: Duration,
    /// how executable outputs reach the resident copy (Device models the
    /// device-apply PJRT path; Host models the stateless fallback)
    pub apply: ApplyMode,
    /// deterministic fault-injection schedule (empty = no faults). The
    /// sim consumes one `exec` and one `transfer` event per executable
    /// run, one `alloc` event per chain seed/checkout, and one
    /// `diverge` event per fused dispatch — the same event cadence the
    /// stub device models, so an ordinal faults at the same point on
    /// both layers.
    pub fault_plan: FaultPlan,
    /// modeled context-tier family (ascending live-context lengths the
    /// compiled executables exist at; empty = untiered, the full `ctx`
    /// only). Mirrors the manifest's `generation.ctx_tiers` so the sim
    /// planner prices pruned ticks byte-exactly against the PJRT
    /// ledger.
    pub ctx_tiers: Vec<usize>,
}

impl Default for SimCfg {
    fn default() -> SimCfg {
        SimCfg {
            // the artifact geometry (manifest.json), with tiny model dims
            // so host-side caches stay cheap
            dims: Dims {
                vocab: 64,
                d_model: 8,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 1,
                d_ff: 16,
                head_dim: 4,
                prompt_len: 48,
                gen_len: 32,
                ctx: 80,
            },
            prefill_cost: Duration::ZERO,
            dual_cost: Duration::ZERO,
            es_cost: Duration::ZERO,
            apply: ApplyMode::Device,
            fault_plan: FaultPlan::default(),
            ctx_tiers: Vec::new(),
        }
    }
}

impl SimCfg {
    /// Latency model mirroring the measured executable cost ordering:
    /// prefill > dual step > es step.
    pub fn with_costs(mut self, prefill_us: u64, dual_us: u64, es_us: u64) -> SimCfg {
        self.prefill_cost = Duration::from_micros(prefill_us);
        self.dual_cost = Duration::from_micros(dual_us);
        self.es_cost = Duration::from_micros(es_us);
        self
    }

    /// Selected logit rows a step of `plan` downloads — the sim's model
    /// of the compiled executables' `final_keep`: the whole block for a
    /// dual step, and for an ES step the survivors of the default skip
    /// chain (two layers at ratio 0.5, `modelcfg.SKIP_CONFIGS["default"]`
    /// — the same two-stage rounding the compile pipeline applies). This
    /// is what keeps the sim's D2H ledger byte-exact with the PJRT
    /// planner, which accounts the real `exe.final_keep`.
    pub fn n_sel(plan: StepPlan, block: usize) -> usize {
        match plan {
            StepPlan::EsStep => {
                let after_r1 = ((block as f64 * 0.5).round() as usize).max(1);
                ((after_r1 as f64 * 0.5).round() as usize).max(1)
            }
            // prefills never reach run_step; dual keeps the whole block
            _ => block,
        }
    }

    /// Model the given apply mode (Host = the stateless-executable
    /// fallback, for Host-vs-Device comparisons).
    pub fn with_apply(mut self, apply: ApplyMode) -> SimCfg {
        self.apply = apply;
        self
    }

    /// Inject the given deterministic fault schedule.
    pub fn with_faults(mut self, plan: FaultPlan) -> SimCfg {
        self.fault_plan = plan;
        self
    }

    /// Model a compiled context-tier family (the default tier ladder the
    /// compile pipeline emits: gen-region sublengths plus the full
    /// context). Enables live-context pricing when the scheduler opts
    /// in via [`super::GroupScheduler::enable_live_ctx`].
    pub fn with_ctx_tiers(mut self, tiers: &[usize]) -> SimCfg {
        self.ctx_tiers = tiers.to_vec();
        self
    }

    /// The default tier ladder matching the compile pipeline's
    /// `CTX_TIER_GEN` sublengths for this geometry: one tier per
    /// gen-region multiple of 8 plus the full context.
    pub fn default_ctx_tiers(dims: &Dims) -> Vec<usize> {
        let mut tiers: Vec<usize> = (8..dims.gen_len)
            .step_by(8)
            .map(|g| dims.prompt_len + g)
            .collect();
        tiers.push(dims.ctx);
        tiers
    }
}

pub struct SimBackend {
    cfg: SimCfg,
    tok: Tokenizer,
    /// shared residency pool. The sim parks its plans under the shared
    /// owner `None`: there are no real device buffers, so a chain parked
    /// by one worker is genuinely resumable by any other — the
    /// true-sharing model the PJRT backend cannot offer behind the
    /// non-`Send` constraint.
    pool: Arc<ResidencyPool>,
    /// shared cross-request prefix cache (`None` = prefix reuse off).
    /// The sim probes and inserts under the shared owner `None`: its
    /// payloads are plain host memory, so — like its pooled chains — a
    /// prefix cached by one worker is genuinely reusable by any other,
    /// which makes the sim the reference model for cross-worker prefix
    /// sharing.
    prefix: Option<Arc<PrefixCache>>,
    /// resident-cache planner per batch class, created lazily when a
    /// class first activates (the ledger is cumulative, so entries live
    /// for the backend's lifetime)
    residents: BTreeMap<usize, DeviceGroupCaches>,
    /// classes whose chain is currently parked in the pool
    parked: BTreeSet<usize>,
    /// classes whose chain is live (activated and not parked/evicted)
    registered: BTreeSet<usize>,
    /// classes whose activation contributed to the pool's live-chain
    /// count (register_fresh only, for the shared owner: clone-checkouts
    /// leave the counted entry in the parked registry)
    counted: BTreeSet<usize>,
    /// deterministic fault injector built from [`SimCfg::fault_plan`]
    /// (empty plan = never faults); also the home of this backend's
    /// [`crate::fault::FaultStats`] ledger
    injector: Arc<FaultInjector>,
    /// recovery-ladder override of the configured apply mode: `Some`
    /// when the router has quarantined the device-apply path to Host
    /// (or re-probed it back). Changing it retires every resident layer
    /// so chains rebuild in the new mode.
    apply_override: Option<ApplyMode>,
    /// cumulative transfer ledger of resident layers retired by an
    /// apply-mode change, so `transfer_stats` stays monotone across a
    /// Host quarantine
    retired_stats: TransferStats,
    /// live-context rows the scheduler last selected via `set_live_ctx`
    /// (the tier every Device dispatch prices at); `dims.ctx` until the
    /// scheduler opts in, which keeps the untiered ledger bit-identical
    live_ctx_target: usize,
}

/// Pool key namespace for the simulated architecture.
const SIM_ARCH: &str = "sim";

impl SimBackend {
    /// Backend with a private residency pool (single-worker tests and
    /// benches — behavior identical to the pre-pool sim).
    pub fn new(cfg: SimCfg) -> SimBackend {
        Self::with_pool(cfg, ResidencyPool::new())
    }

    /// Backend sharing `pool` with other workers (the router wires every
    /// worker to one pool).
    pub fn with_pool(cfg: SimCfg, pool: Arc<ResidencyPool>) -> SimBackend {
        let injector = FaultInjector::new(cfg.fault_plan.clone());
        let live_ctx_target = cfg.dims.ctx;
        SimBackend {
            cfg,
            tok: Tokenizer::builtin(),
            pool,
            prefix: None,
            residents: BTreeMap::new(),
            parked: BTreeSet::new(),
            registered: BTreeSet::new(),
            counted: BTreeSet::new(),
            injector,
            apply_override: None,
            retired_stats: TransferStats::default(),
            live_ctx_target,
        }
    }

    /// Wire the shared cross-request prefix cache (the router does this
    /// for every worker before serving). Prefix reuse is off until set.
    pub fn set_prefix_cache(&mut self, cache: Arc<PrefixCache>) {
        self.prefix = Some(cache);
    }

    /// The apply mode new resident layers are built in: the recovery
    /// ladder's override when set, the configured mode otherwise.
    fn effective_apply(&self) -> ApplyMode {
        self.apply_override.unwrap_or(self.cfg.apply)
    }

    /// Invalidate the active resident layer and return `f` as the tick
    /// error — the shared exit of every injection site, so a faulted run
    /// leaves the chain in the same state a real failed dispatch would
    /// (untrusted, pending a re-ground).
    fn faulted(
        &mut self,
        caches: &mut GroupCaches,
        f: crate::fault::FaultError,
        what: &str,
    ) -> anyhow::Error {
        self.invalidate_resident(caches);
        anyhow::Error::from(f).context(format!("sim {what}"))
    }

    /// Activate the resident layer for `caches`' batch class — the same
    /// state machine as the PJRT backend's activation (resume parked /
    /// check out shared / build fresh), against the shared owner `None`.
    ///
    /// Chain seed/checkout is an allocation event: on an injected
    /// allocation fault the first ladder rung evicts the pool's LRU
    /// parked entry to model freeing device memory — the fault only
    /// surfaces as an error when the pool has nothing left to evict.
    fn activate(&mut self, caches: &mut GroupCaches) -> Result<()> {
        let batch = caches.batch;
        if self.registered.contains(&batch) && !self.parked.contains(&batch) {
            return Ok(());
        }
        // this call will seed or check out a chain: one allocation event
        if let Err(f) = self.injector.check(FaultKind::Alloc) {
            if self.pool.evict_lru(1).is_empty() {
                return Err(anyhow::Error::from(f)
                    .context(format!("sim chain seed/checkout for class {batch}")));
            }
            // absorbed: an LRU parked chain was evicted to make room
        }
        let seed = chain_seed_bytes(&self.cfg.dims, batch);
        if self.parked.remove(&batch) {
            match self.pool.checkout(SIM_ARCH, batch, None, seed) {
                Some(plan) => {
                    self.residents
                        .get_mut(&batch)
                        .expect("parked implies a resident entry")
                        .restore_plan(plan);
                }
                None => {
                    // the shared entry was evicted while this worker had
                    // the class parked: the device chain is gone, so
                    // re-seed from scratch
                    if let Some(r) = self.residents.get_mut(&batch) {
                        r.invalidate(caches);
                    }
                    self.pool.register_fresh();
                    self.counted.insert(batch);
                }
            }
            self.registered.insert(batch);
            return Ok(());
        }
        if self.residents.contains_key(&batch) {
            // evicted earlier and now reactivated: a fresh chain
            self.pool.register_fresh();
            self.counted.insert(batch);
        } else {
            let apply = self.effective_apply();
            let r = match self.pool.checkout(SIM_ARCH, batch, None, seed) {
                // another worker parked this class: the shared device
                // still holds the chain (the clone leaves the counted
                // entry in the parked registry), so this worker starts
                // seeded without adding to the live count
                Some(plan) => {
                    DeviceGroupCaches::with_plan(&self.cfg.dims, batch, apply, plan)
                }
                None => {
                    self.pool.register_fresh();
                    self.counted.insert(batch);
                    DeviceGroupCaches::new(&self.cfg.dims, batch, apply)
                }
            };
            self.residents.insert(batch, r);
        }
        self.registered.insert(batch);
        Ok(())
    }

    /// Intended token for gen position `j` of the row whose prompt is
    /// `prompt_ids`: echo the prompt, then EOS-fill.
    fn target(&self, prompt_ids: &[i32], j: usize) -> i32 {
        let plen = prompt_ids
            .iter()
            .position(|&t| t == self.tok.pad)
            .unwrap_or(prompt_ids.len());
        if j < plen {
            prompt_ids[j]
        } else {
            self.tok.eos
        }
    }

    /// Write peaked logits for the given gen positions of one slot; the
    /// peak magnitude decreases with position so confidence is strictly
    /// ordered left to right.
    fn write_positions(
        &self,
        tokens: &[i32],
        slot: usize,
        lo: usize,
        hi: usize,
        caches: &mut GroupCaches,
    ) {
        let d = &self.cfg.dims;
        let prompt = &tokens[slot * d.ctx..slot * d.ctx + d.prompt_len];
        for j in lo..hi {
            let t = self.target(prompt, j) as usize;
            let row = (slot * d.gen_len + j) * d.vocab;
            caches.logits[row..row + d.vocab].fill(0.0);
            caches.logits[row + t] = 8.0 - 0.05 * j as f32;
        }
        caches.recompute_conf_slots(&[slot]);
    }
}

impl Drop for SimBackend {
    fn drop(&mut self) {
        // return this worker's live-chain count on exit/unwind (the
        // shared PARKED entries stay: other workers still use the
        // modeled device chains) so a dead worker never inflates the
        // `resident_chains` gauge
        self.pool.release(self.counted.len() as u64);
    }
}

impl StepBackend for SimBackend {
    fn dims(&self) -> &Dims {
        &self.cfg.dims
    }

    fn tokenizer(&self) -> &Tokenizer {
        &self.tok
    }

    fn run_prefill(
        &mut self,
        tokens: &[i32],
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()> {
        if !self.cfg.prefill_cost.is_zero() {
            std::thread::sleep(self.cfg.prefill_cost);
        }
        self.activate(caches)?;
        {
            let r = self.residents.get_mut(&caches.batch).expect("activated");
            if r.apply_mode() == ApplyMode::Device {
                // the same composite sync the PJRT device-apply backend
                // runs: tokens + refresh mask ship, kv/ind/conf seed
                // once then chain as retained outputs
                r.set_live_ctx(self.live_ctx_target);
                r.sync_prefill_device(caches, "h", tokens, slots)?;
            } else {
                r.stage_prefill_tokens(tokens, slots);
            }
        }
        // the modeled executable run + its downlink, each one fault event
        if let Err(f) = self.injector.check(FaultKind::Exec) {
            return Err(self.faulted(caches, f, "prefill run"));
        }
        if let Err(f) = self.injector.check(FaultKind::Transfer) {
            return Err(self.faulted(caches, f, "prefill downlink"));
        }
        let gen = self.cfg.dims.gen_len;
        for &s in slots {
            self.write_positions(tokens, s, 0, gen, caches);
        }
        {
            let r = self.residents.get_mut(&caches.batch).expect("activated");
            if r.apply_mode() == ApplyMode::Device {
                // prefill outputs (KV + indicators + in-graph conf)
                // refresh the resident rows of the requested slots in
                // place — in particular this absorbs a slot-admission
                // reset without any re-upload
                r.note_prefill_applied(caches, slots);
            } else {
                // Host fallback: the downloaded prefill outputs refresh
                // the host mirrors, diverging them from the device copy
                for &b in slots {
                    caches.dirty.kv.mark_slot(b);
                    for bm in caches.dirty.ind.values_mut() {
                        bm.mark_slot(b);
                    }
                }
            }
        }
        Ok(())
    }

    fn ctx_tiers(&self) -> Vec<usize> {
        if self.cfg.ctx_tiers.is_empty() {
            vec![self.cfg.dims.ctx]
        } else {
            self.cfg.ctx_tiers.clone()
        }
    }

    fn set_live_ctx(&mut self, rows: usize) {
        self.live_ctx_target = rows;
    }

    fn note_early_retire(&mut self, caches: &mut GroupCaches, blocks: u64) {
        if let Some(r) = self.residents.get_mut(&caches.batch) {
            r.note_early_retired(blocks);
        }
    }

    fn run_prefill_blk(
        &mut self,
        tokens: &[i32],
        slots: &[usize],
        block_starts: &[usize],
        block: usize,
        caches: &mut GroupCaches,
    ) -> Result<()> {
        if self.effective_apply() != ApplyMode::Device {
            // the stateless fallback has no blk variants — same
            // delegation (and fault cadence) as the PJRT backend
            return self.run_prefill(tokens, slots, caches);
        }
        if !self.cfg.prefill_cost.is_zero() {
            std::thread::sleep(self.cfg.prefill_cost);
        }
        self.activate(caches)?;
        {
            let r = self.residents.get_mut(&caches.batch).expect("activated");
            // the blk planner sync: same uplink as a grounding prefill
            // plus the [B] blk_start vector, but the downlink priced at
            // `[B, block, V]` — the only rows the unmask decision reads
            r.set_live_ctx(self.live_ctx_target);
            r.sync_prefill_device_blk(caches, "h", tokens, slots, block)?;
        }
        // the modeled executable run + its downlink, each one fault event
        // (identical cadence to the full-gen prefill, so fault ordinals
        // land on the same dispatch either way)
        if let Err(f) = self.injector.check(FaultKind::Exec) {
            return Err(self.faulted(caches, f, "prefill_blk run"));
        }
        if let Err(f) = self.injector.check(FaultKind::Transfer) {
            return Err(self.faulted(caches, f, "prefill_blk downlink"));
        }
        // host-mirror refresh covers each slot's CURRENT block window
        // only — the slice the executable downloads. The sampler never
        // reads outside the window, so the trajectory is identical to a
        // full-gen refresh (the sim's peaks are position-targeted).
        for &s in slots {
            let g0 = block_starts[s];
            self.write_positions(tokens, s, g0, g0 + block, caches);
        }
        {
            let r = self.residents.get_mut(&caches.batch).expect("activated");
            r.note_prefill_applied(caches, slots);
        }
        Ok(())
    }

    fn run_step(
        &mut self,
        plan: StepPlan,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<()> {
        let cost = match plan {
            StepPlan::Prefill => self.cfg.prefill_cost,
            StepPlan::DualStep => self.cfg.dual_cost,
            StepPlan::EsStep => self.cfg.es_cost,
        };
        if !cost.is_zero() {
            std::thread::sleep(cost);
        }
        self.activate(caches)?;
        let n_layers = self.cfg.dims.n_layers;
        {
            let r = self.residents.get_mut(&caches.batch).expect("activated");
            if r.apply_mode() == ApplyMode::Device {
                // the PJRT device-apply step sync: tokens + occupancy
                // mask ship; kv/ind/conf chain retained outputs (donated
                // in place) and confidence is computed in-graph. The
                // indicator model is dual-style (every layer maintained);
                // the downlink model is per-plan `final_keep`
                // ([`SimCfg::n_sel`]) so the D2H ledger matches what the
                // real dual/ES apply executables download.
                r.set_live_ctx(self.live_ctx_target);
                let n_sel = SimCfg::n_sel(plan, block);
                r.sync_step_device(
                    caches, "h", n_layers, n_sel, tokens, block_start, block, slots,
                )?;
            } else {
                // Host fallback: dirty-delta uploads per input kind
                r.stage_step_tokens(tokens, block_start, block, slots);
                r.sync_kv(caches, slots);
                let all_layers: Vec<usize> = (0..n_layers).collect();
                r.sync_ind(caches, "h", &all_layers, slots)?;
                r.sync_conf_masked(caches, slots);
            }
        }
        // the modeled executable run + its downlink, each one fault event
        if let Err(f) = self.injector.check(FaultKind::Exec) {
            return Err(self.faulted(caches, f, "step run"));
        }
        if let Err(f) = self.injector.check(FaultKind::Transfer) {
            return Err(self.faulted(caches, f, "step downlink"));
        }
        let d = &self.cfg.dims;
        let lo = block_start - d.prompt_len;
        // the sim refreshes from the window start to the end of the gen
        // region; writing past the current block is harmless — the
        // sampler only reads the current block, and later blocks are
        // re-written by their own steps
        for &s in slots {
            self.write_positions(tokens, s, lo, d.gen_len, caches);
        }
        {
            let r = self.residents.get_mut(&caches.batch).expect("activated");
            if r.apply_mode() == ApplyMode::Device {
                r.note_step_applied(caches, "h", false, block_start, block, slots);
            } else {
                // the downloaded block outputs were scattered host-side:
                // those rows diverge and re-ship as deltas next sync
                let g0 = block_start - d.prompt_len;
                for &b in slots {
                    caches.dirty.kv.mark_range(b, block_start, block_start + block);
                    if let Some(bm) = caches.dirty.ind.get_mut("h") {
                        bm.mark_range(b, g0, g0 + block);
                    }
                }
            }
        }
        Ok(())
    }

    fn run_step_fused(
        &mut self,
        tokens: &[i32],
        block_start: usize,
        block: usize,
        k: usize,
        slots: &[usize],
        caches: &mut GroupCaches,
    ) -> Result<(usize, FusedCommits)> {
        if self.effective_apply() != ApplyMode::Device {
            // the stateless fallback has no fused variants
            return Ok((0, FusedCommits::new()));
        }
        // the in-graph loop still computes k iterations of model work
        if !self.cfg.es_cost.is_zero() {
            std::thread::sleep(self.cfg.es_cost * k as u32);
        }
        self.activate(caches)?;
        let d = self.cfg.dims;
        {
            let r = self.residents.get_mut(&caches.batch).expect("activated");
            // one fused planner sync models k inner iterations per
            // dispatch — the same [`DeviceGroupCaches::sync_step_device_k`]
            // call the PJRT fused path makes, so the two ledgers stay
            // byte-exact on the fused path too
            r.set_live_ctx(self.live_ctx_target);
            let n_sel = SimCfg::n_sel(StepPlan::EsStep, block);
            r.sync_step_device_k(
                caches, "h", d.n_layers, n_sel, k, tokens, block_start, block, slots,
            )?;
        }
        // the modeled fused run + its commit-transcript downlink, plus
        // one divergence event per dispatch: an injected divergence
        // models the committed-count audit failing — the chain is
        // poisoned at this fused depth
        if let Err(f) = self.injector.check(FaultKind::Exec) {
            return Err(self.faulted(caches, f, "fused run"));
        }
        if let Err(f) = self.injector.check(FaultKind::Transfer) {
            return Err(self.faulted(caches, f, "fused downlink"));
        }
        if let Err(f) = self.injector.check(FaultKind::FusedDivergence) {
            return Err(self.faulted(caches, f, "fused committed-count audit"));
        }
        let lo = block_start - d.prompt_len;
        // the final iteration's downlink refresh (the sim's peaks are
        // position-targeted and iteration-independent)
        for &s in slots {
            self.write_positions(tokens, s, lo, d.gen_len, caches);
        }
        // model the in-graph per-iteration commits: the device replays
        // the HOST sampler rule between inner iterations, so run that
        // exact sampler k times over a scratch copy of each slot's gen
        // row — iteration-independent peaks make the downloaded mirror
        // valid for every inner iteration
        let sampler = SamplerCfg::llada();
        let mut rng = SplitMix::new(0); // greedy: never consulted
        let mut commits = FusedCommits::with_capacity(slots.len());
        for &s in slots {
            let mut gen: Vec<i32> =
                tokens[s * d.ctx + d.prompt_len..(s + 1) * d.ctx].to_vec();
            let mut row = Vec::with_capacity(k);
            for i in 0..k {
                let dec = decide_unmask(
                    &sampler,
                    &UnmaskInput {
                        logits: &caches.logits
                            [s * d.gen_len * d.vocab..(s + 1) * d.gen_len * d.vocab],
                        conf: &caches.conf[s * d.gen_len..(s + 1) * d.gen_len],
                        gen_tokens: &gen,
                        block_lo: lo,
                        block_hi: lo + block,
                        vocab: d.vocab,
                        mask_id: self.tok.mask,
                        eos_id: self.tok.eos,
                    },
                    &mut rng,
                );
                let (Some(&p), Some(&t)) = (dec.positions.first(), dec.tokens.first())
                else {
                    // no masked position left mid-run: the scheduler's
                    // remaining-masked depth cap was violated upstream —
                    // the modeled chain is now unaccountable, fail loud
                    let r = self.residents.get_mut(&caches.batch).expect("activated");
                    r.invalidate(caches);
                    return Err(anyhow!(
                        "fused sim run: slot {s} had nothing to commit at \
                         inner iteration {i} of {k}"
                    ));
                };
                gen[p] = t;
                row.push((p, t));
            }
            commits.push(row);
        }
        {
            let r = self.residents.get_mut(&caches.batch).expect("activated");
            r.note_step_applied(caches, "h", false, block_start, block, slots);
        }
        Ok((k, commits))
    }

    fn transfer_stats(&self) -> TransferStats {
        let mut total = self.retired_stats;
        for r in self.residents.values() {
            total.merge(&r.stats);
        }
        total
    }

    fn fault_injector(&self) -> Option<Arc<FaultInjector>> {
        Some(self.injector.clone())
    }

    fn set_apply_override(&mut self, mode: Option<ApplyMode>) {
        if self.apply_override == mode {
            return;
        }
        self.apply_override = mode;
        // resident layers are built for one apply mode, so a quarantine
        // (or a re-probe back) retires them all: banked ledgers keep
        // `transfer_stats` monotone, pooled entries are evicted so no
        // worker resumes a chain in the wrong mode, and the next
        // activation rebuilds fresh — the caller re-grounds afterwards
        for (&batch, r) in self.residents.iter() {
            self.retired_stats.merge(&r.stats);
            let was_active = self.counted.contains(&batch);
            self.pool.evict(SIM_ARCH, batch, None, was_active);
        }
        self.residents.clear();
        self.registered.clear();
        self.parked.clear();
        self.counted.clear();
    }

    fn invalidate_resident(&mut self, caches: &mut GroupCaches) {
        let batch = caches.batch;
        if let Some(r) = self.residents.get_mut(&batch) {
            r.invalidate(caches);
            // drop the pooled entry too: eviction must be visible to
            // every worker sharing the device, not just this one
            self.registered.remove(&batch);
            self.parked.remove(&batch);
            let was_active = self.counted.remove(&batch);
            self.pool.evict(SIM_ARCH, batch, None, was_active);
        }
    }

    fn park_chain(&mut self, caches: &mut GroupCaches) {
        let batch = caches.batch;
        if let Some(r) = self.residents.get(&batch) {
            if self.registered.remove(&batch) && self.parked.insert(batch) {
                let was_active = self.counted.remove(&batch);
                self.pool.park(SIM_ARCH, batch, None, r.park_plan(), was_active);
            }
        }
    }

    fn checkout_chain(&mut self, caches: &mut GroupCaches) -> Result<()> {
        self.activate(caches)
    }

    fn note_chain_switch(&self) {
        self.pool.record_switch();
    }

    fn note_preempt(&self, ev: crate::runtime::resident::PreemptEvent) {
        self.pool.note_victim(ev);
    }

    fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    fn prefix_probe(
        &mut self,
        content: &[i32],
        block: usize,
        caches: &GroupCaches,
    ) -> Option<(usize, Vec<u16>)> {
        let cache = self.prefix.as_ref()?;
        cache.probe(SIM_ARCH, None, content, block, caches.kv_row_bytes() as u64)
    }

    fn prefix_offer(
        &mut self,
        content: &[i32],
        block: usize,
        caches: &GroupCaches,
        slot: usize,
    ) {
        let Some(cache) = self.prefix.as_ref() else {
            return;
        };
        if block == 0 {
            return;
        }
        let p = (content.len() / block) * block;
        if p == 0 {
            return;
        }
        let Ok(rows) = caches.extract_prefix_rows(slot, p) else {
            return;
        };
        cache.insert(SIM_ARCH, None, &content[..p], rows);
    }

    fn prefix_stats(&self) -> PrefixStats {
        self.prefix.as_ref().map(|c| c.stats()).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_targets_and_confidence_ordering() {
        let mut b = SimBackend::new(SimCfg::default());
        let d = b.cfg.dims;
        let mut caches = GroupCaches::new(&d, 1);
        let mut tokens = vec![0i32; d.ctx];
        let ids = b.tok.encode_prompt("ab", d.prompt_len).unwrap();
        tokens[..d.prompt_len].copy_from_slice(&ids);
        b.run_prefill(&tokens, &[0], &mut caches).unwrap();
        // targets echo the prompt then EOS
        let argmax = |j: usize| {
            let row = &caches.logits[j * d.vocab..(j + 1) * d.vocab];
            (0..d.vocab).max_by(|&x, &y| row[x].total_cmp(&row[y])).unwrap() as i32
        };
        assert_eq!(argmax(0), ids[0]);
        assert_eq!(argmax(1), ids[1]);
        assert_eq!(argmax(2), b.tok.eos);
        // confidence strictly decreasing → greedy decodes left to right
        for j in 1..d.gen_len {
            assert!(caches.conf[j] < caches.conf[j - 1], "position {j}");
        }
    }

    #[test]
    fn injected_exec_fault_is_transient_and_a_rerun_recovers() {
        let cfg = SimCfg::default()
            .with_faults(FaultPlan::parse("exec@1").unwrap());
        let mut b = SimBackend::new(cfg);
        let d = b.cfg.dims;
        let mut caches = GroupCaches::new(&d, 1);
        let mut tokens = vec![0i32; d.ctx];
        let ids = b.tok.encode_prompt("ab", d.prompt_len).unwrap();
        tokens[..d.prompt_len].copy_from_slice(&ids);
        let err = b.run_prefill(&tokens, &[0], &mut caches).unwrap_err();
        assert_eq!(
            crate::fault::classify(&err),
            crate::fault::TickErrorClass::Transient
        );
        assert_eq!(b.injector.stats().faults_injected, 1);
        // no logits were written by the faulted run
        assert!(caches.logits.iter().all(|&x| x == 0.0));
        // the re-run (exec event 2, clean) seeds a fresh chain and
        // produces the exact state a fault-free run would
        b.run_prefill(&tokens, &[0], &mut caches).unwrap();
        let row = &caches.logits[..d.vocab];
        assert_eq!(
            (0..d.vocab).max_by(|&x, &y| row[x].total_cmp(&row[y])).unwrap() as i32,
            ids[0]
        );
    }

    #[test]
    fn apply_override_quarantines_to_host_and_reprobes_back() {
        let mut b = SimBackend::new(SimCfg::default());
        let d = b.cfg.dims;
        let mut caches = GroupCaches::new(&d, 1);
        let tokens = vec![0i32; d.ctx];
        b.run_prefill(&tokens, &[0], &mut caches).unwrap();
        let banked = b.transfer_stats();
        b.set_apply_override(Some(ApplyMode::Host));
        // the ledger stays monotone across the retirement
        assert_eq!(b.transfer_stats(), banked);
        b.run_prefill(&tokens, &[0], &mut caches).unwrap();
        assert_eq!(
            b.residents.get(&1).unwrap().apply_mode(),
            ApplyMode::Host
        );
        b.set_apply_override(None);
        b.run_prefill(&tokens, &[0], &mut caches).unwrap();
        assert_eq!(
            b.residents.get(&1).unwrap().apply_mode(),
            ApplyMode::Device
        );
    }
}
