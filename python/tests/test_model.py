"""L2 model correctness: shapes, cache semantics, ES/Dual equivalences."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.modelcfg import LLADA_NANO, DREAM_NANO, SKIP_CONFIGS, final_keep
from compile import model as M


@pytest.fixture(scope="module", params=["llada-nano", "dream-nano"])
def setup(request):
    cfg = LLADA_NANO if request.param == "llada-nano" else DREAM_NANO
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(4, 60, (2, cfg.ctx)), jnp.int32)
    logits, kv, ind, mass = M.prefill(cfg, params, toks, use_pallas=False)
    return cfg, params, toks, logits, kv, ind, mass


def _step(cfg, params, toks, kv, ind_h, conf, *, skip, block=8, alpha=0.5,
          ind_layers=None, indicator="h"):
    x_tok = toks[:, cfg.prompt_len:cfg.prompt_len + block]
    return M.step(cfg, params, x_tok, jnp.int32(cfg.prompt_len), kv, ind_h,
                  conf, jnp.float32(alpha), block=block, skip=skip,
                  ind_layers=ind_layers, indicator=indicator,
                  use_pallas=False)


def test_prefill_logits_gen_is_the_gen_region_slice(setup):
    # the Host-fallback executables (`vanilla_b*` / `prefill_b*`) are
    # lowered with logits_gen=True: the output must be exactly the
    # gen-region rows of the full-context forward, nothing resampled
    cfg, params, toks, logits, kv, ind, mass = setup
    lg, kv2, ind2, mass2 = M.prefill(cfg, params, toks, use_pallas=False,
                                     logits_gen=True)
    assert lg.shape == (toks.shape[0], cfg.gen_len, cfg.vocab)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits[:, cfg.prompt_len:]),
                               rtol=0, atol=0)
    # the cache outputs are untouched by the slice
    np.testing.assert_array_equal(np.asarray(kv2.astype(jnp.float32)),
                                  np.asarray(kv.astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(mass2), np.asarray(mass))


def test_prefill_shapes(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    assert logits.shape == (B, cfg.ctx, cfg.vocab)
    assert kv.shape == (cfg.n_layers, 2, B, cfg.n_kv_heads, cfg.ctx,
                        cfg.head_dim)
    assert kv.dtype == jnp.bfloat16
    for t in "hqkv":
        assert ind[t].shape == (cfg.n_layers, B, cfg.gen_len, cfg.d_model)
    assert mass.shape == (B, cfg.ctx)
    # attention mass over positions sums to ~1 per sequence
    np.testing.assert_allclose(np.asarray(mass.sum(-1)), 1.0, rtol=1e-4)


def test_step_shapes_and_dtypes(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.zeros((B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    sl = [1, 2]
    out = _step(cfg, params, toks, kv, ind["h"][jnp.asarray(sl)], conf, skip=skip)
    k_f = final_keep(8, skip)
    assert out[0].shape == (B, k_f, cfg.vocab)
    assert out[1].shape == (B, k_f)
    assert out[2].shape == (cfg.n_layers, 2, B, cfg.n_kv_heads, 8,
                            cfg.head_dim)
    assert out[3].shape == (len(sl), B, 8, cfg.d_model)
    assert out[2].dtype == jnp.bfloat16


def test_es_zero_ratio_equals_dual_mod_permutation(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.asarray(np.random.RandomState(1).rand(B, cfg.gen_len),
                       jnp.float32)
    all_layers = list(range(cfg.n_layers))
    dual = _step(cfg, params, toks, kv, ind["h"], conf, skip=[],
                 ind_layers=all_layers)
    es0 = _step(cfg, params, toks, kv, ind["h"], conf,
                skip=[(1, 0.0), (2, 0.0)], ind_layers=all_layers)
    order = jnp.argsort(es0[1], axis=1)
    el = jnp.take_along_axis(es0[0], order[..., None], axis=1)
    ep = jnp.take_along_axis(es0[1], order, axis=1)
    assert bool(jnp.all(ep == dual[1]))
    np.testing.assert_allclose(np.asarray(el), np.asarray(dual[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(es0[2].astype(jnp.float32)),
        np.asarray(dual[2].astype(jnp.float32)))


def test_dual_step_matches_prefill_logits(setup):
    """After prefill the caches are exact, so a dual step over the first
    block must reproduce the prefill logits up to bf16 cache rounding."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.zeros((B, cfg.gen_len), jnp.float32)
    dual = _step(cfg, params, toks, kv, ind["h"], conf, skip=[],
                 ind_layers=list(range(cfg.n_layers)))
    want = logits[:, cfg.prompt_len:cfg.prompt_len + 8]
    err = float(jnp.max(jnp.abs(dual[0] - want)))
    assert err < 0.15, err  # bf16 cache round-trip tolerance


def test_alpha_extremes_change_selection(setup):
    """α=1 ranks purely by confidence, α=0 purely by variation — with
    adversarial inputs the surviving sets must differ."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    rng = np.random.RandomState(3)
    conf = jnp.asarray(rng.rand(B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    sl = [1, 2]
    # perturb the indicator cache so variation is adversarial to confidence
    ind_h = ind["h"][jnp.asarray(sl)] + jnp.asarray(
        rng.standard_normal(ind["h"][jnp.asarray(sl)].shape) * 0.5, jnp.bfloat16)
    a1 = _step(cfg, params, toks, kv, ind_h, conf, skip=skip, alpha=1.0)
    a0 = _step(cfg, params, toks, kv, ind_h, conf, skip=skip, alpha=0.0)
    assert not bool(jnp.all(a1[1] == a0[1]))


def test_skip_positions_are_subset_of_block(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.zeros((B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    out = _step(cfg, params, toks, kv, ind["h"][jnp.asarray([1, 2])], conf, skip=skip)
    pos = np.asarray(out[1])
    assert ((pos >= cfg.prompt_len) & (pos < cfg.prompt_len + 8)).all()
    # positions unique per row
    for b in range(B):
        assert len(set(pos[b].tolist())) == pos.shape[1]


def test_sparse_kv_layout_step(setup):
    """Step against a pruned cache (retained prompt rows + gen region)
    equals the dense step when the pruned rows carry the same data and
    attention ignores... (smoke: shapes + runs)."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    keep = 24
    kv_np = np.asarray(kv.astype(jnp.float32))
    pruned = np.concatenate(
        [kv_np[:, :, :, :, :keep], kv_np[:, :, :, :, cfg.prompt_len:]], axis=4)
    conf = jnp.zeros((B, cfg.gen_len), jnp.float32)
    x_tok = toks[:, cfg.prompt_len:cfg.prompt_len + 8]
    out = M.step(cfg, params, x_tok, jnp.int32(cfg.prompt_len),
                 jnp.asarray(pruned, jnp.bfloat16), ind["h"][jnp.asarray([1, 2])], conf,
                 jnp.float32(0.5), block=8, skip=[(1, 0.5), (2, 0.5)],
                 kv_len=keep + cfg.gen_len, use_pallas=False)
    assert out[2].shape[4] == 8


def test_observe_probe_shapes(setup):
    cfg, params, toks, *_ = setup
    B = toks.shape[0]
    logits, probes = M.observe(cfg, params, toks, probe_layers=[2, 5, 7],
                               use_pallas=False)
    assert probes.shape == (3, 4, B, cfg.gen_len, cfg.d_model)
    assert logits.shape == (B, cfg.ctx, cfg.vocab)


def test_pallas_and_ref_paths_agree_on_step():
    cfg = LLADA_NANO
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(5)
    toks = jnp.asarray(rng.randint(4, 60, (1, cfg.ctx)), jnp.int32)
    _, kv, ind, _ = M.prefill(cfg, params, toks, use_pallas=False)
    conf = jnp.asarray(rng.rand(1, cfg.gen_len), jnp.float32)
    args = (cfg, params, toks[:, cfg.prompt_len:cfg.prompt_len + 8],
            jnp.int32(cfg.prompt_len), kv, ind["h"][jnp.asarray([1, 2])], conf,
            jnp.float32(0.5))
    kw = dict(block=8, skip=[(1, 0.5), (2, 0.5)])
    a = M.step(*args, **kw, use_pallas=True)
    b = M.step(*args, **kw, use_pallas=False)
    assert bool(jnp.all(a[1] == b[1]))
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               rtol=2e-4, atol=2e-4)


def test_step_apply_matches_block_step(setup):
    """Device-apply step with all rows occupied must produce the same
    logits/pos as the block-output step, and its in-graph cache updates
    must equal the host-side scatter of the block outputs."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    rng = np.random.RandomState(7)
    conf = jnp.asarray(rng.rand(B, cfg.gen_len), jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    sl = [1, 2]
    blk = _step(cfg, params, toks, kv, ind["h"][jnp.asarray(sl)], conf,
                skip=skip)
    x_tok = toks[:, cfg.prompt_len:cfg.prompt_len + 8]
    occ = jnp.ones((B,), jnp.int32)
    app = M.step(cfg, params, x_tok, jnp.int32(cfg.prompt_len), kv,
                 ind["h"], conf, jnp.float32(0.5), block=8, skip=skip,
                 ind_layers=sl, use_pallas=False, apply=True, occ=occ)
    # identical selection and logits
    assert bool(jnp.all(app[1] == blk[1]))
    np.testing.assert_allclose(np.asarray(app[0]), np.asarray(blk[0]),
                               rtol=1e-5, atol=1e-5)
    # the in-graph KV scatter equals the host scatter of the block slice
    kv_host = np.asarray(kv.astype(jnp.float32)).copy()
    kv_host[:, :, :, :, cfg.prompt_len:cfg.prompt_len + 8] = np.asarray(
        blk[2].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(app[2].astype(jnp.float32)),
                               kv_host)
    # full shapes: kv/ind/conf are the resident tensors, not slices
    assert app[2].shape == kv.shape
    assert app[3].shape == ind["h"].shape
    assert app[4].shape == (B, cfg.gen_len)
    # the maintained indicator layers carry the block update; others
    # pass through
    ih = np.asarray(ind["h"].astype(jnp.float32))
    ia = np.asarray(app[3].astype(jnp.float32))
    np.testing.assert_allclose(ia[0], ih[0])  # layer 0 not maintained
    assert not np.allclose(ia[1, :, :8], ih[1, :, :8])
    # in-graph confidence: computed positions hold the max softmax prob
    probs = np.asarray(jax.nn.softmax(app[0], axis=-1).max(-1))
    pos = np.asarray(app[1]) - cfg.prompt_len
    conf_np = np.asarray(app[4])
    for bi in range(B):
        for j, p in enumerate(pos[bi]):
            np.testing.assert_allclose(conf_np[bi, p], probs[bi, j],
                                       rtol=1e-5)


def test_step_apply_passes_vacant_rows_through(setup):
    """Rows with occ = 0 keep their cache and confidence unchanged."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.asarray(np.random.RandomState(8).rand(B, cfg.gen_len),
                       jnp.float32)
    x_tok = toks[:, cfg.prompt_len:cfg.prompt_len + 8]
    occ = jnp.asarray([1] + [0] * (B - 1), jnp.int32)
    app = M.step(cfg, params, x_tok, jnp.int32(cfg.prompt_len), kv,
                 ind["h"], conf, jnp.float32(0.5), block=8,
                 skip=[(1, 0.5), (2, 0.5)], ind_layers=[1, 2],
                 use_pallas=False, apply=True, occ=occ)
    kv0 = np.asarray(kv.astype(jnp.float32))
    kva = np.asarray(app[2].astype(jnp.float32))
    # spectator rows (batch dim 2 of kv layout) untouched, stepped row not
    np.testing.assert_allclose(kva[:, :, 1:], kv0[:, :, 1:])
    assert not np.allclose(kva[:, :, :1, :, cfg.prompt_len:cfg.prompt_len + 8],
                           kv0[:, :, :1, :, cfg.prompt_len:cfg.prompt_len + 8])
    np.testing.assert_allclose(np.asarray(app[4])[1:],
                               np.asarray(conf)[1:])
    ia = np.asarray(app[3].astype(jnp.float32))
    ih = np.asarray(ind["h"].astype(jnp.float32))
    np.testing.assert_allclose(ia[:, 1:], ih[:, 1:])


def test_step_k_chains_commits_between_inner_iterations(setup):
    """A fused k=2 run must equal: one apply-step, a greedy commit of the
    highest-confidence masked row (numpy replay of the in-graph rule),
    then a second apply-step on the advanced tokens — and must report
    exactly one committed token per inner iteration per occupied row
    when the threshold disables parallel commits."""
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    conf = jnp.asarray(np.random.RandomState(11).rand(B, cfg.gen_len),
                       jnp.float32)
    skip = [(1, 0.5), (2, 0.5)]
    sl = [1, 2]
    MASK = 1
    x0 = jnp.full((B, 8), MASK, jnp.int32)
    occ = jnp.asarray([1] + [0] * (B - 1), jnp.int32)
    fused = M.step_k(cfg, params, x0, jnp.int32(cfg.prompt_len), kv,
                     ind["h"], conf, occ, jnp.float32(0.5),
                     jnp.float32(2.0), k=2, block=8, skip=skip,
                     mask_id=MASK, ind_layers=sl, use_pallas=False)
    # threshold 2.0 > any softmax prob → greedy only: one commit per
    # inner iteration for the occupied row, none for the vacant row
    np.testing.assert_array_equal(np.asarray(fused[5]),
                                  [2] + [0] * (B - 1))
    # manual replay of iteration 1 + the commit rule in numpy
    s1 = M.step(cfg, params, x0, jnp.int32(cfg.prompt_len), kv, ind["h"],
                conf, jnp.float32(0.5), block=8, skip=skip, ind_layers=sl,
                use_pallas=False, apply=True, occ=occ)
    lg, pos = np.asarray(s1[0]), np.asarray(s1[1])
    prob = np.asarray(jax.nn.softmax(s1[0], axis=-1).max(-1))
    lg_banned = lg.copy()
    lg_banned[:, :, MASK] = -np.inf
    tok_hat = lg_banned.argmax(-1)
    x1 = np.asarray(x0).copy()
    j = int(prob[0].argmax())            # all block rows start masked
    x1[0, pos[0, j] - cfg.prompt_len] = tok_hat[0, j]
    s2 = M.step(cfg, params, jnp.asarray(x1), jnp.int32(cfg.prompt_len),
                s1[2], s1[3], s1[4], jnp.float32(0.5), block=8, skip=skip,
                ind_layers=sl, use_pallas=False, apply=True, occ=occ)
    # the fused downlink is the final iteration's logits/pos, and the
    # chained caches equal the replayed second step's
    np.testing.assert_array_equal(np.asarray(fused[1]), np.asarray(s2[1]))
    np.testing.assert_allclose(np.asarray(fused[0]), np.asarray(s2[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(fused[2].astype(jnp.float32)),
        np.asarray(s2[2].astype(jnp.float32)))
    np.testing.assert_allclose(
        np.asarray(fused[3].astype(jnp.float32)),
        np.asarray(s2[3].astype(jnp.float32)))
    np.testing.assert_allclose(np.asarray(fused[4]), np.asarray(s2[4]),
                               rtol=1e-5)


def test_prefill_apply_refreshes_only_masked_rows(setup):
    cfg, params, toks, logits, kv, ind, mass = setup
    B = toks.shape[0]
    rng = np.random.RandomState(9)
    kv_prev = jnp.asarray(rng.standard_normal(kv.shape), jnp.bfloat16)
    ind_prev = jnp.asarray(rng.standard_normal(ind["h"].shape), jnp.bfloat16)
    conf_prev = jnp.asarray(rng.rand(B, cfg.gen_len), jnp.float32)
    refresh = jnp.asarray([1] + [0] * (B - 1), jnp.int32)
    out = M.prefill_apply(cfg, params, toks, kv_prev, ind_prev, conf_prev,
                          refresh, use_pallas=False)
    lg_gen, kv_new, ind_new, conf_new = out
    # refreshed row matches a fresh prefill; spectator rows pass through
    np.testing.assert_allclose(
        np.asarray(kv_new.astype(jnp.float32))[:, :, 0],
        np.asarray(kv.astype(jnp.float32))[:, :, 0])
    np.testing.assert_allclose(
        np.asarray(kv_new.astype(jnp.float32))[:, :, 1:],
        np.asarray(kv_prev.astype(jnp.float32))[:, :, 1:])
    np.testing.assert_allclose(np.asarray(ind_new.astype(jnp.float32))[:, 1:],
                               np.asarray(ind_prev.astype(jnp.float32))[:, 1:])
    np.testing.assert_allclose(np.asarray(conf_new)[1:],
                               np.asarray(conf_prev)[1:])
    # in-graph confidence of the refreshed row = max softmax of its
    # gen-region logits
    want = np.asarray(jax.nn.softmax(lg_gen, axis=-1).max(-1))
    np.testing.assert_allclose(np.asarray(conf_new)[0], want[0], rtol=1e-5)
    # the logit output is the gen-region slice, not the full context:
    # the prompt rows never cross the bus
    assert lg_gen.shape == (B, cfg.gen_len, cfg.vocab)
    full = M.prefill(cfg, params, toks, use_pallas=False)[0]
    np.testing.assert_allclose(np.asarray(lg_gen),
                               np.asarray(full[:, cfg.prompt_len:]),
                               rtol=1e-5, atol=1e-6)
