//! Minimal in-tree substitute for the `log` facade crate: the `error!`
//! through `trace!` macros, [`Log`] trait, and global logger plumbing
//! used by `crate::logging`. Vendored so the build needs no registry
//! access; the API mirrors upstream so the real crate can be swapped
//! back in without source changes.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let name = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(name)
    }
}

#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level as usize > MAX_LEVEL.load(Ordering::SeqCst) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_upstream() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("{:5}", Level::Warn), "WARN ");
    }
}
