//! Side-by-side comparison of vanilla / DualCache / ES-dLLM (+PD, +Sparse)
//! on one benchmark — a small interactive version of the paper's Table 1.
//!
//! Run: `cargo run --release --example compare_methods -- \
//!        [--bench arith] [--n 16] [--arch llada-nano]`

use esdllm::bench::Table;
use esdllm::cli::Args;
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::flops;
use esdllm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let args = Args::parse().map_err(anyhow::Error::msg)?;
    let arch = args.str("arch", "llada-nano");
    let n = args.usize("n", 16);
    let bench: &'static str = match args.str("bench", "arith").as_str() {
        "chain" => "chain",
        "logic" => "logic",
        "codegen" => "codegen",
        "listops" => "listops",
        _ => "arith",
    };

    let rt = Runtime::load_default()?;
    let dims = rt.arch(&arch)?.dims;

    let mut table = Table::new(
        &format!("compare_methods: {arch} / {bench} / {n} samples"),
        &["Method", "TPS", "Speedup", "Score", "iters (p/d/e)", "run GFLOPs"],
    );

    let cells: Vec<(Method, EvalOpts)> = vec![
        (Method::Vanilla, EvalOpts::default()),
        (Method::DualCache, EvalOpts::default()),
        (Method::EsDllm, EvalOpts::default()),
        (
            Method::EsDllm,
            EvalOpts { parallel_threshold: Some(0.9), ..Default::default() },
        ),
        (Method::EsDllm, EvalOpts { sparse: true, ..Default::default() }),
    ];

    let mut baseline_tps = None;
    for (method, opts) in cells {
        let r = evaluate(&rt, &arch, method, bench, n, &opts)?;
        let base = *baseline_tps.get_or_insert(r.tps);
        let block = esdllm::eval::bench_cfg(bench).block;
        let skip = [(1usize, 0.5f64), (2, 0.5)];
        let gflops = flops::run_flops(
            &dims, block,
            if method == Method::EsDllm { &skip } else { &[] },
            r.n_prefill, r.n_dual, r.n_es,
        ) / 1e9;
        table.row(&[
            r.method.clone(),
            format!("{:.2}", r.tps),
            format!("{:.2}x", r.tps / base),
            format!("{:.1}%", r.score),
            format!("{} ({}/{}/{})", r.iterations, r.n_prefill, r.n_dual, r.n_es),
            format!("{gflops:.2}"),
        ]);
    }
    table.print();
    Ok(())
}
