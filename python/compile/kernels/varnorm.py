"""Layer-1 Pallas kernel: fused variation norm (Eq. 1, second term).

Computes, per token row, the normalized L1 variation of the indicator
tensor between successive iterations:

    var_i = ||H_i - H_i_prev||_1 / (sqrt(d) * ||H_i_prev||_2)

Fusing the subtraction, both norms and the division in one VMEM pass
avoids materializing the [S, d] difference tensor in HBM — on the paper's
GPU this was a bandwidth-bound elementwise chain; on TPU it is one
VPU sweep per row tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _varnorm_kernel(h_ref, p_ref, o_ref, *, d, eps):
    h = h_ref[0]        # [S, d]
    p = p_ref[0]
    l1 = jnp.sum(jnp.abs(h - p), axis=-1)
    l2 = jnp.sqrt(jnp.sum(p * p, axis=-1))
    o_ref[0] = l1 / (jnp.sqrt(jnp.asarray(d, h.dtype)) * l2 + eps)


def varnorm(h, h_prev, *, eps=1e-6, interpret=True):
    """h, h_prev: [B, S, d] -> [B, S]."""
    b, s, d = h.shape
    kernel = functools.partial(_varnorm_kernel, d=d, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s), h.dtype),
        interpret=interpret,
    )(h, h_prev)
