//! Golden-manifest parse contract for the device-apply executable kinds:
//! a checked-in fixture (mirroring what `python/compile/aot.py` emits)
//! pins the `prefill_apply` / `step_apply` / `step_apply_k` kinds (the
//! last with its required `k` unroll-depth field), their
//! `retained_outputs` chaining signatures with the `alias` (donation)
//! flags, and the gen-region `logits_gen` output signature, and the
//! error paths must name the offending executable and field instead of
//! failing generically.

use std::path::{Path, PathBuf};

use esdllm::manifest::{ExeKind, Manifest, RetainedSig};

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/golden_artifacts")
}

#[test]
fn golden_manifest_parses_device_apply_kinds() {
    let m = Manifest::load(&golden_dir()).expect("golden manifest parses");
    let a = m.arch("llada-nano").unwrap();

    let pf = a.exe("prefill_apply_b8").unwrap();
    assert_eq!(pf.kind, ExeKind::PrefillApply);
    assert_eq!(pf.batch, 8);
    // non-parameter inputs only (the one param is stripped)
    assert_eq!(pf.inputs.len(), 5);
    assert_eq!(pf.inputs[0].name, "tokens");
    assert_eq!(pf.inputs[4].name, "refresh");
    assert_eq!(
        pf.retained,
        vec![
            RetainedSig { output: "kv".into(), input: "kv".into(), donate: true },
            RetainedSig { output: "ind".into(), input: "ind".into(), donate: true },
            RetainedSig { output: "conf".into(), input: "conf".into(), donate: true },
        ]
    );
    // retain flags in output order: logits download, the cache chain
    // stays on device
    assert_eq!(pf.retain_flags(), vec![false, true, true, true]);
    assert_eq!(pf.output_index("kv").unwrap(), 1);
    assert_eq!(pf.output_index("conf").unwrap(), 3);
    assert!(pf.output_index("nope").is_err());
    // gen-region logit output: [B, gen, V], not [B, ctx, V] — and the
    // old full-context name is gone, so a stale runtime fails loudly
    let lg = pf.output_index("logits_gen").unwrap();
    assert_eq!(lg, 0);
    assert_eq!(pf.outputs[lg].shape, vec![8, 32, 64]);
    assert!(pf.output_index("logits").is_err());
    // input-output alias (donation) pairs in the executable's true
    // argument order: 1 model param, then tokens/kv/ind/conf/refresh
    assert_eq!(pf.alias_pairs(1), vec![(1, 2), (2, 3), (3, 4)]);

    let st = a.exe("es_apply_blk8_b8").unwrap();
    assert_eq!(st.kind, ExeKind::StepApply);
    assert_eq!(st.block, Some(8));
    assert_eq!(st.skip_layers, vec![1, 2]);
    assert_eq!(st.k, None, "single-step kinds carry no unroll depth");
    assert_eq!(st.retain_flags(), vec![false, false, true, true, true]);
    // args: param, x_tok, block_start, kv, ind, conf, occ, alpha
    assert_eq!(st.alias_pairs(1), vec![(2, 4), (3, 5), (4, 6)]);

    // the fused k-step variant: same chain/donation contract as the
    // single-step exe, plus the unroll depth, a threshold input for the
    // in-graph unmask, and the per-slot committed-count downlink
    let fk = a.exe("es_applyk4_blk8_b8").unwrap();
    assert_eq!(fk.kind, ExeKind::StepApplyK);
    assert_eq!(fk.k, Some(4));
    assert_eq!(fk.block, Some(8));
    assert_eq!(fk.skip_layers, vec![1, 2]);
    assert_eq!(fk.inputs.last().unwrap().name, "threshold");
    assert_eq!(
        fk.retain_flags(),
        vec![false, false, true, true, true, false],
        "logits/pos/committed download, the cache chain stays on device"
    );
    // args: param, x_tok, block_start, kv, ind, conf, occ, alpha, threshold
    assert_eq!(fk.alias_pairs(1), vec![(2, 4), (3, 5), (4, 6)]);
    let cm = fk.output_index("committed").unwrap();
    assert_eq!(fk.outputs[cm].shape, vec![8], "per-slot committed count");

    // plain step executables carry no retained outputs and no aliases
    let dual = a.exe("dual_blk8_b8").unwrap();
    assert_eq!(dual.kind, ExeKind::Step);
    assert!(dual.retained.is_empty());
    assert_eq!(dual.retain_flags(), vec![false; 4]);
    assert!(dual.alias_pairs(1).is_empty());

    // the Host-fallback full forwards are gen-sliced too: `vanilla_b*`
    // (and `prefill_b*`) emit `logits_gen` [B, gen, V], and the old
    // full-context `logits` name is gone so a stale runtime fails
    // loudly at output lookup instead of mis-slicing rows
    let vanilla = a.exe("vanilla_b8").unwrap();
    assert_eq!(vanilla.kind, ExeKind::Prefill);
    let lg = vanilla.output_index("logits_gen").unwrap();
    assert_eq!(lg, 0);
    assert_eq!(vanilla.outputs[lg].shape, vec![8, 32, 64], "[B, gen, V]");
    assert!(vanilla.output_index("logits").is_err());
    assert!(vanilla.retained.is_empty(), "stateless: nothing chained");

    // and the cache-refreshing prefill keeps its output ORDER (logits
    // first, then kv / ind_h..ind_v / attn_mass — what
    // refresh_slots_from_prefill indexes positionally) with the logit
    // output gen-sliced: [B, gen, V], distinguishable from [B, ctx, V]
    // by its second dimension, which is the compat sniff the host merge
    // relies on
    let pf = a.exe("prefill_b8").unwrap();
    assert_eq!(pf.kind, ExeKind::Prefill);
    assert_eq!(pf.output_index("logits_gen").unwrap(), 0);
    assert_eq!(pf.outputs[0].shape, vec![8, 32, 64], "[B, gen, V] not ctx");
    assert_eq!(pf.output_index("kv").unwrap(), 1);
    assert_eq!(pf.output_index("attn_mass").unwrap(), 6);
    assert_eq!(pf.outputs.len(), 7);
    assert!(pf.output_index("logits").is_err());
}

fn load_patched(patch: impl Fn(&str) -> String, subdir: &str) -> anyhow::Error {
    let src = std::fs::read_to_string(golden_dir().join("manifest.json")).unwrap();
    let dir = std::env::temp_dir().join(format!("esdllm-golden-{subdir}"));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), patch(&src)).unwrap();
    Manifest::load(&dir).expect_err("patched manifest must fail to parse")
}

#[test]
fn unknown_kind_error_names_the_executable() {
    let err = load_patched(
        |src| src.replace("\"kind\": \"step_apply\"", "\"kind\": \"warp_apply\""),
        "kind",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("es_apply_blk8_b8"), "names the exe: {msg}");
    assert!(msg.contains("warp_apply"), "names the bad value: {msg}");
    assert!(msg.contains("`kind`"), "names the field: {msg}");
    assert!(msg.contains("prefill_apply"), "lists the accepted kinds: {msg}");
}

#[test]
fn bad_fused_k_error_names_the_executable() {
    // an unroll depth of 1 is not a fused executable: the parse must
    // fail naming the exe and the bad value
    let err = load_patched(
        |src| src.replace("\"kind\": \"step_apply_k\", \"k\": 4",
                          "\"kind\": \"step_apply_k\", \"k\": 1"),
        "badk",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("es_applyk4_blk8_b8"), "names the exe: {msg}");
    assert!(msg.contains("`k`"), "names the field: {msg}");
    assert!(msg.contains("k >= 2"), "states the constraint: {msg}");

    // a step_apply_k entry without a `k` field at all (older emitter)
    // must also fail naming the exe
    let err = load_patched(
        |src| src.replace("\"kind\": \"step_apply_k\", \"k\": 4",
                          "\"kind\": \"step_apply_k\""),
        "missingk",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("es_applyk4_blk8_b8"), "names the exe: {msg}");
    assert!(msg.contains("requires a `k` field"), "{msg}");
}

#[test]
fn retained_output_must_reference_real_output_and_input() {
    let err = load_patched(
        |src| src.replacen("{\"output\": \"kv\", \"input\": \"kv\", \"alias\": true}",
                           "{\"output\": \"kvx\", \"input\": \"kv\", \"alias\": true}", 1),
        "retout",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("retained_outputs"), "{msg}");
    assert!(msg.contains("kvx"), "{msg}");

    let err = load_patched(
        |src| src.replacen("{\"output\": \"kv\", \"input\": \"kv\", \"alias\": true}",
                           "{\"output\": \"kv\", \"input\": \"kvx\", \"alias\": true}", 1),
        "retin",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("retained_outputs"), "{msg}");
    assert!(msg.contains("kvx"), "{msg}");
}

#[test]
fn alias_flag_must_be_boolean_and_error_names_the_exe() {
    // patch the first alias flag (prefill_apply_b8's kv signature) to a
    // string: the parse must fail naming the executable and the field
    let err = load_patched(
        |src| src.replacen("\"input\": \"kv\", \"alias\": true}",
                           "\"input\": \"kv\", \"alias\": \"yes\"}", 1),
        "aliastype",
    );
    let msg = format!("{err:#}");
    assert!(msg.contains("prefill_apply_b8"), "names the exe: {msg}");
    assert!(msg.contains("`alias`"), "names the field: {msg}");
    assert!(msg.contains("boolean"), "names the expected type: {msg}");
}

#[test]
fn alias_flag_defaults_to_no_donation() {
    // a manifest without alias flags (the pre-donation format) still
    // parses; the chain works, donation is just not declared
    let src = std::fs::read_to_string(golden_dir().join("manifest.json")).unwrap();
    let patched = src.replace(", \"alias\": true}", "}");
    let dir = std::env::temp_dir().join("esdllm-golden-noalias");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), patched).unwrap();
    let m = Manifest::load(&dir).expect("alias-less manifest parses");
    let pf = m.arch("llada-nano").unwrap().exe("prefill_apply_b8").unwrap();
    assert_eq!(pf.retained.len(), 3);
    assert!(pf.retained.iter().all(|r| !r.donate));
    assert!(pf.alias_pairs(1).is_empty(), "no donation declared");
    assert_eq!(pf.retain_flags(), vec![false, true, true, true], "chain intact");
}
