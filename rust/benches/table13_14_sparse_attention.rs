//! Tables 13 & 14: integration with sparse attention (prompt-KV pruning,
//! retention 0.5, smoothing kernel 3) — the Sparse-dLLM baseline is
//! DualCache+Sparse; ES-dLLM+Sparse adds early-skipping on top. Speedup
//! is vs DualCache without sparse attention, as in the paper.

use esdllm::bench::{bench_archs, bench_n, Table};
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::runtime::Runtime;
use esdllm::workload::{paper_name, BENCHMARKS};

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let n = bench_n(16);

    for arch in bench_archs() {
        let table_no = if arch.starts_with("llada") { 13 } else { 14 };
        let mut table = Table::new(
            &format!("Table {table_no} analog: sparse attention on {arch}, {n} samples"),
            &["Benchmark", "Method", "TPS", "Speedup vs DualCache", "Score"],
        );
        for bench in BENCHMARKS {
            let base =
                evaluate(&rt, &arch, Method::DualCache, bench, n, &EvalOpts::default())?;
            // Sparse-dLLM analog: cached pruning without early-skip
            let sparse_opts = EvalOpts { sparse: true, ..Default::default() };
            for (label, method) in
                [("Sparse-dLLM", Method::DualCache), ("ES-dLLM+Sparse", Method::EsDllm)]
            {
                let r = evaluate(&rt, &arch, method, bench, n, &sparse_opts)?;
                table.row(&[
                    paper_name(bench).to_string(),
                    label.to_string(),
                    format!("{:.2}", r.tps),
                    format!("{:.2}x", r.speedup_vs(&base)),
                    format!("{:.2}", r.score),
                ]);
            }
        }
        table.print();
        table.write_csv(&format!("artifacts/results/table{table_no}.csv"))?;
    }
    Ok(())
}
