//! Continuous batching vs run-to-completion on the same Poisson trace.
//!
//! Both modes run the identical router → slot-scheduler stack over the
//! deterministic simulation backend (per-plan sleeps model the measured
//! executable cost ordering: prefill ≫ dual step > es step), so this
//! bench runs on any machine — no artifacts, no PJRT.
//!
//! The workload is the serving-motivated skewed mix: mostly short
//! requests (one block) with a rare long pole (every 8th request needs
//! all 8 blocks). Run-to-completion drains a batch before admitting the
//! queue, so the long pole holds seven finished slots hostage for ~7/8
//! of its lifetime; the continuous scheduler retires the shorts at their
//! block boundaries and admits queued requests into the freed slots
//! mid-flight. Expected outcome (reported below): continuous batching
//! sustains higher slot occupancy and higher token throughput, with far
//! lower tail latency, on the same arrival trace.
//!
//! A second section exercises **pooled device residency** under
//! batch-class churn: two workers share one residency pool while a
//! trace alternates lone requests (the scheduler downshifts to the b=1
//! class) with Poisson bursts (upshift to the full class). Every switch
//! parks the outgoing retained chain and resumes the incoming one, so
//! the section's acceptance is that chains are re-used, not rebuilt:
//! `chain_rebuilds_avoided > 0` with bounded full-KV seeds. Emits
//! `artifacts/results/BENCH_residency.json`; runs artifact-free in CI.
//!
//! A third section exercises **fault injection + recovery**: the same
//! Poisson trace runs fault-free and with a seeded Bernoulli fault rate
//! over every injector event (exec / transfer / alloc / fused
//! divergence). The recovery ladder — re-ground + bounded retry, fused
//! depth demotion, LRU eviction — must absorb every transient fault:
//! the acceptance gate is zero failed requests AND goodput (tokens/s)
//! ≥ 90% of the fault-free run. Emits
//! `artifacts/results/BENCH_faults.json`; runs artifact-free in CI.
//!
//! A fourth section exercises the **cross-request prefix cache** on a
//! deterministic multi-turn chat trace: every turn re-submits its
//! conversation's full prior context plus a fresh message, so each
//! retirement inserts a block-aligned prefix that the next turn's
//! admission probes. The trace runs twice — with and without a shared
//! `PrefixCache` — and the section gates on token-identical outputs
//! (prefix seeding is trajectory-exact), `prefix_hits > 0`, and
//! `prefill_bytes_saved` ≥ 50% of the block-aligned baseline prefill
//! bytes. Emits `artifacts/results/BENCH_prefix.json`; runs
//! artifact-free in CI.
//!
//! A fifth section exercises **SLO-aware serving under overload**: the
//! identical bursty trace (square-wave arrival rate, ~20% of requests
//! latency-sensitive) runs under the plain FIFO policy and under the
//! SLO-aware policy (priority lanes + block-boundary preemption +
//! lowest-class shedding). The acceptance gate is that every request
//! gets SOME reply (completion or structured shed — never a hang), the
//! SLO-aware run exercised preemption or shedding, and the
//! latency-sensitive p99 TTFT drops to ≤ 0.5× the FIFO baseline. Emits
//! `artifacts/results/BENCH_slo.json`; runs artifact-free in CI.
//!
//! A sixth section exercises **live-context decoding** on a mixed
//! gen-length trace (the workload generator draws a short / medium /
//! unbounded `gen_len` tier per request): the identical trace runs with
//! suffix pruning off and on. With pruning on, the scheduler sizes each
//! dispatch to the group's live frontier (per-request `gen_len` caps
//! it), prunes fully-decoded suffix blocks from the attention context
//! at block boundaries, and retires trailing blocks early on the EOS
//! guard. The acceptance gate is token-identical outputs, a non-zero
//! pruning ledger, and ≥ 30% reduction in per-token attention FLOPs or
//! uplink+downlink bytes. Emits `artifacts/results/BENCH_suffix.json`;
//! runs artifact-free in CI.
//!
//! Run: `cargo bench --bench serve_continuous` (ESDLLM_BENCH_N overrides
//! the request count).

use std::time::{Duration, Instant};

use esdllm::batcher::BatcherCfg;
use esdllm::bench::{bench_n, Table};
use esdllm::cache::RefreshPolicy;
use esdllm::engine::{EngineCfg, Method};
use esdllm::router::{
    Router, RouterCfg, SchedMode, SloPolicy, WorkerBackend, PREFIX_CACHE_BUDGET,
};
use esdllm::runtime::resident::{PrefixCache, PrefixStats};
use esdllm::scheduler::sim::{SimBackend, SimCfg};
use esdllm::scheduler::{GroupScheduler, SchedCfg, SeqInput, SeqParams, SloClass};
use esdllm::workload;

const SLOTS: usize = 8;
/// arrivals per second: above the run-to-completion capacity, below the
/// continuous capacity, so head-of-line blocking becomes visible
const RATE: f64 = 110.0;

fn engine_cfg() -> EngineCfg {
    let mut cfg = EngineCfg::new("llada-nano", Method::EsDllm);
    // small blocks amplify the grounding-prefill cadence the continuous
    // scheduler shares across slots
    cfg.block = 4;
    cfg.refresh = RefreshPolicy { prompt_period: 16, block_period: 2 };
    cfg
}

/// Skewed echo workload: the sim completion length equals the prompt
/// length, so every 8th request is an 8-block pole and the rest finish
/// after one block.
fn prompt_for(i: usize) -> String {
    const SHORT: [&str; 7] = ["1+2", "9*8", "0-1", "a|b", "x&y", "7*7", "3,4"];
    if i % 8 == 0 {
        "sort(9,8,7,6,5,4,3,2,1,0)=0123".to_string() // 30 chars → 8 blocks
    } else {
        SHORT[i % SHORT.len()].to_string() // 3 chars → 1 block
    }
}

struct ModeResult {
    label: &'static str,
    completed: usize,
    failed: usize,
    wall_s: f64,
    tokens: u64,
    tps: f64,
    occupancy: f64,
    tps_busy_slot: f64,
    p50_s: f64,
    p90_s: f64,
    /// resident-cache accounting: bytes shipped / saved per scheduler tick
    up_kb_per_tick: f64,
    saved_kb_per_tick: f64,
    full_kv_uploads: u64,
    /// device-apply accounting: D2H KB avoided per tick, retained-output
    /// chain reuses per tick, in-graph-confidence steps
    d2h_avoided_kb_per_tick: f64,
    retained_reuse_per_tick: f64,
    ingraph_conf_steps: u64,
    /// sliced-downlink accounting: sampler-bound KB actually downloaded
    /// per tick, and KB saved per tick vs the full-context [B, ctx, V]
    /// logit download
    down_kb_per_tick: f64,
    down_saved_kb_per_tick: f64,
    donated_execs: u64,
    /// dispatch-cadence accounting: device executions (prefill + dual +
    /// es) per scheduler tick, and the fused k-step amortization
    dispatches_per_tick: f64,
    fused_execs: u64,
    avg_iters_per_dispatch: f64,
    /// pooled-residency accounting (shared ResidencyPool ledger)
    chain_switches: u64,
    chain_rebuilds_avoided: u64,
    reseed_kb_saved: f64,
    resident_chains: u64,
}

fn run_mode(mode: SchedMode, label: &'static str, n: usize) -> ModeResult {
    let mut cfg = RouterCfg::new(engine_cfg(), std::path::PathBuf::from("/nonexistent"));
    cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(8000, 1500, 1000));
    cfg.batcher = BatcherCfg { max_batch: SLOTS, flush_ms: 5 };
    cfg.queue_cap = 1024;
    cfg.mode = mode;
    let router = Router::start(cfg);

    // identical arrival process for both modes
    let trace = workload::poisson_trace(RATE, n, 0xC0117);
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(n);
    let mut i = 0usize;
    workload::replay_trace(&trace, |_req| {
        if let Ok(h) = router.submit(prompt_for(i), SeqParams::default()) {
            handles.push(h);
        }
        i += 1;
    });
    let mut completed = 0usize;
    let mut failed = 0usize;
    for h in handles {
        match h.wait() {
            Ok(_) => completed += 1,
            Err(_) => failed += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = &router.metrics;
    let tokens = m.tokens_generated.get();
    let busy = m.slot_busy_seconds.get_secs();
    let ticks = m.ticks_total.get().max(1);
    let result = ModeResult {
        label,
        completed,
        failed,
        wall_s,
        tokens,
        tps: tokens as f64 / wall_s,
        occupancy: (busy / (wall_s * SLOTS as f64)).min(1.0),
        tps_busy_slot: m.tps_per_busy_slot(),
        p50_s: m.request_latency.quantile(0.5),
        p90_s: m.request_latency.quantile(0.9),
        up_kb_per_tick: m.upload_bytes.get() as f64 / 1e3 / ticks as f64,
        saved_kb_per_tick: m.upload_bytes_saved.get() as f64 / 1e3 / ticks as f64,
        full_kv_uploads: m.full_kv_uploads.get(),
        d2h_avoided_kb_per_tick: m.d2h_bytes_avoided.get() as f64 / 1e3 / ticks as f64,
        retained_reuse_per_tick: m.retained_out_reuses.get() as f64 / ticks as f64,
        ingraph_conf_steps: m.ingraph_conf_steps.get(),
        down_kb_per_tick: m.d2h_bytes_shipped.get() as f64 / 1e3 / ticks as f64,
        down_saved_kb_per_tick: m.d2h_bytes_saved.get() as f64 / 1e3 / ticks as f64,
        donated_execs: m.donated_execs.get(),
        dispatches_per_tick: (m.prefill_steps.get() + m.dual_steps.get() + m.es_steps.get())
            as f64
            / ticks as f64,
        fused_execs: m.fused_execs.get(),
        avg_iters_per_dispatch: if m.fused_execs.get() == 0 {
            1.0
        } else {
            m.inner_iters_fused.get() as f64 / m.fused_execs.get() as f64
        },
        chain_switches: m.chain_switches.get(),
        chain_rebuilds_avoided: m.chain_rebuilds_avoided.get(),
        reseed_kb_saved: m.reseed_bytes_saved.get() as f64 / 1e3,
        resident_chains: m.resident_chains.get(),
    };
    router.shutdown();
    result
}

/// Batch-class-churn section: `workers` workers over one shared
/// residency pool, driven by `rounds` of (lone request → Poisson burst)
/// so schedulers repeatedly park and resume the b=1 and full-class
/// chains. Asserts chain reuse and emits BENCH_residency.json.
fn residency_section(workers: usize, rounds: usize) -> anyhow::Result<()> {
    let mut cfg = RouterCfg::new(engine_cfg(), std::path::PathBuf::from("/nonexistent"));
    cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(2000, 600, 400));
    cfg.batcher = BatcherCfg { max_batch: SLOTS, flush_ms: 5 };
    cfg.queue_cap = 1024;
    cfg.mode = SchedMode::Continuous;
    cfg.workers = workers;
    let router = Router::start(cfg);

    let t0 = Instant::now();
    let mut completed = 0usize;
    let mut failed = 0usize;
    for round in 0..rounds {
        // a lone request: demand 1 → the serving worker downshifts to
        // the b=1 class (parking its full-class chain, if any)
        if let Ok(h) = router.submit(prompt_for(1), SeqParams::default()) {
            match h.wait() {
                Ok(_) => completed += 1,
                Err(_) => failed += 1,
            }
        }
        // a Poisson burst: demand ≫ 1 → upshift back to the full class,
        // resuming the parked chain (zero full-KV reseed on a hit)
        let trace = workload::poisson_trace(400.0, 2 * SLOTS, 0xD1CE + round as u64);
        let mut handles = Vec::new();
        let mut i = 0usize;
        workload::replay_trace(&trace, |_req| {
            if let Ok(h) = router.submit(prompt_for(i + 1), SeqParams::default()) {
                handles.push(h);
            }
            i += 1;
        });
        for h in handles {
            match h.wait() {
                Ok(_) => completed += 1,
                Err(_) => failed += 1,
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let m = &router.metrics;
    let switches = m.chain_switches.get();
    let rebuilds_avoided = m.chain_rebuilds_avoided.get();
    let reseed_saved = m.reseed_bytes_saved.get();
    let resident_chains = m.resident_chains.get();
    let full_kv_uploads = m.full_kv_uploads.get();
    router.shutdown();

    println!(
        "\n== residency: {rounds} churn rounds (lone ↔ Poisson burst) over \
         {workers} workers sharing one pool =="
    );
    println!(
        "completed {completed} (failed {failed}) in {wall_s:.2}s; \
         {switches} class switches, {rebuilds_avoided} chain rebuilds avoided, \
         {:.1} KB of reseed traffic saved, {resident_chains} resident chains, \
         {full_kv_uploads} full-KV seeds total",
        reseed_saved as f64 / 1e3,
    );

    std::fs::create_dir_all("artifacts/results")?;
    let json = format!(
        "{{\n  \"bench\": \"serve_continuous_residency\",\n  \
         \"workers\": {workers},\n  \"rounds\": {rounds},\n  \
         \"completed\": {completed},\n  \"failed\": {failed},\n  \
         \"wall_s\": {wall_s:.3},\n  \"chain_switches\": {switches},\n  \
         \"chain_rebuilds_avoided\": {rebuilds_avoided},\n  \
         \"reseed_bytes_saved\": {reseed_saved},\n  \
         \"resident_chains\": {resident_chains},\n  \
         \"full_kv_uploads\": {full_kv_uploads}\n}}\n"
    );
    std::fs::write("artifacts/results/BENCH_residency.json", json)?;
    println!("wrote artifacts/results/BENCH_residency.json");

    // acceptance: batch-class churn must RE-USE parked chains — at
    // least one resumed chain (an avoided cold rebuild with its seed
    // bytes saved), and the seed count stays bounded by (worker, class)
    // pairs instead of growing with the trace
    let ok = switches >= 2
        && rebuilds_avoided >= 1
        && reseed_saved > 0
        && full_kv_uploads <= (2 * workers) as u64;
    println!(
        "acceptance (chains reused across b1↔b{SLOTS} churn, seeds bounded \
         by workers × classes): {}",
        if ok { "PASS" } else { "FAIL" }
    );
    if !ok {
        return Err(anyhow::anyhow!(
            "residency churn reused no chains: switches={switches} \
             rebuilds_avoided={rebuilds_avoided} reseed_saved={reseed_saved} \
             full_kv_uploads={full_kv_uploads}"
        ));
    }
    Ok(())
}

/// Fault-trace section: the identical Poisson workload, fault-free vs
/// a seeded per-event fault rate. Reports the FaultStats ledger and
/// gates on full recovery (no failed requests) at ≥ 90% of the
/// fault-free goodput. Emits BENCH_faults.json.
fn fault_section(n: usize) -> anyhow::Result<()> {
    let run = |plan: &str| -> anyhow::Result<(usize, usize, f64, u64, [u64; 7])> {
        let mut cfg = RouterCfg::new(engine_cfg(), std::path::PathBuf::from("/nonexistent"));
        cfg.engine.fault_plan = esdllm::fault::FaultPlan::parse(plan)
            .map_err(anyhow::Error::msg)?;
        cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(8000, 1500, 1000));
        cfg.batcher = BatcherCfg { max_batch: SLOTS, flush_ms: 5 };
        cfg.queue_cap = 1024;
        cfg.mode = SchedMode::Continuous;
        let router = Router::start(cfg);
        let trace = workload::poisson_trace(RATE, n, 0xC0117);
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(n);
        let mut i = 0usize;
        workload::replay_trace(&trace, |_req| {
            if let Ok(h) = router.submit(prompt_for(i), SeqParams::default()) {
                handles.push(h);
            }
            i += 1;
        });
        let mut completed = 0usize;
        let mut failed = 0usize;
        for h in handles {
            match h.wait() {
                Ok(_) => completed += 1,
                Err(_) => failed += 1,
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let m = &router.metrics;
        let tokens = m.tokens_generated.get();
        let ledger = [
            m.faults_injected.get(),
            m.ticks_retried.get(),
            m.chains_regrounded.get(),
            m.fused_k_demotions.get(),
            m.host_demotions.get(),
            m.requests_failed.get(),
            m.timeouts_total.get(),
        ];
        router.shutdown();
        Ok((completed, failed, wall_s, tokens, ledger))
    };

    let (c0, f0, w0, tok0, _) = run("")?;
    // ~1% of injector events fault (seeded, deterministic draws): a few
    // re-ground + retry cycles per hundred ticks at this trace length
    let (c1, f1, w1, tok1, ledger) = run("rate=0.01,seed=7")?;
    let goodput0 = tok0 as f64 / w0.max(1e-9);
    let goodput1 = tok1 as f64 / w1.max(1e-9);
    let ratio = goodput1 / goodput0.max(1e-9);
    let [injected, retried, regrounded, demotions_k, demotions_host, req_failed, timeouts] =
        ledger;

    println!("\n== faults: same {n}-request trace, fault-free vs rate=0.01 ==");
    println!(
        "fault-free: {c0} done ({f0} failed) in {w0:.2}s, {goodput0:.1} tok/s; \
         faulted: {c1} done ({f1} failed) in {w1:.2}s, {goodput1:.1} tok/s \
         (goodput ×{ratio:.3})"
    );
    println!(
        "recovery ledger: {injected} faults injected, {retried} ticks retried, \
         {regrounded} chains re-grounded, {demotions_k} fused-k demotions, \
         {demotions_host} host demotions, {req_failed} requests failed, \
         {timeouts} timeouts"
    );

    std::fs::create_dir_all("artifacts/results")?;
    let json = format!(
        "{{\n  \"bench\": \"serve_continuous_faults\",\n  \
         \"requests\": {n},\n  \"fault_rate\": 0.01,\n  \
         \"clean_completed\": {c0},\n  \"clean_goodput_tps\": {goodput0:.3},\n  \
         \"faulted_completed\": {c1},\n  \"faulted_failed\": {f1},\n  \
         \"faulted_goodput_tps\": {goodput1:.3},\n  \
         \"goodput_ratio\": {ratio:.4},\n  \
         \"faults_injected\": {injected},\n  \"ticks_retried\": {retried},\n  \
         \"chains_regrounded\": {regrounded},\n  \
         \"fused_k_demotions\": {demotions_k},\n  \
         \"host_demotions\": {demotions_host},\n  \
         \"requests_failed\": {req_failed},\n  \"timeouts_total\": {timeouts}\n}}\n"
    );
    std::fs::write("artifacts/results/BENCH_faults.json", json)?;
    println!("wrote artifacts/results/BENCH_faults.json");

    // acceptance: every transient fault recovered (nobody failed) and
    // the retry overhead cost at most 10% goodput
    let ok = injected >= 1 && f1 == 0 && req_failed == 0 && ratio >= 0.9;
    println!(
        "acceptance (faults fired, zero unrecovered, goodput ≥ 0.9× \
         fault-free): {}",
        if ok { "PASS" } else { "FAIL" }
    );
    if !ok {
        return Err(anyhow::anyhow!(
            "fault recovery degraded service: injected={injected} failed={f1} \
             requests_failed={req_failed} goodput_ratio={ratio:.4}"
        ));
    }
    Ok(())
}

/// Replay the chat trace turn-by-turn (each turn driven to retirement
/// before the next is admitted — the sequencing under which turn i's
/// retirement inserts the prefix that turn i+1's admission probes)
/// through the slot scheduler over the sim backend. Returns the decoded
/// texts, the prefix ledger, and the block-aligned baseline prefill
/// bytes a cacheless server grounds over the same admissions.
fn run_chat_trace(
    trace: &[workload::TraceRequest],
    cached: bool,
) -> anyhow::Result<(Vec<String>, PrefixStats, u64)> {
    let mut backend = SimBackend::new(SimCfg::default());
    if cached {
        backend.set_prefix_cache(PrefixCache::new(PREFIX_CACHE_BUDGET));
    }
    let scfg = SchedCfg::from_engine(&engine_cfg());
    let block = scfg.block;
    let mut s = GroupScheduler::new(Box::new(backend), 2, scfg)?;
    let row_bytes = s.group_caches().kv_row_bytes() as u64;
    let plen = s.group_caches().dims.prompt_len;
    let mut texts = Vec::with_capacity(trace.len());
    let mut baseline = 0u64;
    for (i, req) in trace.iter().enumerate() {
        let clen = req.item.prompt.len().min(plen);
        baseline += ((clen / block) * block) as u64 * row_bytes;
        s.admit(SeqInput {
            id: i as u64,
            prompt: req.item.prompt.clone(),
            params: SeqParams::default(),
            submitted: Instant::now(),
        })?;
        let mut guard = 0;
        while s.active() > 0 {
            for f in s.tick()? {
                texts.push(f.text);
            }
            guard += 1;
            anyhow::ensure!(guard < 10_000, "chat scheduler failed to drain");
        }
    }
    Ok((texts, s.prefix_stats(), baseline))
}

/// Multi-turn chat section: the cross-request prefix cache. Runs the
/// identical deterministic chat trace with and without a shared
/// `PrefixCache`, asserts the decoded outputs are token-identical
/// (prefix-seeded admission is trajectory-exact), and gates on a warm
/// hit rate > 0 with ≥ 50% of the baseline grounding-prefill bytes
/// credited as saved. Emits BENCH_prefix.json.
fn prefix_section(conversations: usize, turns: usize) -> anyhow::Result<()> {
    let plen = SimCfg::default().dims.prompt_len;
    let trace = workload::chat_trace(conversations, turns, 200.0, plen, 0xCAFE);
    let requests = trace.len();

    let t0 = Instant::now();
    let (cached_texts, xs, baseline) = run_chat_trace(&trace, true)?;
    let (plain_texts, no_cache_xs, _) = run_chat_trace(&trace, false)?;
    let wall_s = t0.elapsed().as_secs_f64();

    let identical = cached_texts == plain_texts;
    let ratio = xs.prefill_bytes_saved as f64 / (baseline as f64).max(1.0);

    println!(
        "\n== prefix: {conversations} conversations × {turns} turns \
         ({requests} requests), cached vs cacheless =="
    );
    println!(
        "{} hits / {} misses in {wall_s:.2}s; {} B of grounding-prefill \
         traffic saved of a {baseline} B block-aligned baseline \
         ({:.1}%); {} B resident, {} evictions; outputs token-identical: \
         {identical}",
        xs.prefix_hits,
        xs.prefix_misses,
        xs.prefill_bytes_saved,
        100.0 * ratio,
        xs.prefix_cache_bytes,
        xs.prefix_evictions,
    );
    assert_eq!(
        no_cache_xs,
        PrefixStats::default(),
        "the cacheless run must touch no prefix ledger"
    );

    std::fs::create_dir_all("artifacts/results")?;
    let json = format!(
        "{{\n  \"bench\": \"serve_continuous_prefix\",\n  \
         \"conversations\": {conversations},\n  \"turns\": {turns},\n  \
         \"requests\": {requests},\n  \"wall_s\": {wall_s:.3},\n  \
         \"prefix_hits\": {},\n  \"prefix_misses\": {},\n  \
         \"prefill_bytes_saved\": {},\n  \
         \"baseline_prefill_bytes\": {baseline},\n  \
         \"saved_ratio\": {ratio:.4},\n  \"prefix_cache_bytes\": {},\n  \
         \"prefix_evictions\": {},\n  \"token_identical\": {identical}\n}}\n",
        xs.prefix_hits,
        xs.prefix_misses,
        xs.prefill_bytes_saved,
        xs.prefix_cache_bytes,
        xs.prefix_evictions,
    );
    std::fs::write("artifacts/results/BENCH_prefix.json", json)?;
    println!("wrote artifacts/results/BENCH_prefix.json");

    // acceptance: warm turns must HIT (every turn past a conversation's
    // first re-submits a cached block-aligned prefix), the credited
    // savings must cover at least half the baseline grounding-prefill
    // bytes, and caching must not perturb a single decoded token
    let ok = xs.prefix_hits > 0 && ratio >= 0.5 && identical;
    println!(
        "acceptance (warm hits, ≥ 50% prefill bytes saved, \
         trajectory-exact): {}",
        if ok { "PASS" } else { "FAIL" }
    );
    if !ok {
        return Err(anyhow::anyhow!(
            "prefix cache underperformed: hits={} saved={} baseline={baseline} \
             ratio={ratio:.4} identical={identical}",
            xs.prefix_hits,
            xs.prefill_bytes_saved,
        ));
    }
    Ok(())
}

struct SloRun {
    completed: usize,
    shed: usize,
    unreplied: usize,
    ls_count: u64,
    ls_p50_ttft: f64,
    ls_p99_ttft: f64,
    preemptions: u64,
    resumed: u64,
    shed_total: u64,
}

/// One pass of the bursty mixed-SLO trace through a small (2-slot)
/// router under `policy`. Every handle is waited with a generous bound
/// so a wedged worker shows up as `unreplied` instead of hanging the
/// bench.
fn slo_run(policy: SloPolicy, trace: &[workload::TraceRequest]) -> SloRun {
    let mut cfg = RouterCfg::new(engine_cfg(), std::path::PathBuf::from("/nonexistent"));
    cfg.backend = WorkerBackend::Sim(SimCfg::default().with_costs(8000, 1500, 1000));
    // 2 slots: bursts saturate the device, so latency-sensitive arrivals
    // must either jump the queue (priority lanes) or take a slot
    // (block-boundary preemption) to meet their SLO
    cfg.batcher = BatcherCfg { max_batch: 2, flush_ms: 5 };
    cfg.queue_cap = 32;
    cfg.mode = SchedMode::Continuous;
    cfg.policy = policy;
    let router = Router::start(cfg);

    let mut handles = Vec::with_capacity(trace.len());
    workload::replay_trace(trace, |req| {
        let params = SeqParams { slo: req.slo, ..Default::default() };
        if let Ok(h) = router.submit(req.item.prompt.clone(), params) {
            handles.push(h);
        }
    });
    let mut completed = 0usize;
    let mut shed = 0usize;
    let mut unreplied = 0usize;
    for h in &handles {
        match h.wait_timeout(Duration::from_secs(120)) {
            Some(Ok(_)) => completed += 1,
            Some(Err(_)) => shed += 1,
            None => unreplied += 1,
        }
    }
    let m = &router.metrics;
    let ls = SloClass::LatencySensitive.index();
    let run = SloRun {
        completed,
        shed,
        unreplied,
        ls_count: m.class_ttft[ls].count(),
        ls_p50_ttft: m.class_ttft[ls].quantile(0.5),
        ls_p99_ttft: m.class_ttft[ls].quantile(0.99),
        preemptions: m.preemptions_total.get(),
        resumed: m.resumed_total.get(),
        shed_total: m.shed_total.get(),
    };
    router.shutdown();
    run
}

/// SLO section: FIFO vs SLO-aware on the identical overload burst
/// trace. Gates on zero un-replied requests under both policies, on the
/// SLO-aware run actually exercising its machinery (preemptions or
/// sheds), and on the latency-sensitive p99 TTFT dropping to ≤ 0.5× the
/// FIFO baseline. Emits BENCH_slo.json.
fn slo_section(n: usize) -> anyhow::Result<()> {
    // square-wave overload: 30% of each second runs at 10× the base
    // rate — ~2× the 2-slot capacity on average, far above it in-burst —
    // with the ~20/70/10 latency-sensitive/throughput/batch mix
    let trace = workload::burst_trace(40.0, 400.0, 1.0, 0.3, n, 0x510);
    let fifo = slo_run(SloPolicy::Fifo, &trace);
    let slo = slo_run(SloPolicy::SloAware, &trace);

    println!("\n== slo: {n}-request overload burst, FIFO vs SLO-aware ==");
    for (label, r) in [("fifo", &fifo), ("slo-aware", &slo)] {
        println!(
            "{label:>9}: {} completed, {} shed, {} unreplied; \
             LS TTFT p50 {:.3}s p99 {:.3}s ({} obs); \
             {} preemptions, {} resumes, {} sheds",
            r.completed, r.shed, r.unreplied, r.ls_p50_ttft, r.ls_p99_ttft,
            r.ls_count, r.preemptions, r.resumed, r.shed_total,
        );
    }
    let ratio = slo.ls_p99_ttft / fifo.ls_p99_ttft.max(1e-9);

    std::fs::create_dir_all("artifacts/results")?;
    let json = format!(
        "{{\n  \"bench\": \"serve_continuous_slo\",\n  \"requests\": {n},\n  \
         \"fifo_completed\": {},\n  \"fifo_unreplied\": {},\n  \
         \"fifo_ls_p99_ttft_s\": {:.4},\n  \
         \"slo_completed\": {},\n  \"slo_shed\": {},\n  \
         \"slo_unreplied\": {},\n  \"slo_ls_p99_ttft_s\": {:.4},\n  \
         \"ls_p99_ratio\": {ratio:.4},\n  \"preemptions\": {},\n  \
         \"victim_resumes\": {},\n  \"shed_total\": {}\n}}\n",
        fifo.completed, fifo.unreplied, fifo.ls_p99_ttft,
        slo.completed, slo.shed, slo.unreplied, slo.ls_p99_ttft,
        slo.preemptions, slo.resumed, slo.shed_total,
    );
    std::fs::write("artifacts/results/BENCH_slo.json", json)?;
    println!("wrote artifacts/results/BENCH_slo.json");

    // acceptance: overload is answered, never absorbed silently — every
    // request gets a completion or a structured shed under BOTH
    // policies, the SLO-aware machinery actually fired, and the
    // latency-sensitive tail collapses vs FIFO
    let ok = fifo.unreplied == 0
        && slo.unreplied == 0
        && fifo.ls_count > 0
        && slo.ls_count > 0
        && (slo.preemptions >= 1 || slo.shed_total >= 1)
        && slo.ls_p99_ttft <= 0.5 * fifo.ls_p99_ttft;
    println!(
        "acceptance (zero unreplied, slo machinery fired, LS p99 TTFT \
         ≤ 0.5× FIFO — measured ×{ratio:.3}): {}",
        if ok { "PASS" } else { "FAIL" }
    );
    if !ok {
        return Err(anyhow::anyhow!(
            "slo policy underperformed: fifo_unreplied={} slo_unreplied={} \
             fifo_ls_p99={:.4} slo_ls_p99={:.4} ratio={ratio:.4} \
             preemptions={} shed_total={}",
            fifo.unreplied, slo.unreplied, fifo.ls_p99_ttft, slo.ls_p99_ttft,
            slo.preemptions, slo.shed_total,
        ));
    }
    Ok(())
}

struct SuffixRun {
    texts: Vec<String>,
    completed: usize,
    failed: usize,
    wall_s: f64,
    tokens: u64,
    ticks: u64,
    up_bytes: u64,
    down_bytes: u64,
    flops: u64,
    live_rows: u64,
    full_rows: u64,
    pruned_blocks: u64,
    retired_blocks: u64,
    switches: u64,
}

/// One pass of the mixed gen-length trace through the continuous
/// router, with live-context decoding on or off. The per-request
/// `gen_len` tier drawn by the trace generator rides in on `SeqParams`,
/// so short requests compile down to a 2-block frontier while the rare
/// long pole walks the whole tier ladder.
fn suffix_run(live: bool, trace: &[workload::TraceRequest]) -> SuffixRun {
    let mut cfg = RouterCfg::new(engine_cfg(), std::path::PathBuf::from("/nonexistent"));
    let sim = SimCfg::default();
    let tiers = SimCfg::default_ctx_tiers(&sim.dims);
    cfg.backend = WorkerBackend::Sim(sim.with_ctx_tiers(&tiers).with_costs(8000, 1500, 1000));
    cfg.batcher = BatcherCfg { max_batch: SLOTS, flush_ms: 5 };
    cfg.queue_cap = 1024;
    cfg.mode = SchedMode::Continuous;
    cfg.live_ctx = live;
    let router = Router::start(cfg);
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(trace.len());
    let mut i = 0usize;
    workload::replay_trace(trace, |req| {
        let params = SeqParams { gen_len: req.gen_len, ..Default::default() };
        if let Ok(h) = router.submit(prompt_for(i), params) {
            handles.push(h);
        }
        i += 1;
    });
    let mut texts = Vec::with_capacity(handles.len());
    let mut completed = 0usize;
    let mut failed = 0usize;
    for h in handles {
        match h.wait() {
            Ok(r) => {
                completed += 1;
                texts.push(r.text);
            }
            Err(e) => {
                failed += 1;
                texts.push(format!("<error: {e}>"));
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let m = &router.metrics;
    let run = SuffixRun {
        texts,
        completed,
        failed,
        wall_s,
        tokens: m.tokens_generated.get(),
        ticks: m.ticks_total.get(),
        up_bytes: m.upload_bytes.get(),
        down_bytes: m.d2h_bytes_shipped.get(),
        flops: m.flops_units.get(),
        live_rows: m.live_ctx_rows.get(),
        full_rows: m.full_ctx_rows.get(),
        pruned_blocks: m.suffix_blocks_pruned.get(),
        retired_blocks: m.early_retired_blocks.get(),
        switches: m.tier_switches.get(),
    };
    router.shutdown();
    run
}

/// Suffix-pruning section: the identical mixed gen-length Poisson trace
/// (short / medium / unbounded tiers drawn by the workload generator)
/// runs with live-context decoding off and on. Gates on token-identical
/// outputs (tier switching, suffix pruning, and early retirement are
/// trajectory-exact), on the pruning machinery actually firing, and on
/// a ≥ 30% reduction in per-token attention FLOPs OR per-token
/// uplink+downlink bytes. Emits BENCH_suffix.json.
fn suffix_section(n: usize) -> anyhow::Result<()> {
    let trace = workload::poisson_trace(RATE, n, 0x5F17);
    let full = suffix_run(false, &trace);
    let pruned = suffix_run(true, &trace);

    let identical = full.texts == pruned.texts;
    let per_tok = |bytes: u64, toks: u64| bytes as f64 / (toks as f64).max(1.0);
    let full_bpt = per_tok(full.up_bytes + full.down_bytes, full.tokens);
    let pruned_bpt = per_tok(pruned.up_bytes + pruned.down_bytes, pruned.tokens);
    let byte_red = 1.0 - pruned_bpt / full_bpt.max(1e-9);
    let full_fpt = per_tok(full.flops, full.tokens);
    let pruned_fpt = per_tok(pruned.flops, pruned.tokens);
    let flops_red = 1.0 - pruned_fpt / full_fpt.max(1e-9);
    let best_red = byte_red.max(flops_red);
    let live_ratio = pruned.live_rows as f64 / (pruned.full_rows as f64).max(1.0);

    println!(
        "\n== suffix: {n}-request mixed gen-length trace \
         (short/medium/unbounded tiers), full-context vs live-context =="
    );
    for (label, r) in [("full", &full), ("pruned", &pruned)] {
        println!(
            "{label:>7}: {} completed ({} failed) in {:.2}s; {} tokens over \
             {} ticks; {:.1} flops-units/tok, {:.1} B/tok up+down; \
             {} suffix blocks pruned, {} blocks retired early, \
             {} tier switches",
            r.completed,
            r.failed,
            r.wall_s,
            r.tokens,
            r.ticks,
            per_tok(r.flops, r.tokens),
            per_tok(r.up_bytes + r.down_bytes, r.tokens),
            r.pruned_blocks,
            r.retired_blocks,
            r.switches,
        );
    }
    println!(
        "live-context decode attends {:.1}% of the compiled-maximum rows; \
         outputs token-identical: {identical}; FLOPs −{:.1}%, \
         uplink+downlink bytes −{:.1}%",
        100.0 * live_ratio,
        100.0 * flops_red,
        100.0 * byte_red,
    );

    std::fs::create_dir_all("artifacts/results")?;
    let json = format!(
        "{{\n  \"bench\": \"serve_continuous_suffix\",\n  \
         \"requests\": {n},\n  \"full_completed\": {},\n  \
         \"pruned_completed\": {},\n  \"pruned_failed\": {},\n  \
         \"token_identical\": {identical},\n  \
         \"full_flops_per_tok\": {full_fpt:.3},\n  \
         \"pruned_flops_per_tok\": {pruned_fpt:.3},\n  \
         \"flops_reduction\": {flops_red:.4},\n  \
         \"full_bytes_per_tok\": {full_bpt:.3},\n  \
         \"pruned_bytes_per_tok\": {pruned_bpt:.3},\n  \
         \"byte_reduction\": {byte_red:.4},\n  \
         \"live_row_ratio\": {live_ratio:.4},\n  \
         \"suffix_blocks_pruned\": {},\n  \
         \"early_retired_blocks\": {},\n  \"tier_switches\": {}\n}}\n",
        full.completed,
        pruned.completed,
        pruned.failed,
        pruned.pruned_blocks,
        pruned.retired_blocks,
        pruned.switches,
    );
    std::fs::write("artifacts/results/BENCH_suffix.json", json)?;
    println!("wrote artifacts/results/BENCH_suffix.json");

    // acceptance: pruning must be invisible in the outputs (every token
    // identical to the full-context run), must actually fire (suffix
    // blocks pruned and trailing blocks retired, while the full run's
    // ledger stays untouched), and must buy ≥ 30% of either steady-state
    // attention FLOPs or uplink+downlink transfer per generated token
    let ok = identical
        && pruned.pruned_blocks > 0
        && pruned.retired_blocks > 0
        && full.pruned_blocks == 0
        && full.retired_blocks == 0
        && best_red >= 0.30;
    println!(
        "acceptance (token-identical, pruning fired, ≥ 30% FLOPs or byte \
         reduction — measured {:.1}%): {}",
        100.0 * best_red,
        if ok { "PASS" } else { "FAIL" }
    );
    if !ok {
        return Err(anyhow::anyhow!(
            "suffix pruning underperformed: identical={identical} \
             pruned_blocks={} retired_blocks={} flops_red={flops_red:.4} \
             byte_red={byte_red:.4}",
            pruned.pruned_blocks,
            pruned.retired_blocks,
        ));
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let n = bench_n(330);
    println!(
        "== serve_continuous: {n} requests @ {RATE}/s over {SLOTS} slots \
         (skewed mix: 1 in 8 is an 8-block pole) =="
    );

    let rtc = run_mode(SchedMode::RunToCompletion, "run-to-completion", n);
    let cont = run_mode(SchedMode::Continuous, "continuous", n);

    let mut table = Table::new(
        "serve_continuous: run-to-completion vs continuous batching",
        &[
            "mode", "done", "fail", "wall s", "tokens", "TPS", "occupancy",
            "TPS/busy-slot", "p50 s", "p90 s", "up KB/tick", "saved KB/tick",
            "full-KV ups", "d2h-avoid KB/tick", "chain reuse/tick",
            "ingraph-conf", "down KB/tick", "down-saved KB/tick", "donated",
            "disp/tick",
        ],
    );
    for r in [&rtc, &cont] {
        table.row(&[
            r.label.to_string(),
            format!("{}", r.completed),
            format!("{}", r.failed),
            format!("{:.2}", r.wall_s),
            format!("{}", r.tokens),
            format!("{:.1}", r.tps),
            format!("{:.3}", r.occupancy),
            format!("{:.1}", r.tps_busy_slot),
            format!("{:.3}", r.p50_s),
            format!("{:.3}", r.p90_s),
            format!("{:.2}", r.up_kb_per_tick),
            format!("{:.2}", r.saved_kb_per_tick),
            format!("{}", r.full_kv_uploads),
            format!("{:.2}", r.d2h_avoided_kb_per_tick),
            format!("{:.2}", r.retained_reuse_per_tick),
            format!("{}", r.ingraph_conf_steps),
            format!("{:.2}", r.down_kb_per_tick),
            format!("{:.2}", r.down_saved_kb_per_tick),
            format!("{}", r.donated_execs),
            format!("{:.2}", r.dispatches_per_tick),
        ]);
    }
    table.print();
    table.write_csv("artifacts/results/serve_continuous.csv")?;

    println!(
        "\ncontinuous vs run-to-completion: TPS ×{:.2}, occupancy ×{:.2}, \
         p90 latency ×{:.2}",
        cont.tps / rtc.tps.max(1e-9),
        cont.occupancy / rtc.occupancy.max(1e-9),
        rtc.p90_s / cont.p90_s.max(1e-9),
    );
    println!(
        "resident caches: continuous ships {:.2} KB/tick and keeps {:.2} KB/tick \
         on-device ({} full-KV upload(s) = the residency seed; steady-state ES/dual \
         steps re-upload no KV bytes)",
        cont.up_kb_per_tick, cont.saved_kb_per_tick, cont.full_kv_uploads,
    );
    println!(
        "device-apply: {:.2} KB/tick of cache downloads avoided, {:.2} retained-\
         output reuses/tick, {} steps with in-graph confidence (no host conf \
         round-trip in either direction)",
        cont.d2h_avoided_kb_per_tick, cont.retained_reuse_per_tick,
        cont.ingraph_conf_steps,
    );
    println!(
        "sliced downlink: continuous downloads {:.2} KB/tick of gen-region \
         logit rows and keeps {:.2} KB/tick of prompt-region rows on device \
         vs the full-context [B, ctx, V] download; {} executions donated \
         their chained cache inputs in place",
        cont.down_kb_per_tick, cont.down_saved_kb_per_tick, cont.donated_execs,
    );
    println!(
        "dispatch cadence: continuous issues {:.2} device dispatches/tick \
         ({} fused k-step executions, {:.2} iterations per dispatch; this \
         trace's block-period-2 refresh leaves no consecutive-ES runs to \
         fuse — see perf_hotpath's kstep section for the fused-depth sweep)",
        cont.dispatches_per_tick, cont.fused_execs, cont.avg_iters_per_dispatch,
    );
    println!(
        "pooled residency: {} batch-class switches, {} chain rebuilds \
         avoided, {:.1} KB of reseed traffic saved, {} resident chains at \
         drain",
        cont.chain_switches, cont.chain_rebuilds_avoided,
        cont.reseed_kb_saved, cont.resident_chains,
    );
    let ok = cont.tps > rtc.tps && cont.occupancy > rtc.occupancy;
    println!(
        "acceptance (continuous > rtc on TPS and occupancy): {}",
        if ok { "PASS" } else { "FAIL" }
    );
    println!(
        "cost model: one flat sleep per executable RUN (static shapes — a \
         full-batch run costs the same however many rows are useful), so \
         continuous batching IS charged for fragmenting ticks into \
         per-(block, plan) groups; the prefill ≫ step ratio mirrors \
         perf_hotpath. Re-validate against the PJRT backend with real \
         artifacts before trusting absolute numbers."
    );

    // pooled-residency churn section (workers=2, shared pool)
    residency_section(2, 5)?;
    // fault-injection recovery section (same trace, seeded fault rate)
    fault_section(n.min(120))?;
    // cross-request prefix-cache section (multi-turn chat trace)
    prefix_section(6, 4)?;
    // SLO-aware overload section (bursty mixed-SLO trace, FIFO vs SLO)
    slo_section(n.min(120))?;
    // live-context suffix-pruning section (mixed gen-length trace)
    suffix_section(n.min(120))?;
    Ok(())
}
