//! Table 9: skip-ratio and skip-position ablation on the MATH analog
//! (chain, block = gen = 32) using llada-nano. Rows mirror the paper:
//! no skipping (DualCache), the default r1=r2=0.5, single-position ratio
//! sweep at layer 2, and position sweep at ratio 0.5. FLOPs proportion
//! comes from the analytic model (rust/src/flops).

use esdllm::bench::{bench_n, Table};
use esdllm::engine::Method;
use esdllm::eval::{evaluate, EvalOpts};
use esdllm::flops;
use esdllm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let n = bench_n(16);
    let arch = "llada-nano";
    let dims = rt.arch(arch)?.dims;
    let bench = "chain";
    let block = 32;

    // (label, exe override, skip spec) — nano layer mapping of the paper's
    // r0/r4/r8/r16 rows is r0/r1/r2/r4 (32→8 layers)
    let variants: Vec<(&str, Option<&str>, Vec<(usize, f64)>)> = vec![
        ("No skipping (DualCache)", None, vec![]),
        ("r1=r2=0.5 (default)", Some("es_blk32_b8"), vec![(1, 0.5), (2, 0.5)]),
        ("r2=0.75", Some("es_r2_only_75_blk32_b8"), vec![(2, 0.75)]),
        ("r2=0.5", Some("es_r2_only_50_blk32_b8"), vec![(2, 0.5)]),
        ("r2=0.25", Some("es_r2_only_25_blk32_b8"), vec![(2, 0.25)]),
        ("r0=0.5", Some("es_r0_only_50_blk32_b8"), vec![(0, 0.5)]),
        ("r1=0.5", Some("es_r1_only_50_blk32_b8"), vec![(1, 0.5)]),
        ("r4=0.5", Some("es_r4_only_50_blk32_b8"), vec![(4, 0.5)]),
    ];

    let mut table = Table::new(
        &format!("Table 9 analog: skip ratio/position on MATH~chain, {n} samples"),
        &["Skip Ratio & Position", "FLOPs Prop.", "TPS", "Speedup", "Score"],
    );
    let mut base_tps = None;
    for (label, exe, skip) in variants {
        let method = if exe.is_some() { Method::EsDllm } else { Method::DualCache };
        let opts = EvalOpts {
            es_exe_override: exe.map(|s| s.to_string()),
            ..Default::default()
        };
        let r = evaluate(&rt, arch, method, bench, n, &opts)?;
        let base = *base_tps.get_or_insert(r.tps);
        let prop = flops::flops_proportion(&dims, block, &skip);
        table.row(&[
            label.to_string(),
            format!("{:.0}%", prop * 100.0),
            format!("{:.2}", r.tps),
            format!("{:.2}x", r.tps / base),
            format!("{:.2}", r.score),
        ]);
    }
    table.print();
    table.write_csv("artifacts/results/table9.csv")?;
    Ok(())
}
