//! Hot-path microbenchmarks + §7 analyses:
//!   * per-executable latency (prefill / dual / es, b1 / b8) with the
//!     upload/execute/download breakdown from runtime counters,
//!   * the paper's §7 memory-overhead table analog (cache bytes/seq),
//!   * the §7 speedup-vs-FLOPs gap: measured speedup vs the analytic
//!     FLOPs ratio, explained by the per-iteration byte traffic that
//!     early-skipping does NOT reduce (this testbed's bandwidth wall).

use esdllm::bench::{bench, bench_n, Table};
use esdllm::cache::GroupCaches;
use esdllm::flops;
use esdllm::manifest::ExeKind;
use esdllm::runtime::tensor::HostTensor;
use esdllm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    esdllm::logging::init();
    let rt = Runtime::load_default()?;
    let iters = bench_n(12);

    for arch_name in ["llada-nano", "dream-nano"] {
        let arch = rt.arch(arch_name)?.clone();
        let d = arch.dims.clone();

        let mut table = Table::new(
            &format!("perf_hotpath: {arch_name} per-executable latency ({iters} iters)"),
            &["executable", "mean ms", "p90 ms", "exec ms", "transfer ms", "GFLOP", "GFLOP/s"],
        );

        for exe_name in [
            "vanilla_b8", "prefill_b8", "dual_blk8_b8", "es_blk8_b8",
            "dual_blk8_b1", "es_blk8_b1",
        ] {
            let exe = match arch.exe(exe_name) {
                Ok(e) => e.clone(),
                Err(_) => continue,
            };
            let batch = exe.batch;
            let caches = GroupCaches::new(&d, batch);
            let inputs: Vec<HostTensor> = match exe.kind {
                ExeKind::Prefill | ExeKind::Observe => vec![HostTensor::I32 {
                    shape: vec![batch, d.ctx],
                    data: vec![2; batch * d.ctx],
                }],
                ExeKind::Step => {
                    let layers: Vec<usize> = if exe.skip.is_empty() {
                        (0..d.n_layers).collect()
                    } else {
                        exe.skip_layers.clone()
                    };
                    vec![
                        HostTensor::I32 {
                            shape: vec![batch, exe.block.unwrap()],
                            data: vec![1; batch * exe.block.unwrap()],
                        },
                        HostTensor::scalar_i32(d.prompt_len as i32),
                        caches.kv_tensor(),
                        caches.gather_ind("h", &layers)?,
                        caches.conf_tensor(),
                        HostTensor::scalar_f32(0.5),
                    ]
                }
            };
            // warm compile + measure
            rt.run(&arch, &exe, "instruct", &inputs)?;
            let _ = rt.take_stats();
            let stats = bench(1, iters, || {
                rt.run(&arch, &exe, "instruct", &inputs).unwrap();
            });
            let rstats = rt.take_stats();
            let per = rstats.executions.max(1) as f64;
            let gflop = match exe.kind {
                ExeKind::Step => flops::step_flops(
                    &d,
                    exe.block.unwrap(),
                    &exe.skip,
                    exe.kv_len,
                ) * batch as f64 / 8.0 / 1e9,
                _ => flops::prefill_flops(&d) * batch as f64 / 8.0 / 1e9,
            };
            table.row(&[
                exe_name.to_string(),
                format!("{:.2}", stats.mean_s * 1e3),
                format!("{:.2}", stats.p90_s * 1e3),
                format!("{:.2}", rstats.exec_seconds / per * 1e3),
                format!("{:.2}", rstats.transfer_seconds / per * 1e3),
                format!("{gflop:.3}"),
                format!("{:.2}", gflop / stats.mean_s),
            ]);
        }
        table.print();
        table.write_csv(&format!("artifacts/results/perf_{arch_name}.csv"))?;

        // §7 memory-overhead analog
        let mut mem = Table::new(
            &format!("§7 analog: cache state per sequence ({arch_name})"),
            &["component", "bytes/seq", "bytes/output-token"],
        );
        let kv = (d.n_layers * 2 * d.n_kv_heads * d.ctx * d.head_dim * 2) as u64;
        let ind = (2 * d.gen_len * d.d_model * 2) as u64; // default 2 skip layers
        let logits = (d.gen_len * d.vocab * 4) as u64;
        for (name, b) in [("KV cache (bf16)", kv), ("indicator cache", ind),
                          ("latest logits", logits),
                          ("total", kv + ind + logits)] {
            mem.row(&[
                name.to_string(),
                format!("{b}"),
                format!("{}", b / d.gen_len as u64),
            ]);
        }
        mem.print();

        // §7 speedup-vs-FLOPs gap
        let skip = [(1usize, 0.5f64), (2, 0.5)];
        let fl_ratio = flops::step_flops(&d, 8, &[], d.ctx)
            / flops::step_flops(&d, 8, &skip, d.ctx);
        let traffic = flops::step_traffic_bytes(&d, 8, 2, d.ctx);
        println!(
            "\n§7 analog ({arch_name}): ES step FLOPs reduction {fl_ratio:.2}x, but \
             per-iteration traffic stays {:.2} MB — the measured ES-vs-Dual speedup \
             lands between 1x and {fl_ratio:.2}x, mirroring the paper's \
             memory-bound gap (theirs: 2.5x FLOPs -> 1.2-1.85x measured).",
            traffic as f64 / 1e6
        );
    }
    Ok(())
}
